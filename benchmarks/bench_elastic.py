"""Elastic churn storm: throughput under live topology change.

The elastic-operations PR's claim is that the epoch-versioned routing
plane keeps the pipeline moving *while* the topology changes: slot
migrations drain and commit under traffic, a shard added mid-run starts
taking records, and the live TCP consumers re-resolve their fan-in on
the piggybacked epoch bump — no restart, no loss, no duplication.

This benchmark measures that claim end to end on the daemon deployment
(``LcapClusterService``: every shard its own port + poller, the
coordinator's routing loop in a distributor thread, consumers on wire
``FanInStream`` sessions):

- **steady window** — 4 producers sustain records through the cluster
  with no topology change; aggregate delivered records/sec.
- **churn window** — the same workload while a churn storm runs:
  repeated ``migrate_slots`` (each waits for the previous drain to
  commit, then moves half of a random live shard's slots) plus one
  ``add_shard`` mid-window that the storm then migrates slots onto.
  The consumer observes every epoch bump on the wire mid-iteration.
- **reconciler sweep** — after both windows, the delivered multiset is
  compared against the logged set: every record exactly once (the
  graceful paths promise zero loss *and* zero dup; any discrepancy
  fails the run).
- **kill phase** (reported, not throughput-gated) — a forced migration:
  one shard killed under traffic with records in flight; asserts zero
  loss and reports the duplicate count (at-least-once is the contract
  there).

Windows are measured as *paired attempts* (steady then churn, back to
back, retried up to ``--attempts`` times on noisy hosts, best ratio
kept).  BENCH_elastic.json records every attempt plus the epoch span
and migration counts of the best churn window.  ``--smoke`` is the CI
mode: exit 1 when the churn-window throughput falls below
{CHURN_GATE}x the steady window, or when the reconciler finds any
loss/duplication in the graceful phases, or when the kill phase loses
a record.

Run:  PYTHONPATH=src python benchmarks/bench_elastic.py
      PYTHONPATH=src python benchmarks/bench_elastic.py --smoke
"""

from __future__ import annotations

import argparse
import json
import os
import random
import sys
import threading
import time
from typing import Dict, List, Set, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import records as R                       # noqa: E402
from repro.core.cluster import LcapCluster, LcapClusterService  # noqa: E402
from repro.core.llog import Llog                          # noqa: E402
from repro.core.session import Subscription, connect      # noqa: E402

CHURN_GATE = 0.5        # churn-window throughput vs steady window
N_PRODUCERS = 4
BATCH = 4096


def make_record(pid_num: int, i: int) -> R.ChangelogRecord:
    return R.ChangelogRecord(
        type=R.CL_STEP_COMMIT if i % 3 else R.CL_CREATE,
        tfid=R.Fid(1, i % 509, pid_num), pfid=R.Fid(1, 0, 0),
        name=b"rec%06d" % i, jobid=b"churn-run",
        metrics=(0.5, 1.25, 4096.0))


class Feeder(threading.Thread):
    """Sustained producers: each window logs ``per_producer`` records
    per journal in small chunks, yielding between chunks so logging
    overlaps routing/dispatch (a stream, not a pre-filled batch)."""

    def __init__(self, logs: Dict[str, Llog], start: int, count: int,
                 chunk: int = 256):
        super().__init__(daemon=True)
        self.logs = logs
        self.lo = start
        self.count = count
        self.chunk = chunk

    def run(self) -> None:
        done = 0
        while done < self.count:
            n = min(self.chunk, self.count - done)
            for p, log in enumerate(self.logs.values()):
                for i in range(self.lo + done, self.lo + done + n):
                    log.log(make_record(p, i))
            done += n
            time.sleep(0)                 # let the pollers in


class Consumer(threading.Thread):
    """The live TCP fan-in consumer: drains the stream continuously,
    recording every delivered (pid, index) and counting duplicates.
    Never restarted — topology changes must reach it via epoch bumps."""

    def __init__(self, stream):
        super().__init__(daemon=True)
        self.stream = stream
        self.seen: Set[Tuple[str, int]] = set()
        self.dups = 0
        self.delivered = 0
        self._lock = threading.Lock()
        self._halt = threading.Event()

    def run(self) -> None:
        while not self._halt.is_set():
            moved = 0
            for pid, batch in self.stream.fetch(BATCH):
                with self._lock:
                    for i in batch.indices():
                        if (pid, i) in self.seen:
                            self.dups += 1
                        else:
                            self.seen.add((pid, i))
                        self.delivered += 1
                moved += len(batch)
            self.stream.commit()
            if not moved:
                time.sleep(0.001)

    def covered(self, want: int) -> bool:
        with self._lock:
            return len(self.seen) >= want

    def stop(self) -> None:
        self._halt.set()
        self.join(timeout=10)


class ChurnStorm(threading.Thread):
    """Repeated slot migrations (each waiting for the previous drain
    to commit) plus one ``add_shard`` mid-window."""

    def __init__(self, svc: LcapClusterService, rng: random.Random):
        super().__init__(daemon=True)
        self.svc = svc
        self.rng = rng
        self.migrations = 0
        self.added = 0
        self._halt = threading.Event()

    def run(self) -> None:
        cluster = self.svc.cluster
        deadline_half = time.perf_counter()
        started = time.perf_counter()
        while not self._halt.is_set():
            if cluster._migration is not None:
                time.sleep(0.002)
                continue
            if (not self.added
                    and time.perf_counter() - started > 0.3):
                self.svc.add_shard()
                self.added = 1
            live = [i for i in range(len(cluster.shards))
                    if cluster.alive[i]]
            with_slots = [i for i in live if cluster.routing.counts(
                len(cluster.shards))[i] > 0]
            if len(live) < 2 or not with_slots:
                time.sleep(0.002)
                continue
            src = self.rng.choice(with_slots)
            dst = self.rng.choice([i for i in live if i != src])
            slots = cluster.routing.slots_of(src)
            try:
                cluster.migrate_slots(
                    slots[:max(1, len(slots) // 2)], dst)
                self.migrations += 1
            except Exception:
                pass                     # raced another topology change
            time.sleep(0.005)
        _ = deadline_half

    def stop(self) -> None:
        self._halt.set()
        self.join(timeout=10)


def run_window(logs: Dict[str, Llog], consumer: Consumer, start: int,
               per_producer: int, churn: bool, svc: LcapClusterService,
               rng: random.Random, timeout: float = 120.0) -> dict:
    want = len(consumer.seen) + per_producer * len(logs)
    storm = None
    t0 = time.perf_counter()
    feeder = Feeder(logs, start, per_producer)
    feeder.start()
    if churn:
        storm = ChurnStorm(svc, rng)
        storm.start()
    feeder.join()
    deadline = t0 + timeout
    while not consumer.covered(want):
        if time.perf_counter() > deadline:
            break
        time.sleep(0.002)
    elapsed = time.perf_counter() - t0
    if storm is not None:
        storm.stop()
        # let an in-flight drain settle before the next window
        settle = time.perf_counter() + 10
        while (svc.cluster._migration is not None
               and time.perf_counter() < settle):
            time.sleep(0.005)
    n = per_producer * len(logs)
    out = {"records": n, "seconds": round(elapsed, 4),
           "records_per_sec": round(n / elapsed, 1),
           "complete": consumer.covered(want)}
    if storm is not None:
        out["migrations"] = storm.migrations
        out["shards_added"] = storm.added
    return out


def reconcile(logs: Dict[str, Llog], consumer: Consumer,
              total_per_producer: int) -> dict:
    """The sweep: every logged record delivered exactly once."""
    want = {(pid, i) for pid in logs
            for i in range(1, total_per_producer + 1)}
    with consumer._lock:
        seen = set(consumer.seen)
        dups = consumer.dups
    lost = len(want - seen)
    extra = len(seen - want)
    return {"expected": len(want), "delivered_unique": len(seen),
            "lost": lost, "unexpected": extra, "duplicates": dups,
            "discrepancies": lost + extra + dups}


def run_attempt(per_producer: int, seed: int) -> dict:
    logs = {f"ost{p}": Llog(f"ost{p}") for p in range(N_PRODUCERS)}
    cluster = LcapCluster(logs, n_shards=2, batch_size=BATCH)
    svc = LcapClusterService(cluster).start()
    rng = random.Random(seed)
    try:
        sess = connect(svc)
        stream = sess.subscribe(Subscription(
            group="bench", auto_commit=False, max_records=BATCH))
        epoch0 = stream.epoch
        consumer = Consumer(stream)
        consumer.start()
        steady = run_window(logs, consumer, start=1,
                            per_producer=per_producer, churn=False,
                            svc=svc, rng=rng)
        churn = run_window(logs, consumer, start=per_producer + 1,
                           per_producer=per_producer, churn=True,
                           svc=svc, rng=rng)
        # drain the tail of the churn window fully before reconciling
        deadline = time.perf_counter() + 30
        want = 2 * per_producer * N_PRODUCERS
        while (not consumer.covered(want)
               and time.perf_counter() < deadline):
            time.sleep(0.005)
        sweep = reconcile(logs, consumer, 2 * per_producer)
        epochs = stream.epoch - epoch0
        shards_seen = sorted(stream.shards)
        # ---- kill phase: forced migration under traffic, in flight
        kill_fee = Feeder(logs, 2 * per_producer + 1, per_producer // 2)
        kill_fee.start()
        time.sleep(0.05)                 # records in flight everywhere
        victims = [i for i in range(len(cluster.shards))
                   if cluster.alive[i]]
        cluster.kill_shard(rng.choice(victims))
        kill_fee.join()
        want = sweep["expected"] + (per_producer // 2) * N_PRODUCERS
        deadline = time.perf_counter() + 60
        while (not consumer.covered(want)
               and time.perf_counter() < deadline):
            time.sleep(0.005)
        kill_sweep = reconcile(logs, consumer,
                               2 * per_producer + per_producer // 2)
        consumer.stop()
        sess.close()
        ratio = round(churn["records_per_sec"]
                      / steady["records_per_sec"], 3)
        return {
            "steady": steady, "churn": churn, "churn_ratio": ratio,
            "epoch_bumps_observed": epochs,
            "fan_in_shards": shards_seen,
            "reconciler": sweep,
            "kill_phase": {"lost": kill_sweep["lost"],
                           "duplicates": kill_sweep["duplicates"],
                           "unexpected": kill_sweep["unexpected"]},
        }
    finally:
        svc.stop()
        cluster.close()


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__.format(CHURN_GATE=CHURN_GATE))
    ap.add_argument("--records", type=int, default=12_000,
                    help="records per producer per window")
    ap.add_argument("--attempts", type=int, default=3,
                    help="paired steady/churn retries; best ratio kept")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: exit 1 when the churn window falls "
                         f"below {CHURN_GATE}x steady, the reconciler "
                         "finds any graceful-phase loss/dup, or the "
                         "kill phase loses a record")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_elastic.json"))
    args = ap.parse_args()

    attempts = []
    best = None
    for k in range(args.attempts):
        run = run_attempt(args.records, seed=0xE1A + k)
        run["attempt"] = k
        attempts.append(run)
        print(f"  attempt={k}: steady="
              f"{run['steady']['records_per_sec']:>9,.0f} rec/s  "
              f"churn={run['churn']['records_per_sec']:>9,.0f} rec/s "
              f"({run['churn_ratio']:.2f}x)  "
              f"migrations={run['churn'].get('migrations', 0)} "
              f"epochs+{run['epoch_bumps_observed']} "
              f"discrepancies={run['reconciler']['discrepancies']} "
              f"kill_lost={run['kill_phase']['lost']}")
        if best is None or run["churn_ratio"] > best["churn_ratio"]:
            best = run
        if (run["churn_ratio"] >= CHURN_GATE + 0.25
                and run["reconciler"]["discrepancies"] == 0
                and run["kill_phase"]["lost"] == 0):
            break

    clean = [r for r in attempts
             if r["reconciler"]["discrepancies"] == 0
             and r["kill_phase"]["lost"] == 0]
    gate_ratio = max((r["churn_ratio"] for r in clean), default=0.0)
    payload = {
        "benchmark": "elastic churn storm: live migration + shard add "
                     "under sustained wire traffic",
        "unit": "records/sec",
        "workload": {"producers": N_PRODUCERS,
                     "records_per_producer_per_window": args.records,
                     "consumer": "one TCP FanInStream, never restarted; "
                                 "epoch bumps observed mid-iteration"},
        "attempts": attempts,
        "best": best,
        "gate": {"required_churn_ratio": CHURN_GATE,
                 "best_clean_churn_ratio": gate_ratio,
                 "graceful_discrepancies":
                     best["reconciler"]["discrepancies"] if best else -1,
                 "kill_lost":
                     best["kill_phase"]["lost"] if best else -1},
    }
    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"wrote {os.path.abspath(args.out)}; best clean churn ratio "
          f"{gate_ratio:.2f}x (gate {CHURN_GATE}x)")
    if args.smoke and gate_ratio < CHURN_GATE:
        print(f"SMOKE FAIL: no attempt kept >= {CHURN_GATE}x steady "
              f"throughput through the churn storm with zero "
              f"discrepancies and zero kill-phase loss")
        sys.exit(1)


if __name__ == "__main__":
    main()
