"""Observability-plane overhead: dispatch throughput with the plane on.

Measures the columnar dispatch drain (the ``run_columnar`` shape from
bench_proxy: proxy API, full-drain consumer, bulk commit/ack) twice on
the same machine and workload:

- ``baseline``: the bare pipeline, nothing watching.
- ``observed``: the same pipeline with the whole plane attached — a
  ``MetricsRegistry`` on the proxy (pump-latency histogram + stats
  collectors) and an ephemeral ``ActivityAggregator`` subscription
  receiving every record (whole-batch chunk hand-off into its outbox,
  exactly what a live dashboard consumes).

The timed section is the *dispatch path*: pump + primary-consumer
drain.  The aggregator's own fold runs where it runs in deployment —
on the viewer's CPU, off the pipeline's critical path — so it is
measured separately: ``fold_records_per_sec`` over the full backlog,
with a keep-up gate (the fold must be at least as fast as observed
dispatch, or a live dashboard would fall behind its stream).

``--smoke`` (the CI mode) fails (exit 1) when the observed dispatch
path runs more than {MAX_OVERHEAD_PCT}% slower than the paired bare
run, or the fold cannot keep up with dispatch.  Also reports scrape
cost (registry snapshot + Prometheus render) as an informational side
measurement.  Writes BENCH_obs.json (consumed by CI as an artifact).

Run:  PYTHONPATH=src python benchmarks/bench_obs.py
      PYTHONPATH=src python benchmarks/bench_obs.py --smoke
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import records as R                       # noqa: E402
from repro.core.llog import Llog                          # noqa: E402
from repro.core.proxy import LcapProxy                    # noqa: E402
from repro.obs import (ActivityAggregator, MetricsRegistry,  # noqa: E402
                       render_prometheus)

#: smoke gate: attaching the plane may cost at most this much dispatch
#: throughput vs the paired bare run
MAX_OVERHEAD_PCT = 10.0

FLAGS = R.CLF_JOBID | R.CLF_SHARD | R.CLF_METRICS
T0 = 1_700_000_000_000_000_000
WINDOW_NS = 1_000_000_000


def fill_logs(n_producers: int, total_records: int):
    per = total_records // n_producers
    return {f"mdt{p}": Llog(f"mdt{p}") for p in range(n_producers)}, per


def feed(logs: Dict[str, Llog], per: int) -> int:
    """An aggregation-relevant stream: rolling 1 s windows, a few
    jobids, per-producer shard tags, a metric value on most records."""
    n = 0
    for p, log in enumerate(logs.values()):
        for i in range(per):
            log.log(R.ChangelogRecord(
                type=R.CL_CREATE if i % 3 else R.CL_CLOSE,
                tfid=R.Fid(1, i, 0), pfid=R.Fid(1, 0, 0),
                name=b"f%08d" % i, jobid=b"job-%d" % (i % 8),
                shard=(0, p, 0, 0),
                metrics=(float(i % 100),) if i % 2 else None,
                time=T0 + i * 50_000))
            n += 1
    return n


def run_drain(n_producers: int, total_records: int, observe: bool) -> dict:
    logs, per = fill_logs(n_producers, total_records)
    # same outbox headroom both runs: paired measurements must differ
    # only in the plane being attached, and the undrained aggregator
    # outbox must never back-pressure the timed section
    proxy = LcapProxy(logs, batch_size=4096, outbox_cap=1 << 22)
    cid = proxy.subscribe("bench", flags=FLAGS)
    reg = agg = None
    if observe:
        reg = MetricsRegistry()
        proxy.attach_registry(reg)
        agg = ActivityAggregator(proxy, mode="ephemeral", flags=FLAGS,
                                 window_ns=WINDOW_NS, retention=1 << 30)
        reg.register_collector(agg.collector())
    total = feed(logs, per)

    t0 = time.perf_counter()
    done = 0
    while done < total:
        moved = proxy.pump()
        while True:
            batches = proxy.fetch_batches(cid, 1 << 30)
            if not batches:
                break
            for pid, batch in batches:
                proxy.commit(cid, {pid: batch.indices()})
                done += len(batch)
        if not moved:
            proxy.flush_upstream()
    elapsed = time.perf_counter() - t0

    assert all(log.first_index == log.last_index + 1 for log in logs.values())
    out = {"records": total, "seconds": elapsed,
           "records_per_sec": total / elapsed}
    if observe:
        # the viewer's side of the plane, off the dispatch path: fold
        # the full backlog and time it — the keep-up rate
        t1 = time.perf_counter()
        folded = agg.run_once(1 << 30)
        fold_secs = time.perf_counter() - t1
        assert folded == total and agg.stats["records"] == total, \
            f"aggregator saw {agg.stats['records']}/{total}"
        assert proxy.stats["ephemeral_drops"] == 0
        out["fold_records_per_sec"] = folded / fold_secs
        out["windows_folded"] = len(agg.window_ids())
        t2 = time.perf_counter()
        text = render_prometheus(reg.snapshot())
        out["scrape_seconds"] = time.perf_counter() - t2
        out["scrape_bytes"] = len(text)
    return out


def measure(n_producers: int, total_records: int) -> dict:
    base = run_drain(n_producers, total_records, observe=False)
    obs = run_drain(n_producers, total_records, observe=True)
    overhead = (1.0 - obs["records_per_sec"] / base["records_per_sec"]) * 100
    return {"baseline": base, "observed": obs,
            "overhead_pct": round(overhead, 2),
            "fold_keeps_up": bool(obs["fold_records_per_sec"]
                                  >= obs["records_per_sec"])}


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__.format(MAX_OVERHEAD_PCT=MAX_OVERHEAD_PCT))
    ap.add_argument("--records", type=int, default=64_000,
                    help="total records per topology")
    ap.add_argument("--producers", type=int, nargs="+", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI workload; exit 1 if the observed "
                         f"dispatch path is > {MAX_OVERHEAD_PCT}% slower "
                         "or the fold cannot keep up")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_obs.json"))
    args = ap.parse_args()
    if args.smoke:
        args.records = min(args.records, 20_000)
        producers = args.producers or [1, 4]
    else:
        producers = args.producers or [1, 4, 16]

    results = {}
    for n in producers:
        r = measure(n, args.records)
        if args.smoke and (r["overhead_pct"] > MAX_OVERHEAD_PCT
                           or not r["fold_keeps_up"]):
            # one retry: a shared CI runner can stall a single paired
            # measurement; a real regression fails both
            r2 = measure(n, args.records)
            if (r2["overhead_pct"] < r["overhead_pct"]
                    or (r2["fold_keeps_up"] and not r["fold_keeps_up"])):
                r = r2
        results[str(n)] = r
        print(f"producers={n:3d}  "
              f"bare={r['baseline']['records_per_sec']:>12,.0f} rec/s  "
              f"observed={r['observed']['records_per_sec']:>12,.0f} rec/s  "
              f"overhead={r['overhead_pct']:+.2f}%  "
              f"fold={r['observed']['fold_records_per_sec']:>12,.0f} rec/s  "
              f"scrape={r['observed']['scrape_seconds'] * 1e3:.1f}ms"
              f"/{r['observed']['scrape_bytes']:,}B")

    payload = {
        "benchmark": "observability plane overhead on columnar dispatch",
        "unit": "records/sec",
        "flags": "CLF_JOBID|CLF_SHARD|CLF_METRICS",
        "total_records": args.records,
        "results": results,
        "max_overhead_pct": max(r["overhead_pct"] for r in results.values()),
        "fold_keeps_up": all(r["fold_keeps_up"] for r in results.values()),
    }
    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"wrote {os.path.abspath(args.out)}")
    if args.smoke and payload["max_overhead_pct"] > MAX_OVERHEAD_PCT:
        print(f"SMOKE FAIL: observability overhead "
              f"{payload['max_overhead_pct']:.2f}% > {MAX_OVERHEAD_PCT}% — "
              f"the plane leaked onto the hot path")
        sys.exit(1)
    if args.smoke and not payload["fold_keeps_up"]:
        print("SMOKE FAIL: aggregator fold slower than dispatch — a live "
              "dashboard would fall behind its stream")
        sys.exit(1)


if __name__ == "__main__":
    main()
