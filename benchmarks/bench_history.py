"""Compacted history tier: compaction ratio + bootstrap-from-history.

A churn-heavy workload (files created, attr-spammed, renamed, and
mostly unlinked — plus heartbeat chatter) runs through a proxy whose
consumer group keeps up, so the journal trims aggressively and the
trimmed segments land in the history tier.  Two configurations of the
same workload are compared:

- **raw**: ``HistoryStore(compactor=None)`` retains every trimmed
  record — the "full-journal replay" a late consumer would otherwise
  need;
- **compacted**: the default ``Compactor`` coalesces per FID
  (CREATE+UNLINK annihilation, rename folding, last-writer-wins
  thinning).

Measured: the record-count compaction ratio (raw records archived /
compacted records retained) and the wall time for a replay-bootstrap
subscription (``Subscription(replay=True)``) to reconstruct final
state from each store.  Both bootstraps are checked to produce the
*same state* as a from-the-start live consumer before their timings
count.

Run:  PYTHONPATH=src python benchmarks/bench_history.py
      PYTHONPATH=src python benchmarks/bench_history.py --smoke

``--smoke`` is the CI mode: a reduced workload that fails (exit 1)
when the compaction ratio drops below {SMOKE_MIN_RATIO}x or the
replay states diverge.  Writes BENCH_history.json.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import records as R                       # noqa: E402
from repro.core.history import Compactor, HistoryStore    # noqa: E402
from repro.core.llog import Llog                          # noqa: E402
from repro.core.proxy import LcapProxy                    # noqa: E402
from repro.core.session import Subscription, connect      # noqa: E402

SMOKE_MIN_RATIO = 3.0


def apply_state(state, r):
    t, k = r.type, r.key()
    if t in (R.CL_CREATE, R.CL_MKDIR):
        state[k] = (r.name, None)
    elif t in (R.CL_UNLINK, R.CL_RMDIR):
        state.pop(k, None)
    elif t == R.CL_RENAME:
        if k in state:
            state[k] = (r.name, state[k][1])
    elif t == R.CL_SETATTR:
        if k in state:
            state[k] = (state[k][0], r.index)
    elif t == R.CL_HEARTBEAT:
        state[("hb",) + k] = r.metrics


def churn(log, start: int, n_files: int, setattrs: int, unlink_pct: int,
          hb_every: int) -> None:
    """Deterministic churn: every file is created, attr-spammed and
    renamed; ``unlink_pct``% die; hosts heartbeat throughout.
    ``start`` offsets the FID range so successive calls continue the
    namespace instead of recreating the same files."""
    for i in range(start, start + n_files):
        log.log(R.ChangelogRecord(type=R.CL_CREATE, tfid=R.Fid(1, i, 0),
                                  pfid=R.Fid(1, 0, 0), name=b"f%07d" % i))
        for _ in range(setattrs):
            log.log(R.ChangelogRecord(type=R.CL_SETATTR,
                                      tfid=R.Fid(1, i, 0),
                                      pfid=R.Fid(1, 0, 0)))
        log.log(R.ChangelogRecord(type=R.CL_RENAME, tfid=R.Fid(1, i, 0),
                                  pfid=R.Fid(1, 0, 0), name=b"g%07d" % i,
                                  sname=b"f%07d" % i, sfid=R.Fid(1, i, 0)))
        if i % 100 < unlink_pct:
            log.log(R.ChangelogRecord(type=R.CL_UNLINK, tfid=R.Fid(1, i, 0),
                                      pfid=R.Fid(1, 0, 0),
                                      name=b"g%07d" % i))
        if i % hb_every == 0:
            log.log(R.ChangelogRecord(type=R.CL_HEARTBEAT,
                                      tfid=R.Fid(2, i % 16, 0),
                                      metrics=(0.1 * (i % 7),)))


def run_workload(workdir: str, compact: bool, n_files: int, setattrs: int,
                 ) -> dict:
    """One full pass: churn -> live consume (trims into history) ->
    replay bootstrap; returns measurements."""
    path = os.path.join(workdir, "compacted" if compact else "raw")
    os.makedirs(path)
    store = HistoryStore(os.path.join(path, "j.hist"),
                         compactor=Compactor() if compact else None)
    log = Llog("mdt0", path=os.path.join(path, "j"), segment_records=1024,
               history=store)
    proxy = LcapProxy({"mdt0": log})
    live = connect(proxy).subscribe("live")
    state_live = {}

    t0 = time.perf_counter()
    done = 0
    batch_files = max(1, n_files // 50)
    while done < n_files:
        churn(log, done, min(batch_files, n_files - done), setattrs,
              unlink_pct=80, hb_every=10)
        done += batch_files
        proxy.pump()
        for _pid, b in live:
            for x in range(len(b)):
                apply_state(state_live, b.record(x))
        live.commit()
        proxy.flush_upstream()
    ingest_s = time.perf_counter() - t0
    total = log.last_index
    store.compact_now()
    retained = store.record_count

    boot = connect(proxy).subscribe(Subscription(group="boot", replay=True,
                                                 max_records=4096))
    state_boot = {}
    t0 = time.perf_counter()
    while True:
        pairs = boot.fetch(8192)
        for _pid, b in pairs:
            for x in range(len(b)):
                apply_state(state_boot, b.record(x))
        boot.commit()
        if not pairs and not boot.replaying:
            break
    bootstrap_s = time.perf_counter() - t0
    assert state_boot == state_live, "replay diverged from live state"
    return {"records_total": total, "history_records": retained,
            "replayed": boot.replayed, "ingest_s": round(ingest_s, 4),
            "bootstrap_s": round(bootstrap_s, 4),
            "bootstrap_rec_per_s": round(boot.replayed /
                                         max(bootstrap_s, 1e-9))}


def main() -> int:
    ap = argparse.ArgumentParser(
        description="history-tier compaction + replay-bootstrap benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small workload, fail below the "
                         f"{SMOKE_MIN_RATIO}x compaction floor")
    ap.add_argument("--files", type=int, default=None)
    ap.add_argument("--setattrs", type=int, default=6)
    args = ap.parse_args()
    n_files = args.files or (1500 if args.smoke else 12000)

    workdir = tempfile.mkdtemp(prefix="bench_history.")
    try:
        raw = run_workload(workdir, compact=False, n_files=n_files,
                           setattrs=args.setattrs)
        compacted = run_workload(workdir, compact=True, n_files=n_files,
                                 setattrs=args.setattrs)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    ratio = raw["history_records"] / max(1, compacted["history_records"])
    speedup = raw["bootstrap_s"] / max(compacted["bootstrap_s"], 1e-9)
    payload = {
        "bench": "history", "smoke": bool(args.smoke),
        "workload": {"files": n_files, "setattrs_per_file": args.setattrs,
                     "unlink_pct": 80, "heartbeat_every": 10},
        "raw": raw, "compacted": compacted,
        "compaction_ratio": round(ratio, 2),
        "bootstrap_speedup": round(speedup, 2),
    }
    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_history.json")
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(json.dumps(payload, indent=2))
    if ratio < SMOKE_MIN_RATIO:
        print(f"FAIL: compaction ratio {ratio:.2f}x < {SMOKE_MIN_RATIO}x",
              file=sys.stderr)
        return 1
    print(f"compaction {ratio:.1f}x, bootstrap-from-history "
          f"{speedup:.1f}x faster than full-journal replay")
    return 0


if __name__ == "__main__":
    sys.exit(main())
