"""Federated multi-tenant plane: scoping overhead + fan-in integrity.

Two measurements, two smoke gates:

1. **Tenant-scoping pushdown cost.**  The columnar dispatch drain
   (``bench_proxy``'s ``run_columnar`` shape) runs twice on the same
   workload: once with a plain group, once with the group scoped to a
   ``TenantPrincipal`` whose prefix covers *every* record — so both
   runs deliver identical records and the delta is purely the pushdown
   predicate (jobid-column compares + the per-tenant eligibility
   partition + quota accounting).  ``--smoke`` fails when the scoped
   run is more than {MAX_OVERHEAD_PCT}% slower.  A mixed two-tenant run
   (half the records out of scope) is reported informationally.

2. **Federation fan-in integrity.**  Two 2-shard clusters federated
   under one ``FederatedStream``; every (origin, producer, index)
   triple must arrive exactly once, with the right origin stamp.
   ``--smoke`` fails on any loss or duplication.

Writes BENCH_federation.json (consumed by CI as an artifact).

Run:  PYTHONPATH=src python benchmarks/bench_federation.py
      PYTHONPATH=src python benchmarks/bench_federation.py --smoke
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import records as R                       # noqa: E402
from repro.core.cluster import LcapCluster                # noqa: E402
from repro.core.federation import Federation              # noqa: E402
from repro.core.llog import Llog                          # noqa: E402
from repro.core.proxy import LcapProxy                    # noqa: E402
from repro.core.session import Subscription, connect      # noqa: E402
from repro.core.tenancy import TenantPrincipal            # noqa: E402

#: smoke gate: tenant scoping may cost at most this much dispatch
#: throughput vs the paired unscoped run
MAX_OVERHEAD_PCT = 10.0

FLAGS = R.CLF_JOBID | R.CLF_SHARD | R.CLF_METRICS
T0 = 1_700_000_000_000_000_000


def fill_logs(n_producers: int) -> Dict[str, Llog]:
    return {f"mdt{p}": Llog(f"mdt{p}") for p in range(n_producers)}


def feed(logs: Dict[str, Llog], per: int, two_tenants: bool = False) -> int:
    """Jobid-bearing stream: 8 jobids under one tenant prefix, or an
    even split across two tenant prefixes for the mixed run."""
    n = 0
    for p, log in enumerate(logs.values()):
        for i in range(per):
            pre = b"acme" if (not two_tenants or i % 2) else b"evil"
            log.log(R.ChangelogRecord(
                type=R.CL_CREATE if i % 3 else R.CL_CLOSE,
                tfid=R.Fid(1, i, 0), pfid=R.Fid(1, 0, 0),
                name=b"f%08d" % i, jobid=b"%s.job-%d" % (pre, i % 8),
                shard=(0, p, 0, 0),
                metrics=(float(i % 100),) if i % 2 else None,
                time=T0 + i * 50_000))
            n += 1
    return n


def run_drain(n_producers: int, total_records: int,
              tenant: TenantPrincipal = None,
              two_tenants: bool = False) -> dict:
    logs = fill_logs(n_producers)
    proxy = LcapProxy(logs, batch_size=4096, outbox_cap=1 << 22)
    cid = proxy.attach("bench", flags=FLAGS, tenant=tenant)["cid"]
    total = feed(logs, total_records // n_producers, two_tenants)
    expect = total if not two_tenants else total // 2

    t0 = time.perf_counter()
    done = 0
    while done < expect:
        moved = proxy.pump()
        while True:
            batches = proxy.fetch_batches(cid, 1 << 30)
            if not batches:
                break
            for pid, batch in batches:
                proxy.commit(cid, {pid: batch.indices()})
                done += len(batch)
        if not moved:
            proxy.flush_upstream()
    elapsed = time.perf_counter() - t0

    proxy.flush_upstream()
    assert done == expect, f"delivered {done}, expected {expect}"
    assert all(log.first_index == log.last_index + 1
               for log in logs.values()), "journals not trimmed"
    return {"records": total, "delivered": done, "seconds": elapsed,
            "records_per_sec": total / elapsed,
            "tenant_filtered": proxy.stats["tenant_filtered"]}


def measure_scoping(n_producers: int, total_records: int,
                    reps: int = 3) -> dict:
    """Paired runs: bare vs all-in-scope tenant (identical delivery —
    the overhead is the predicate), plus the mixed informational run.
    Each arm keeps its best of ``reps`` runs — the drain is a few ms,
    so a single scheduler stall would otherwise dominate the ratio."""
    covers_all = TenantPrincipal("acme", prefixes=[b"acme."])
    pairs = []
    for _ in range(reps):
        # interleave the arms so slow machine-state drift (turbo,
        # noisy neighbors) hits both sides of the ratio alike
        pairs.append((run_drain(n_producers, total_records),
                      run_drain(n_producers, total_records,
                                tenant=covers_all),
                      run_drain(n_producers, total_records,
                                tenant=covers_all, two_tenants=True)))
    best = lambda runs: min(runs, key=lambda r: r["seconds"])  # noqa: E731
    base = best([p[0] for p in pairs])
    scoped = best([p[1] for p in pairs])
    mixed = best([p[2] for p in pairs])
    # gate on the smallest *paired* delta: a real regression shows in
    # every clean pair, while a scheduler stall corrupts only the pair
    # it lands in.  The median pair is the honest headline estimate.
    deltas = sorted((1.0 - s["records_per_sec"] / b["records_per_sec"])
                    * 100 for b, s, _ in pairs)
    return {"baseline": base, "scoped": scoped, "mixed": mixed,
            "overhead_pct": round(deltas[len(deltas) // 2], 2),
            "overhead_pct_gate": round(deltas[0], 2)}


def run_fan_in(per_producer: int) -> dict:
    """Two 2-shard clusters federated; exact-once delivery with origin
    stamps is the gate, throughput the headline number."""
    logs_a = {"fs0-p0": Llog("fs0-p0"), "fs0-p1": Llog("fs0-p1")}
    logs_b = {"fs1-p0": Llog("fs1-p0"), "fs1-p1": Llog("fs1-p1")}
    ca = LcapCluster(logs_a, n_shards=2, batch_size=4096)
    cb = LcapCluster(logs_b, n_shards=2, batch_size=4096)
    fed = Federation({"fs0": ca, "fs1": cb})
    stream = fed.subscribe(Subscription(group="fan", auto_commit=False,
                                        flags=FLAGS))
    total = 0
    for logs in (logs_a, logs_b):
        total += feed(logs, per_producer)

    t0 = time.perf_counter()
    seen: Dict[tuple, int] = {}
    misstamped = 0
    idle = 0
    while idle < 5:
        moved = fed.pump()
        got = 0
        for origin, pid, batch in stream.fetch(1 << 30):
            if batch.origin != origin or not pid.startswith(origin):
                misstamped += len(batch)
            for ix in batch.indices():
                key = (origin, pid, ix)
                seen[key] = seen.get(key, 0) + 1
            got += len(batch)
        stream.commit()
        idle = 0 if (moved or got) else idle + 1
    elapsed = time.perf_counter() - t0

    dup = sum(c - 1 for c in seen.values() if c > 1)
    fed.close()
    ca.close()
    cb.close()
    return {"records": total, "seconds": elapsed,
            "records_per_sec": total / elapsed,
            "delivered_unique": len(seen), "lost": total - len(seen),
            "duplicated": dup, "misstamped": misstamped,
            "clean": len(seen) == total and not dup and not misstamped}


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__.format(MAX_OVERHEAD_PCT=MAX_OVERHEAD_PCT))
    ap.add_argument("--records", type=int, default=64_000,
                    help="total records per topology")
    ap.add_argument("--producers", type=int, nargs="+", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI workload; exit 1 when tenant "
                         f"scoping costs > {MAX_OVERHEAD_PCT}% dispatch "
                         "throughput or federation fan-in loses or "
                         "duplicates any record")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_federation.json"))
    args = ap.parse_args()
    if args.smoke:
        # not smaller: the paired drain is a few tens of ms, and the
        # overhead ratio needs enough work per run to ride out
        # scheduler noise on a shared CI runner
        args.records = min(args.records, 60_000)
        producers = args.producers or [1, 4]
    else:
        producers = args.producers or [1, 4, 16]

    results = {}
    for n in producers:
        r = measure_scoping(n, args.records)
        if args.smoke and r["overhead_pct_gate"] > MAX_OVERHEAD_PCT:
            # one retry: a shared CI runner can stall a single paired
            # measurement; a real regression fails both
            r2 = measure_scoping(n, args.records)
            if r2["overhead_pct_gate"] < r["overhead_pct_gate"]:
                r = r2
        results[str(n)] = r
        print(f"producers={n:3d}  "
              f"bare={r['baseline']['records_per_sec']:>12,.0f} rec/s  "
              f"scoped={r['scoped']['records_per_sec']:>12,.0f} rec/s  "
              f"overhead={r['overhead_pct']:+.2f}%  "
              f"mixed={r['mixed']['records_per_sec']:>12,.0f} rec/s "
              f"(filtered {r['mixed']['tenant_filtered']:,})")

    fan = run_fan_in(args.records // 4)
    print(f"fan-in    {fan['records_per_sec']:>12,.0f} rec/s  "
          f"unique={fan['delivered_unique']:,}/{fan['records']:,}  "
          f"lost={fan['lost']}  dup={fan['duplicated']}  "
          f"misstamped={fan['misstamped']}")

    payload = {
        "benchmark": "tenant-scoping pushdown overhead + federation "
                     "fan-in integrity",
        "unit": "records/sec",
        "flags": "CLF_JOBID|CLF_SHARD|CLF_METRICS",
        "total_records": args.records,
        "scoping": results,
        "fan_in": fan,
        "max_overhead_pct": max(r["overhead_pct_gate"]
                                for r in results.values()),
        "fan_in_clean": fan["clean"],
    }
    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"wrote {os.path.abspath(args.out)}")
    if args.smoke and payload["max_overhead_pct"] > MAX_OVERHEAD_PCT:
        print(f"SMOKE FAIL: tenant scoping costs "
              f"{payload['max_overhead_pct']:.2f}% > {MAX_OVERHEAD_PCT}% "
              f"dispatch throughput — the pushdown leaked onto the "
              f"unscoped hot path")
        sys.exit(1)
    if args.smoke and not fan["clean"]:
        print(f"SMOKE FAIL: federation fan-in lost {fan['lost']} / "
              f"duplicated {fan['duplicated']} / misstamped "
              f"{fan['misstamped']} records")
        sys.exit(1)


if __name__ == "__main__":
    main()
