"""Build the EXPERIMENTS.md roofline/dry-run tables from the artifacts
written by repro.launch.dryrun.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir artifacts/dryrun]
"""

import argparse
import glob
import json
import os
from collections import defaultdict

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirname):
    cells = {}
    for fn in glob.glob(os.path.join(dirname, "*.json")):
        with open(fn) as fh:
            d = json.load(fh)
        key = (d["arch"], d["shape"], d["mesh"], d.get("tag") or "")
        cells[key] = d
    return cells


def fmt_s(x):
    if x is None:
        return "—"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def fmt_b(x):
    if x is None:
        return "—"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def enrich(d):
    """Add the fused-HBM model terms (see launch/roofline_model.py)."""
    if d.get("status") != "ok" or "compute_s" not in d:
        return d
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro import configs as C
    from repro.launch import mesh as M
    from repro.launch.roofline_model import estimate_hbm_bytes
    from repro.models.config import SHAPES
    cfg = C.get_config(d["arch"])
    shape = SHAPES[d["shape"]]
    est = estimate_hbm_bytes(cfg, shape, n_dev=d["n_devices"], dp=d["dp"],
                             tp=16, n_micro=d.get("n_micro", 1))
    d["memory_model_s"] = est / M.HBM_BW
    terms = {"compute_s": d["compute_s"], "memory_model_s": d["memory_model_s"],
             "collective_s": d["collective_s"]}
    d["dominant_model"] = max(terms, key=terms.get)
    bound = max(terms.values())
    d["roofline_fraction_model"] = d["compute_s"] / bound if bound else 0.0
    return d


def roofline_table(cells, mesh="single", tag=""):
    lines = [
        "| arch | shape | compute | mem(xla-ub) | mem(fused) | collective | "
        "dominant | MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m, t), d in sorted(
            cells.items(), key=lambda kv: (kv[0][0], ORDER.index(kv[0][1]))):
        if m != mesh or t != tag:
            continue
        if d["status"] == "skip":
            lines.append(f"| {arch} | {shape} | SKIP | | | | | | "
                         f"{d['reason'][:40]}… |")
            continue
        d = enrich(d)
        ratio = d.get("model_flops_ratio")
        lines.append(
            f"| {arch} | {shape} | {fmt_s(d.get('compute_s'))} | "
            f"{fmt_s(d.get('memory_s'))} | {fmt_s(d.get('memory_model_s'))} | "
            f"{fmt_s(d.get('collective_s'))} | "
            f"{d.get('dominant_model','?').replace('_s','')} | "
            f"{ratio:.2f} | {d.get('roofline_fraction_model', 0):.3f} |"
            if ratio is not None else
            f"| {arch} | {shape} | ? | ? | ? | ? | ? | ? | ? |")
    return "\n".join(lines)


def dryrun_table(cells, mesh):
    lines = [
        "| arch | shape | status | compile | peak bytes/dev | "
        "AG | AR | RS | A2A | CP |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m, t), d in sorted(
            cells.items(), key=lambda kv: (kv[0][0], ORDER.index(kv[0][1]))):
        if m != mesh or t:
            continue
        if d["status"] == "skip":
            lines.append(f"| {arch} | {shape} | SKIP (documented) | | | | | | | |")
            continue
        mem = d.get("memory") or {}
        peak = mem.get("peak_bytes") or mem.get("temp_bytes")
        c = d.get("collectives_full", {})

        def n(op):
            return c.get(op, {}).get("count", 0)

        lines.append(
            f"| {arch} | {shape} | ok | {d.get('compile_wall_s','?')}s | "
            f"{fmt_b(peak)} | {n('all-gather')} | {n('all-reduce')} | "
            f"{n('reduce-scatter')} | {n('all-to-all')} | "
            f"{n('collective-permute')} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    cells = load(args.dir)
    print("## Roofline (single-pod 16x16, per-chip terms)\n")
    print(roofline_table(cells, "single"))
    print("\n## Dry-run: single-pod\n")
    print(dryrun_table(cells, "single"))
    print("\n## Dry-run: multi-pod (2x16x16)\n")
    print(dryrun_table(cells, "multi"))


if __name__ == "__main__":
    main()
