"""Benchmark harness — one function per paper claim/figure.

The paper (CS.DC 2015) has no numeric tables; its measurable claims are
benchmarked here:
  fig.1/2  proxy aggregation + load-balanced groups   -> bench_proxy_throughput
  §III-A   greedy batched reads are crucial           -> bench_batching
  §III-A   module compaction reduces downstream load  -> bench_compaction
  §IV-A    flag-offset remap beats unpack/repack      -> bench_remap
  §II      journal append/read/ack costs              -> bench_llog
  §IV-C-2  index-traversal bootstrap scales w/ group  -> bench_bootstrap
  kernels  flash attention vs naive oracle (CPU ref)  -> bench_flash_kernel

Prints ``name,us_per_call,derived`` CSV (stub contract).
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import records as R
from repro.core.llog import Llog
from repro.core.modules import CancelCompensating
from repro.core.proxy import LcapProxy
from repro.core.reader import LocalReader
from repro.track.bootstrap import synthesize_index_stream


def _mk_rec(i, jobid=True):
    return R.ChangelogRecord(
        type=R.CL_CREATE, tfid=R.Fid(1, i, 0), pfid=R.Fid(1, 0, 0),
        name=b"file%06d" % i, jobid=b"job-42" if jobid else None,
        metrics=(1.0, 2.0))


def _timeit(fn, n, *, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best / n * 1e6        # us per item


def bench_remap(n=20000):
    bufs = [R.pack(_mk_rec(i)) for i in range(n)]
    t_strip = _timeit(lambda: [R.remap(b, 0) for b in bufs], n)
    t_add = _timeit(lambda: [R.remap(b, R.CLF_SUPPORTED) for b in bufs], n)
    t_full = _timeit(lambda: [R.pack(R.unpack(b)) for b in bufs], n)
    print(f"remap_strip,{t_strip:.2f},vs_repack_{t_full/t_strip:.1f}x")
    print(f"remap_add,{t_add:.2f},vs_repack_{t_full/t_add:.1f}x")
    print(f"unpack_repack,{t_full:.2f},baseline")


def bench_llog(n=20000, tmp="/tmp/bench_llog"):
    log = Llog("mdt0")
    log.register_reader()
    recs = [_mk_rec(i) for i in range(n)]
    t_append = _timeit(lambda: [log.log(r) for r in recs], n, reps=1)
    t_read = _timeit(lambda: log.read(1, n), n)
    print(f"llog_append_mem,{t_append:.2f},{1e6/t_append:.0f}_rec_per_s")
    print(f"llog_read_batch,{t_read:.3f},{1e6/t_read:.0f}_rec_per_s")
    import glob
    for stale in glob.glob(tmp + "*"):
        os.unlink(stale)
    logd = Llog("mdt1", path=tmp)
    logd.register_reader()
    t_disk = _timeit(lambda: [logd.log(r) for r in recs], n, reps=1)
    print(f"llog_append_disk,{t_disk:.2f},{1e6/t_disk:.0f}_rec_per_s")
    logd.close()


def _fill(logs, n_each):
    for pid, log in logs.items():
        for i in range(n_each):
            log.log(_mk_rec(i))


def bench_proxy_throughput(n=10000):
    """End-to-end proxy cost + load-balance evenness vs group size
    (fig. 2).  NB: this harness is single-core/GIL-bound, so wall-clock
    scaling cannot show here; the scalability evidence is the even
    spread (each member processes ~n/k records), which is what lets k
    processes on k hosts each do 1/k of the work."""
    for n_consumers in (1, 2, 4, 8):
        logs = {f"mdt{i}": Llog(f"mdt{i}") for i in range(4)}
        proxy = LcapProxy(logs)
        readers = [LocalReader(proxy, "g") for _ in range(n_consumers)]
        _fill(logs, n // 4)

        t0 = time.perf_counter()
        proxy.pump()
        done = 0
        while done < n:
            for r in readers:
                for pid, rec in r.fetch(512):
                    r.ack(pid, rec.index)
                    done += 1
        dt = time.perf_counter() - t0
        shares = [proxy.consumers[r.cid].delivered for r in readers]
        spread = min(shares) / max(shares)
        print(f"proxy_group{n_consumers},{dt/n*1e6:.2f},"
              f"spread_min_over_max_{spread:.2f}")


def bench_batching(n=10000):
    """Throughput vs proxy read batch size (§III-A: batching crucial)."""
    for batch in (1, 16, 256, 4096):
        logs = {"mdt0": Llog("mdt0")}
        proxy = LcapProxy(logs, batch_size=batch)
        r = LocalReader(proxy, "g")
        _fill(logs, n)
        t0 = time.perf_counter()
        moved = 0
        while moved < n:
            proxy.pump()
            got = r.fetch(max(batch, 1))
            moved += len(got)
        dt = time.perf_counter() - t0
        print(f"proxy_batch{batch},{dt/n*1e6:.2f},{n/dt:.0f}_rec_per_s")


def bench_compaction(n=10000):
    logs = {"mdt0": Llog("mdt0")}
    proxy = LcapProxy(logs, modules=[CancelCompensating()])
    LocalReader(proxy, "g")
    log = logs["mdt0"]
    for i in range(n // 2):
        log.log(_mk_rec(i))
        log.log(R.ChangelogRecord(type=R.CL_UNLINK, tfid=R.Fid(1, i, 0),
                                  name=b"x"))
    t0 = time.perf_counter()
    proxy.pump()
    dt = time.perf_counter() - t0
    dropped = proxy.stats["dropped_by_modules"]
    print(f"module_compaction,{dt/n*1e6:.2f},dropped_{dropped}_of_{n}")


def bench_bootstrap(n=20000):
    """§IV-C-2: index traversal consumed by a load-balanced group."""
    for workers in (1, 4):
        log = synthesize_index_stream(
            ((i, 1, f"obj{i}", 4096) for i in range(n)))
        proxy = LcapProxy({"index0": log})
        readers = [LocalReader(proxy, "boot") for _ in range(workers)]
        t0 = time.perf_counter()
        proxy.pump()
        done = 0
        while done < n:
            for r in readers:
                batch = r.fetch(1024)
                done += len(batch)
                for pid, rec in batch:
                    r.ack(pid, rec.index)
        dt = time.perf_counter() - t0
        print(f"bootstrap_w{workers},{dt/n*1e6:.2f},{n/dt:.0f}_obj_per_s")


def bench_flash_kernel():
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import flash_attention
    from repro.kernels.ref import attention_reference

    B, S, H, KV, D = 1, 256, 4, 2, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    ref = jax.jit(lambda q, k, v: attention_reference(q, k, v))
    ref(q, k, v).block_until_ready()
    t_ref = _timeit(lambda: ref(q, k, v).block_until_ready(), 1)
    flash_attention(q, k, v, interpret=True)  # warm/correctness
    t_int = _timeit(
        lambda: flash_attention(q, k, v, interpret=True).block_until_ready(),
        1)
    print(f"attention_ref_jit,{t_ref:.0f},B{B}_S{S}_H{H}_D{D}")
    print(f"flash_interpret,{t_int:.0f},python_loopback_not_tpu_perf")


def main() -> None:
    print("name,us_per_call,derived")
    bench_remap()
    bench_llog()
    bench_proxy_throughput()
    bench_batching()
    bench_compaction()
    bench_bootstrap()
    bench_flash_kernel()


if __name__ == "__main__":
    main()
