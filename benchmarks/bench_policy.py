"""Policy engine: mirror bootstrap + sustained action throughput.

Two measurements over the same churn-heavy workload (files created,
attr-spammed, renamed, mostly unlinked, heartbeat chatter):

1. **Mirror bootstrap**: wall time for a fresh ``NamespaceMirror`` to
   reconstruct namespace state via ``Subscription(replay=True)`` from
   (a) a raw retained history (``compactor=None`` — the full-journal
   replay a Robinhood-style engine would otherwise need) and (b) the
   compacted history tier.  Both bootstraps must reproduce the live
   mirror's state exactly before their timings count.
2. **Sustained actions/sec**: churn drives the mirror + a SETATTR-match
   rule; every matched target's action chain runs NEW -> UPDATE ->
   COMPLETED -> PURGED through the proxy (the engine's journal is a
   registered producer), and the reconciler must report zero
   discrepancies at the end.  Reported: action records/sec through the
   full emit -> dispatch -> consume loop.

Run:  PYTHONPATH=src python benchmarks/bench_policy.py
      PYTHONPATH=src python benchmarks/bench_policy.py --smoke

``--smoke`` is the CI mode: a reduced workload that fails (exit 1)
when bootstrap-from-history is less than {SMOKE_MIN_SPEEDUP}x faster
than full-journal replay, or the reconciler finds a discrepancy.
Writes BENCH_policy.json.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import records as R                       # noqa: E402
from repro.core.history import Compactor, HistoryStore    # noqa: E402
from repro.core.llog import Llog                          # noqa: E402
from repro.core.proxy import LcapProxy                    # noqa: E402
from repro.core.session import Subscription, connect      # noqa: E402
from repro.policy import (NamespaceMirror, PolicyEngine,  # noqa: E402
                          PolicyRule, reconcile)

SMOKE_MIN_SPEEDUP = 3.0


def churn(log, start: int, n_files: int, setattrs: int, unlink_pct: int,
          hb_every: int) -> None:
    for i in range(start, start + n_files):
        log.log(R.ChangelogRecord(type=R.CL_CREATE, tfid=R.Fid(1, i, 0),
                                  pfid=R.Fid(1, 0, 0), name=b"f%07d" % i))
        for _ in range(setattrs):
            log.log(R.ChangelogRecord(type=R.CL_SETATTR,
                                      tfid=R.Fid(1, i, 0),
                                      pfid=R.Fid(1, 0, 0),
                                      shard=(0, i % 16, 0, 0),
                                      metrics=(float(i % 7),)))
        log.log(R.ChangelogRecord(type=R.CL_RENAME, tfid=R.Fid(1, i, 0),
                                  pfid=R.Fid(1, 0, 0), name=b"g%07d" % i,
                                  sname=b"f%07d" % i, sfid=R.Fid(1, i, 0)))
        if i % 100 < unlink_pct:
            log.log(R.ChangelogRecord(type=R.CL_UNLINK, tfid=R.Fid(1, i, 0),
                                      pfid=R.Fid(1, 0, 0),
                                      name=b"g%07d" % i))
        if i % hb_every == 0:
            log.log(R.ChangelogRecord(type=R.CL_HEARTBEAT,
                                      tfid=R.Fid(2, i % 16, 0),
                                      metrics=(0.1 * (i % 7),)))


def bootstrap_workload(workdir: str, compact: bool, n_files: int,
                       setattrs: int) -> dict:
    """Churn -> live mirror (journal trims into history) -> fresh
    mirror bootstrap; returns timings."""
    path = os.path.join(workdir, "compacted" if compact else "raw")
    os.makedirs(path)
    store = HistoryStore(os.path.join(path, "j.hist"),
                         compactor=Compactor() if compact else None)
    log = Llog("mdt0", path=os.path.join(path, "j"), segment_records=1024,
               history=store)
    proxy = LcapProxy({"mdt0": log})
    live = NamespaceMirror(proxy, group="live", replay=None)

    t0 = time.perf_counter()
    done = 0
    batch_files = max(1, n_files // 50)
    while done < n_files:
        churn(log, done, min(batch_files, n_files - done), setattrs,
              unlink_pct=80, hb_every=10)
        done += batch_files
        proxy.pump()
        live.poll(1 << 20)
        proxy.flush_upstream()
    ingest_s = time.perf_counter() - t0
    store.compact_now()

    boot = NamespaceMirror(proxy, group="boot", replay=True)
    t0 = time.perf_counter()
    boot.bootstrap(max_records=8192)
    bootstrap_s = time.perf_counter() - t0
    assert boot.snapshot() == live.snapshot(), "bootstrap diverged"
    return {"records_total": log.last_index,
            "history_records": store.record_count,
            "replayed": boot.stream.replayed,
            "entries": len(boot.entries),
            "ingest_s": round(ingest_s, 4),
            "bootstrap_s": round(bootstrap_s, 4)}


def actions_workload(workdir: str, n_files: int, setattrs: int) -> dict:
    """Sustained lifecycle throughput: churn -> rule matches -> full
    NEW/UPDATE/COMPLETED/PURGED chains through the proxy, with an
    action-stream consumer group draining them."""
    path = os.path.join(workdir, "actions")
    os.makedirs(path)
    log = Llog("mdt0", path=os.path.join(path, "j"), segment_records=1024,
               history=True)
    proxy = LcapProxy({"mdt0": log})
    mirror = NamespaceMirror(proxy)
    # match every target whose last writer reported metrics (the churn
    # SETATTRs carry them) — metrics_min requires the field's presence
    engine = PolicyEngine(
        mirror, [PolicyRule("attr", metrics_min=0.0)],
        target=proxy, path=os.path.join(path, "act"))
    agent = connect(proxy).subscribe(Subscription(
        group="agent", types=R.CL_ACTION_TYPES, auto_commit=False))

    consumed = 0
    t0 = time.perf_counter()
    done = 0
    batch_files = max(1, n_files // 50)
    while done < n_files:
        churn(log, done, min(batch_files, n_files - done), setattrs,
              unlink_pct=50, hb_every=10)
        done += batch_files
        proxy.pump()
        mirror.poll(1 << 20)
        engine.evaluate()
        engine.run_pending()
        engine.janitor_sweep()
        proxy.pump()
        for _pid, b in agent.fetch(1 << 20):
            consumed += len(b)
        agent.commit()
        proxy.flush_upstream()
    # drain the tail
    for _ in range(20):
        proxy.pump()
        mirror.poll(1 << 20)
        engine.evaluate()
        engine.run_pending()
        proxy.pump()
        for _pid, b in agent.fetch(1 << 20):
            consumed += len(b)
        agent.commit()
    wall_s = time.perf_counter() - t0
    report = reconcile(engine, proxy)
    return {"action_records": engine.log.last_index,
            "consumed": consumed,
            "chains": engine.stats["emitted"],
            "purged": engine.stats["purged"],
            "wall_s": round(wall_s, 4),
            "actions_per_s": round(engine.log.last_index /
                                   max(wall_s, 1e-9)),
            "reconcile_ok": report.ok,
            "reconcile": str(report)}


def main() -> int:
    ap = argparse.ArgumentParser(
        description="policy engine: mirror bootstrap + action throughput")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small workload, fail below the "
                         f"{SMOKE_MIN_SPEEDUP}x bootstrap-speedup floor")
    ap.add_argument("--files", type=int, default=None)
    ap.add_argument("--setattrs", type=int, default=6)
    args = ap.parse_args()
    n_files = args.files or (1500 if args.smoke else 12000)

    workdir = tempfile.mkdtemp(prefix="bench_policy.")
    try:
        raw = bootstrap_workload(workdir, compact=False, n_files=n_files,
                                 setattrs=args.setattrs)
        compacted = bootstrap_workload(workdir, compact=True,
                                       n_files=n_files,
                                       setattrs=args.setattrs)
        actions = actions_workload(workdir, n_files=max(200, n_files // 4),
                                   setattrs=2)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    speedup = raw["bootstrap_s"] / max(compacted["bootstrap_s"], 1e-9)
    payload = {
        "bench": "policy", "smoke": bool(args.smoke),
        "workload": {"files": n_files, "setattrs_per_file": args.setattrs,
                     "unlink_pct": 80, "heartbeat_every": 10},
        "bootstrap_full_journal": raw,
        "bootstrap_from_history": compacted,
        "bootstrap_speedup": round(speedup, 2),
        "actions": actions,
    }
    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_policy.json")
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(json.dumps(payload, indent=2))
    if speedup < SMOKE_MIN_SPEEDUP:
        print(f"FAIL: bootstrap-from-history {speedup:.2f}x < "
              f"{SMOKE_MIN_SPEEDUP}x full-journal replay", file=sys.stderr)
        return 1
    if not actions["reconcile_ok"]:
        print(f"FAIL: {actions['reconcile']}", file=sys.stderr)
        return 1
    print(f"bootstrap-from-history {speedup:.1f}x faster than full-journal "
          f"replay; {actions['actions_per_s']} action records/s sustained")
    return 0


if __name__ == "__main__":
    sys.exit(main())
