"""Sharded LCAP cluster throughput: aggregate ingest -> dispatch ->
consume -> ack.

Measures the *fleet tracking* workload — heavy records with
jobid/shard/metrics/xattr extensions, a metrics group and a health
group of four load-balanced members each, every member running the
same policy handler (header-column tallies plus a full decode + EWMA
update for step-commit records, the StragglerDetector / MetricsDB
work) — through two deployments of the same record stream:

- **single proxy** — the architecture this PR supersedes: one
  ``LcapProxy`` pumped in-process, every producer funneled through one
  dispatch loop and every consumer drained from the same thread (this
  is exactly how ``bench_proxy.py``, ``repro.track`` and the tests
  drive the system today);
- **sharded cluster, v1 wire** — the coordinator partitions each
  journal batch once by the stable FID-hash slot map (``batch_slots``
  — the same routing ``LcapCluster`` uses), ships each shard its rows
  in legacy payload-only frames, and N single-threaded shard worker
  processes run the identical pipeline on their share:
  ``LcapProxy.offer`` ingest, dispatch, co-located consumers on the
  in-process Session API (the same full-decode ``PolicyTally``),
  collective ack.  The coordinator acknowledges each journal at the
  minimum watermark across shards.
- **sharded cluster, columnar wire** — the same topology on the v2
  frame: header columns ride the wire, ``from_wire`` re-attaches them
  with zero re-gather, and every group member runs ``ColumnarTally``
  — the result-equivalent tally built from the column arrays, with
  zero per-record header decodes on the delivery path.

(The TCP daemon deployment — ``LcapClusterService``, ``RemoteShard``,
the offer_many/watermarks verbs, fan-in sessions — is exercised by
tests/test_cluster.py and tests/test_wire2.py; this benchmark
measures the architecture's aggregate throughput without
thread-scheduling artifacts.)

Aggregate throughput is records/sec from the first routed batch until
every journal is trimmed (the full ingest -> dispatch -> consume ->
commit -> collective-ack cycle).  Topologies: 1/2/4 shards x 4/16
producers.

The v1-wire cluster is the *ablation*: same sharding, same routing,
legacy frames, full-decode consumers.  On a multi-core host it scales
with the shard count; on a single shared core it sits near 1x the
single proxy (same per-record work, plus IPC) — which is exactly the
point of the comparison: the columnar-wire deployment's speedup comes
from the wire format and the columnar delivery path, not from CPU
parallelism, so it holds even when the shards are co-scheduled.

The host this runs on may be small or noisy (CI runners, shared
containers), so the headline 4-shard/single-proxy comparison is run
as *paired attempts* — baseline, v1-wire cluster, and columnar-wire
cluster measured back to back — and retried up to ``--attempts``
times, keeping the best triple; every attempt is recorded in
BENCH_cluster.json under ``cluster`` / ``cluster_columnar`` with
``speedup`` / ``columnar_speedup``.  ``--smoke`` is the CI mode: only
the gated 4-shard cell runs, and the run fails (exit 1) when the best
columnar speedup stays below {COLUMNAR_GATE}x the single proxy.  The
workload is NOT scaled down for smoke: the batch-fixed costs of the
columnar path only amortize at real batch sizes, so a small smoke
would gate on noise.

Run:  PYTHONPATH=src python benchmarks/bench_cluster.py
      PYTHONPATH=src python benchmarks/bench_cluster.py --smoke
"""

from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import os
import sys
import time
from itertools import repeat
from typing import Dict, List

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import records as R                       # noqa: E402
from repro.core.cluster import batch_slots                # noqa: E402
from repro.core.llog import Llog                          # noqa: E402
from repro.core.proxy import LcapProxy                    # noqa: E402
from repro.core.session import Subscription, connect      # noqa: E402

COLUMNAR_GATE = 4.0            # columnar-wire 4-shard vs single proxy
#: (group, members) — the fleet consumer topology
GROUPS = (("metrics", 4), ("health", 4))
BATCH = 16384
N_SLOTS = 64
#: consumers ask for exactly what the producers write (the converged
#: deployment case, as in bench_proxy.py): remap is identity end to end
FLAGS = R.CLF_JOBID | R.CLF_SHARD | R.CLF_METRICS | R.CLF_XATTR


class PolicyTally:
    """The per-member policy handler, shared by both deployments: the
    fleet consumers' real work (MetricsDB row building + the
    StragglerDetector EWMA) — every record is fully decoded, turned
    into an events row, tallied per type and per target, and
    step-commit durations feed a per-host EWMA."""

    __slots__ = ("by_type", "latest", "ewma", "rows", "handled")

    def __init__(self):
        self.by_type: Dict[int, int] = {}
        self.latest: Dict[tuple, int] = {}
        self.ewma: Dict[int, float] = {}
        self.rows: List[tuple] = []
        self.handled = 0

    def handle(self, pid: str, batch: R.RecordBatch) -> None:
        by_type, latest, ewma = self.by_type, self.latest, self.ewma
        rows = []
        for i in range(len(batch)):
            rec = batch.record(i)              # full decode: the DB row
            rtype = rec.type                   # needs every field
            by_type[rtype] = by_type.get(rtype, 0) + 1
            tfid = rec.tfid
            latest[(pid, tfid.seq, tfid.oid, tfid.ver)] = rec.index
            m = rec.metrics or ()
            rows.append((pid, rec.index, rtype, rec.time, tfid.seq,
                         tfid.oid, tfid.ver,
                         rec.name.decode(errors="replace"),
                         (rec.jobid or b"").decode(errors="replace"),
                         m[0] if m else None))
            if rtype == R.CL_STEP_COMMIT:
                dt = m[-2] if len(m) >= 2 else 0.0
                prev = ewma.get(tfid.oid)
                ewma[tfid.oid] = dt if prev is None \
                    else 0.3 * dt + 0.7 * prev
        self.rows = rows                       # one "transaction" batch
        self.handled += len(batch)


class ColumnarTally:
    """``PolicyTally``'s columnar twin: the same by_type / latest /
    rows / EWMA results built from the batch's header columns (carried
    over the v2 wire) and the vectorized payload gathers — zero
    per-record header decodes on the delivery path."""

    __slots__ = ("by_type", "latest", "ewma", "rows", "handled")

    def __init__(self):
        self.by_type: Dict[int, int] = {}
        self.latest: Dict[tuple, int] = {}
        self.ewma: Dict[int, float] = {}
        self.rows: List[tuple] = []
        self.handled = 0

    def handle(self, pid: str, batch: R.RecordBatch) -> None:
        h = batch.header()                 # attached by from_wire (v2)
        types = h["type"]
        bc = np.bincount(types)
        for t in np.flatnonzero(bc).tolist():
            self.by_type[t] = self.by_type.get(t, 0) + int(bc[t])
        idx = h["index"].tolist()
        seq = h["tseq"].tolist()
        oid = h["toid"].tolist()
        ver = h["tver"].tolist()
        # later batch rows win, matching the scalar loop's overwrite
        self.latest.update(zip(zip(repeat(pid), seq, oid, ver), idx))
        names = batch.name_col_str()
        # jobids are low-cardinality (one per job): decode each
        # distinct 32-byte cell once, then fan out by inverse index —
        # and a whole batch from one job is a single compare + decode
        jm = batch.jobid_col()
        cells = jm.view(f"V{jm.shape[1]}").ravel()
        if cells.size and (cells == cells[0]).all():
            jobs = [bytes(cells[0]).rstrip(b"\0")
                    .decode(errors="replace")] * len(cells)
        else:
            uniq, inv = np.unique(cells, return_inverse=True)
            dec = [bytes(u).rstrip(b"\0").decode(errors="replace")
                   for u in uniq.tolist()]
            jobs = [dec[i] for i in inv.tolist()]
        mat, cnt = batch.metrics_cols(3)
        m0 = mat[:, 0].tolist()
        for i in np.flatnonzero(cnt == 0).tolist():
            m0[i] = None
        self.rows = list(zip(repeat(pid), idx, types.tolist(),
                             h["time"].tolist(), seq, oid, ver,
                             names, jobs, m0))
        # EWMA, segment-vectorized: group the batch's step commits by
        # host, fold each host's dt sequence into one closed-form
        # update (0.7^k carries the prior state, the weighted tail sum
        # adds the new samples) — one dict touch per distinct host
        # instead of one per record.  Numerically equivalent to the
        # scalar recurrence (FP association differs in the last ulp).
        ewma = self.ewma
        sc = np.flatnonzero(types == R.CL_STEP_COMMIT)
        if sc.size:
            c = cnt[sc]
            dts = np.where(
                c >= 2, mat[sc, np.maximum(c - 2, 0)], 0.0)
            oids = h["toid"][sc].astype(np.int64)
            order = np.argsort(oids, kind="stable")
            so, sd = oids[order], dts[order]
            edge = np.empty(so.size, dtype=bool)
            edge[0] = True
            np.not_equal(so[1:], so[:-1], out=edge[1:])
            starts = np.flatnonzero(edge)
            ends = np.empty(starts.size, dtype=np.int64)
            ends[:-1] = starts[1:]
            ends[-1] = so.size
            seg = ends - starts
            j = np.repeat(ends, seg) - 1 \
                - np.arange(so.size)         # position from segment end
            tail = np.add.reduceat(0.3 * sd * 0.7 ** j, starts)
            decay = 0.7 ** seg.astype(np.float64)
            first = sd[starts]
            for o, d0, dk, tl in zip(so[starts].tolist(), first.tolist(),
                                     decay.tolist(), tail.tolist()):
                prev = ewma.get(o)
                # no prior state: the first sample seeds it (decaying
                # like the prior), which folds to decay*d1 + tail
                ewma[o] = dk * (d0 if prev is None else prev) + tl
        self.handled += len(idx)


def make_logs(n_producers: int) -> Dict[str, Llog]:
    return {f"host{p}": Llog(f"host{p}") for p in range(n_producers)}


def fill_logs(logs: Dict[str, Llog], total: int) -> int:
    """Pre-fill the journals (logging must already be armed by a
    registered reader); returns the records logged."""
    per = total // len(logs)
    for p, log in enumerate(logs.values()):
        for i in range(per):
            log.log(R.ChangelogRecord(
                type=R.CL_STEP_COMMIT if i % 3 else R.CL_HEARTBEAT,
                tfid=R.Fid(1, i % 257, i % 13), pfid=R.Fid(1, 0, 0),
                name=b"step%06d" % i, jobid=b"fleet-run",
                shard=(0, p, 0, 0), metrics=(0.5, 1.25, 4096.0),
                xattr={"n": i % 7}))
    assert all(log.last_index == per for log in logs.values())
    return per * len(logs)


def trimmed(logs: Dict[str, Llog]) -> bool:
    return all(log.first_index == log.last_index + 1
               for log in logs.values())


def _open_streams(proxy, tally_cls=PolicyTally):
    """The identical consumer set for every deployment: one stream and
    one policy handler per group member, on the in-process Session."""
    session = connect(proxy)
    return [(session.subscribe(Subscription(
        group=g, flags=FLAGS, auto_commit=False, max_records=BATCH)),
        tally_cls())
        for g, members in GROUPS for _ in range(members)]


def _consume_round(streams) -> int:
    moved = 0
    for stream, tally in streams:
        for pid, batch in stream.fetch():
            tally.handle(pid, batch)
            moved += len(batch)
        stream.commit()
    return moved


# ----------------------------------------------------------- single proxy
def run_single_proxy(n_producers: int, total: int) -> dict:
    logs = make_logs(n_producers)
    proxy = LcapProxy(logs, batch_size=BATCH)
    streams = _open_streams(proxy)
    total = fill_logs(logs, total)
    t0 = time.perf_counter()
    while not trimmed(logs):
        proxy.pump()
        if not _consume_round(streams):
            proxy.flush_upstream()
    elapsed = time.perf_counter() - t0
    handled = sum(t.handled for _, t in streams)
    assert handled == total * len(GROUPS), (handled, total)
    return {"records": total, "seconds": round(elapsed, 4),
            "records_per_sec": round(total / elapsed, 1)}


# ---------------------------------------------------------------- cluster
def _shard_worker(index: int, sources: List[str], in_q, out_q) -> None:
    """One shard as a single-threaded closed loop: take this shard's
    rows off the queue, push them through ``LcapProxy.offer`` and the
    dispatch loop, and drain them through the same co-located consumer
    set the baseline runs.  Reports per-journal upstream watermarks
    when fully drained; ``reset`` re-arms it for the next attempt."""
    from queue import Empty
    out_q.put(("up", index))               # import/bootstrap finished —
    proxy = streams = None                 # measurements may begin
    drained = 0
    eof = False
    idle = True
    columnar = False
    while True:
        try:
            # an idle shard must not steal CPU from the coordinator's
            # framing (or from a paired baseline measurement on a
            # shared core): block on the queue instead of busy-polling
            msg = in_q.get(timeout=0.1) if idle else in_q.get_nowait()
        except Empty:
            msg = None
        if msg is not None:
            op = msg[0]
            idle = False
            if op == "batch":
                # one coalesced message per shard: the coordinator
                # already selected this shard's rows per producer
                # batch; v2 frames arrive with header columns attached
                for pid, blob, hi in msg[1]:
                    frame = R.RecordBatch.from_wire(blob)
                    if columnar:
                        # walk the extension layout once per frame:
                        # the member sub-batches dispatch carves off
                        # it inherit the subset instead of re-walking
                        frame._layout()
                    proxy.offer(pid, frame, hi)
            elif op == "reset":
                columnar = msg[1]
                # no dispatch quantum: a shard worker is a throughput
                # deployment — whole offered batches go down the
                # columnar fast-dispatch path in one pump
                proxy = LcapProxy({}, batch_size=BATCH)
                for pid in sources:
                    proxy.add_source(pid, 1)
                streams = _open_streams(
                    proxy, ColumnarTally if columnar else PolicyTally)
                drained = 0
                eof = False
                out_q.put(("ready", index))
            elif op == "eof":
                eof = True
            elif op == "exit":
                return
            continue                       # keep the queue drained
        if proxy is None:
            idle = True
            continue
        moved = proxy.pump()
        moved += _consume_round(streams)
        drained += moved
        if eof and not moved and not proxy._buffered:
            proxy.flush_upstream()
            out_q.put(("done", index, dict(proxy.upstream_acked), drained))
            eof = False                    # wait for reset / exit
            idle = True
        elif not moved:
            idle = True                    # nothing to do until more input


class ClusterHarness:
    """N persistent shard worker processes plus the coordinator-side
    routing; one instance serves every attempt of a topology cell."""

    def __init__(self, n_shards: int, sources: List[str]):
        ctx = mp.get_context("spawn")
        self.n_shards = n_shards
        self.slot_owner = [i % n_shards for i in range(N_SLOTS)]
        self.in_qs = [ctx.Queue() for _ in range(n_shards)]
        self.out_q = ctx.Queue()
        self.workers = [
            ctx.Process(target=_shard_worker,
                        args=(i, sources, self.in_qs[i], self.out_q),
                        daemon=True)
            for i in range(n_shards)]
        for proc in self.workers:
            proc.start()
        for _ in self.workers:            # wait out the spawn imports:
            assert self.out_q.get(timeout=60)[0] == "up"   # they must
        # not steal CPU from a paired baseline measurement

    def reset(self, columnar: bool = False) -> None:
        for q in self.in_qs:
            q.put(("reset", columnar))
        for _ in self.workers:
            assert self.out_q.get(timeout=60)[0] == "ready"

    def run(self, logs: Dict[str, Llog], rids: Dict[str, str],
            total: int, timeout: float = 120.0,
            wire: int = R.WIRE_V1) -> dict:
        t0 = time.perf_counter()
        owner = np.asarray(self.slot_owner)
        shipments: List[List[tuple]] = [[] for _ in range(self.n_shards)]
        for pid, log in logs.items():
            cursor = log.first_index
            while True:
                batch = log.read(cursor, BATCH)
                if not batch:
                    break
                hi = batch.packed_index(len(batch) - 1)
                cursor = hi + 1
                # freeze once: the per-shard selects and frames below
                # then share a single zero-copy buffer snapshot
                batch = batch.freeze()
                # partition once by the stable FID-hash slot map —
                # exactly LcapCluster's routing, vectorized over the
                # header columns — and frame each shard its selected
                # sub-batch.  ``wire`` selects the frame generation:
                # v2 carries the header columns so shard workers never
                # re-gather them.
                owners = owner[batch_slots(batch, N_SLOTS)]
                for s in range(self.n_shards):
                    sub = batch.select(np.flatnonzero(owners == s))
                    shipments[s].append((pid, sub.to_wire(wire), hi))
                if len(batch) < BATCH:
                    break
        # one coalesced put per shard (deep batching at the queue
        # layer too), then eof
        for s, q in enumerate(self.in_qs):
            q.put(("batch", shipments[s]))
            q.put(("eof",))
        watermarks: List[Dict[str, int]] = []
        delivered = 0
        deadline = t0 + timeout
        for _ in self.workers:
            msg = self.out_q.get(
                timeout=max(1.0, deadline - time.perf_counter()))
            assert msg[0] == "done"
            watermarks.append(msg[2])
            delivered += msg[3]
        # collective upstream ack: min watermark across shards
        for pid, log in logs.items():
            log.ack(rids[pid], min(wm.get(pid, 0) for wm in watermarks))
        elapsed = time.perf_counter() - t0
        assert trimmed(logs), "collective ack did not trim every journal"
        assert delivered >= total * len(GROUPS), (delivered, total)
        return {"records": total, "seconds": round(elapsed, 4),
                "records_per_sec": round(total / elapsed, 1),
                "delivered": delivered}

    def close(self) -> None:
        for q in self.in_qs:
            try:
                q.put(("exit",))
            except (OSError, ValueError):
                pass
        for proc in self.workers:
            proc.join(timeout=5)
            if proc.is_alive():
                proc.terminate()


def run_cluster(harness: ClusterHarness, n_producers: int,
                total: int, columnar: bool = False) -> dict:
    harness.reset(columnar)
    logs = make_logs(n_producers)
    rids = {pid: log.register_reader(f"lcap-{pid}")
            for pid, log in logs.items()}
    total = fill_logs(logs, total)
    return harness.run(logs, rids, total,
                       wire=R.WIRE_V2 if columnar else R.WIRE_V1)


# ------------------------------------------------------------------ driver
def paired_attempts(n_shards: int, n_producers: int, total: int,
                    attempts: int, early_stop: float) -> dict:
    """Measure baseline, v1-wire cluster, and columnar-wire cluster
    back to back, up to ``attempts`` times (shared hosts have bursty
    CPU supply); keep the best triple by columnar speedup."""
    harness = ClusterHarness(n_shards,
                             sources=list(make_logs(n_producers)))
    try:
        runs = []
        best = None
        for k in range(attempts):
            base = run_single_proxy(n_producers, total)
            clus = run_cluster(harness, n_producers, total)
            col = run_cluster(harness, n_producers, total, columnar=True)
            speedup = round(
                clus["records_per_sec"] / base["records_per_sec"], 2)
            col_speedup = round(
                col["records_per_sec"] / base["records_per_sec"], 2)
            runs.append({"attempt": k, "single_proxy": base,
                         "cluster": clus, "cluster_columnar": col,
                         "speedup": speedup,
                         "columnar_speedup": col_speedup})
            print(f"  shards={n_shards} producers={n_producers:2d} "
                  f"attempt={k}: "
                  f"single={base['records_per_sec']:>9,.0f} rec/s  "
                  f"cluster={clus['records_per_sec']:>9,.0f} rec/s "
                  f"({speedup:.2f}x)  "
                  f"columnar={col['records_per_sec']:>9,.0f} rec/s "
                  f"({col_speedup:.2f}x)")
            if best is None or col_speedup > best["columnar_speedup"]:
                best = runs[-1]
            if col_speedup >= early_stop:
                break
        return {"best": best, "attempts": runs}
    finally:
        harness.close()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.format(
        COLUMNAR_GATE=COLUMNAR_GATE))
    ap.add_argument("--records", type=int, default=192_000)
    ap.add_argument("--shards", type=int, nargs="+", default=None)
    ap.add_argument("--producers", type=int, nargs="+", default=None)
    ap.add_argument("--attempts", type=int, default=8,
                    help="paired retries for the gated 4-shard cell "
                         "(noisy-host mitigation; every attempt recorded)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: gated 4-shard cell only; exit 1 if "
                         "the best columnar speedup is < "
                         f"{COLUMNAR_GATE}x the single proxy")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_cluster.json"))
    args = ap.parse_args()
    if args.smoke:
        # full-size workload on one cell: the columnar path's
        # batch-fixed costs only amortize at real batch sizes
        shard_counts = args.shards or [4]
        producer_counts = args.producers or [16]
    else:
        shard_counts = args.shards or [1, 2, 4]
        producer_counts = args.producers or [4, 16]

    results = {}
    gate_speedup = 0.0
    gate_col_speedup = 0.0
    gate_best = None
    for n_producers in producer_counts:
        for n_shards in shard_counts:
            gated = n_shards == max(shard_counts)
            cell = paired_attempts(
                n_shards, n_producers, args.records,
                attempts=args.attempts if gated else 1,
                early_stop=COLUMNAR_GATE + 0.5 if gated else float("inf"))
            results[f"{n_shards}x{n_producers}"] = cell
            if gated:
                gate_speedup = max(gate_speedup, cell["best"]["speedup"])
                if cell["best"]["columnar_speedup"] > gate_col_speedup:
                    gate_col_speedup = cell["best"]["columnar_speedup"]
                    gate_best = cell["best"]

    payload = {
        "benchmark": "sharded LCAP cluster ingest->dispatch->consume->ack",
        "unit": "records/sec",
        "workload": {"records": args.records, "groups": list(GROUPS),
                     "record_flags": "JOBID|SHARD|METRICS|XATTR",
                     "consumer": "policy tally per member: full-decode "
                                 "PolicyTally on the v1 wire, "
                                 "ColumnarTally (header columns, zero "
                                 "per-record decodes) on the v2 wire"},
        "topologies": results,
        "cluster_columnar": gate_best["cluster_columnar"]
        if gate_best else None,
        "columnar_speedup": gate_col_speedup,
        "gate": {"required_columnar_speedup": COLUMNAR_GATE,
                 "shards": max(shard_counts),
                 "best_speedup": gate_speedup,
                 "best_columnar_speedup": gate_col_speedup},
    }
    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"wrote {os.path.abspath(args.out)}; "
          f"best {max(shard_counts)}-shard speedup {gate_speedup:.2f}x, "
          f"columnar {gate_col_speedup:.2f}x")
    if args.smoke and gate_col_speedup < COLUMNAR_GATE:
        print(f"SMOKE FAIL: best 4-shard columnar speedup "
              f"{gate_col_speedup:.2f}x < {COLUMNAR_GATE}x single proxy")
        sys.exit(1)


if __name__ == "__main__":
    main()
