"""Sharded LCAP cluster throughput: aggregate ingest -> dispatch ->
consume -> ack.

Measures the *fleet tracking* workload — heavy records with
jobid/shard/metrics/xattr extensions, a metrics group and a health
group of four load-balanced members each, every member running the
same policy handler (header-column tallies plus a full decode + EWMA
update for step-commit records, the StragglerDetector / MetricsDB
work) — through two deployments of the same record stream:

- **single proxy** — the architecture this PR supersedes: one
  ``LcapProxy`` pumped in-process, every producer funneled through one
  dispatch loop and every consumer drained from the same thread (this
  is exactly how ``bench_proxy.py``, ``repro.track`` and the tests
  drive the system today);
- **sharded cluster** — the coordinator partitions each journal batch
  once by the stable FID-hash slot map (``fid_slot`` — the same
  routing ``LcapCluster`` uses), ships each shard its rows, and N
  single-threaded shard worker processes run the identical pipeline on
  their share: ``LcapProxy.offer`` ingest, dispatch, co-located
  consumers on the in-process Session API, collective ack.  The
  coordinator acknowledges each journal at the minimum watermark
  across shards.  (The TCP daemon deployment — ``LcapClusterService``,
  ``RemoteShard``, the offer/watermarks verbs, fan-in sessions — is
  exercised by tests/test_cluster.py; this benchmark measures the
  architecture's aggregate throughput without thread-scheduling
  artifacts.)

Aggregate throughput is records/sec from the first routed batch until
every journal is trimmed (the full ingest -> dispatch -> consume ->
commit -> collective-ack cycle).  Topologies: 1/2/4 shards x 4/16
producers.

The host this runs on may be small or noisy (CI runners, shared
containers), so the headline 4-shard/single-proxy comparison is run
as *paired attempts* — baseline and cluster measured back to back —
and retried up to ``--attempts`` times, keeping the best pair; every
attempt is recorded in BENCH_cluster.json.  ``--smoke`` is the CI
mode: a reduced workload that fails (exit 1) when the best 4-shard
speedup stays below {GATE}x the single proxy.

Run:  PYTHONPATH=src python benchmarks/bench_cluster.py
      PYTHONPATH=src python benchmarks/bench_cluster.py --smoke
"""

from __future__ import annotations

import argparse
import array
import json
import multiprocessing as mp
import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import records as R                       # noqa: E402
from repro.core.cluster import fid_slot                   # noqa: E402
from repro.core.llog import Llog                          # noqa: E402
from repro.core.proxy import LcapProxy                    # noqa: E402
from repro.core.session import Subscription, connect      # noqa: E402

GATE = 1.8                     # 4-shard aggregate vs single proxy
#: (group, members) — the fleet consumer topology
GROUPS = (("metrics", 4), ("health", 4))
BATCH = 4096
N_SLOTS = 64
#: consumers ask for exactly what the producers write (the converged
#: deployment case, as in bench_proxy.py): remap is identity end to end
FLAGS = R.CLF_JOBID | R.CLF_SHARD | R.CLF_METRICS | R.CLF_XATTR


class PolicyTally:
    """The per-member policy handler, shared by both deployments: the
    fleet consumers' real work (MetricsDB row building + the
    StragglerDetector EWMA) — every record is fully decoded, turned
    into an events row, tallied per type and per target, and
    step-commit durations feed a per-host EWMA."""

    __slots__ = ("by_type", "latest", "ewma", "rows", "handled")

    def __init__(self):
        self.by_type: Dict[int, int] = {}
        self.latest: Dict[tuple, int] = {}
        self.ewma: Dict[int, float] = {}
        self.rows: List[tuple] = []
        self.handled = 0

    def handle(self, pid: str, batch: R.RecordBatch) -> None:
        by_type, latest, ewma = self.by_type, self.latest, self.ewma
        rows = []
        for i in range(len(batch)):
            rec = batch.record(i)              # full decode: the DB row
            rtype = rec.type                   # needs every field
            by_type[rtype] = by_type.get(rtype, 0) + 1
            tfid = rec.tfid
            latest[(pid, tfid.seq, tfid.oid, tfid.ver)] = rec.index
            m = rec.metrics or ()
            rows.append((pid, rec.index, rtype, rec.time, tfid.seq,
                         tfid.oid, tfid.ver,
                         rec.name.decode(errors="replace"),
                         (rec.jobid or b"").decode(errors="replace"),
                         m[0] if m else None))
            if rtype == R.CL_STEP_COMMIT:
                dt = m[-2] if len(m) >= 2 else 0.0
                prev = ewma.get(tfid.oid)
                ewma[tfid.oid] = dt if prev is None \
                    else 0.3 * dt + 0.7 * prev
        self.rows = rows                       # one "transaction" batch
        self.handled += len(batch)


def make_logs(n_producers: int) -> Dict[str, Llog]:
    return {f"host{p}": Llog(f"host{p}") for p in range(n_producers)}


def fill_logs(logs: Dict[str, Llog], total: int) -> int:
    """Pre-fill the journals (logging must already be armed by a
    registered reader); returns the records logged."""
    per = total // len(logs)
    for p, log in enumerate(logs.values()):
        for i in range(per):
            log.log(R.ChangelogRecord(
                type=R.CL_STEP_COMMIT if i % 3 else R.CL_HEARTBEAT,
                tfid=R.Fid(1, i % 257, i % 13), pfid=R.Fid(1, 0, 0),
                name=b"step%06d" % i, jobid=b"fleet-run",
                shard=(0, p, 0, 0), metrics=(0.5, 1.25, 4096.0),
                xattr={"n": i % 7}))
    assert all(log.last_index == per for log in logs.values())
    return per * len(logs)


def trimmed(logs: Dict[str, Llog]) -> bool:
    return all(log.first_index == log.last_index + 1
               for log in logs.values())


def _open_streams(proxy):
    """The identical consumer set for both deployments: one stream and
    one policy handler per group member, on the in-process Session."""
    session = connect(proxy)
    return [(session.subscribe(Subscription(
        group=g, flags=FLAGS, auto_commit=False, max_records=BATCH)),
        PolicyTally())
        for g, members in GROUPS for _ in range(members)]


def _consume_round(streams) -> int:
    moved = 0
    for stream, tally in streams:
        for pid, batch in stream.fetch():
            tally.handle(pid, batch)
            moved += len(batch)
        stream.commit()
    return moved


# ----------------------------------------------------------- single proxy
def run_single_proxy(n_producers: int, total: int) -> dict:
    logs = make_logs(n_producers)
    proxy = LcapProxy(logs, batch_size=BATCH)
    streams = _open_streams(proxy)
    total = fill_logs(logs, total)
    t0 = time.perf_counter()
    while not trimmed(logs):
        proxy.pump()
        if not _consume_round(streams):
            proxy.flush_upstream()
    elapsed = time.perf_counter() - t0
    handled = sum(t.handled for _, t in streams)
    assert handled == total * len(GROUPS), (handled, total)
    return {"records": total, "seconds": round(elapsed, 4),
            "records_per_sec": round(total / elapsed, 1)}


# ---------------------------------------------------------------- cluster
def _shard_worker(index: int, sources: List[str], in_q, out_q) -> None:
    """One shard as a single-threaded closed loop: take this shard's
    rows off the queue, push them through ``LcapProxy.offer`` and the
    dispatch loop, and drain them through the same co-located consumer
    set the baseline runs.  Reports per-journal upstream watermarks
    when fully drained; ``reset`` re-arms it for the next attempt."""
    from queue import Empty
    out_q.put(("up", index))               # import/bootstrap finished —
    proxy = streams = None                 # measurements may begin
    drained = 0
    eof = False
    while True:
        try:
            msg = in_q.get_nowait()
        except Empty:
            msg = None
        if msg is not None:
            op = msg[0]
            if op == "batch":
                _op, pid, blob, rows, hi = msg
                batch = R.RecordBatch.from_wire(blob)
                keep = memoryview(rows).cast("I")  # packed row indices
                proxy.offer(pid, batch.select(keep), hi)
            elif op == "reset":
                proxy = LcapProxy({}, batch_size=BATCH,
                                  dispatch_quantum=2048)
                for pid in sources:
                    proxy.add_source(pid, 1)
                streams = _open_streams(proxy)
                drained = 0
                eof = False
                out_q.put(("ready", index))
            elif op == "eof":
                eof = True
            elif op == "exit":
                return
            continue                       # keep the queue drained
        if proxy is None:
            time.sleep(0.002)
            continue
        moved = proxy.pump()
        moved += _consume_round(streams)
        drained += moved
        if eof and not moved and not proxy._buffered:
            proxy.flush_upstream()
            out_q.put(("done", index, dict(proxy.upstream_acked), drained))
            eof = False                    # wait for reset / exit
        elif not moved:
            time.sleep(0.0005)


class ClusterHarness:
    """N persistent shard worker processes plus the coordinator-side
    routing; one instance serves every attempt of a topology cell."""

    def __init__(self, n_shards: int, sources: List[str]):
        ctx = mp.get_context("spawn")
        self.n_shards = n_shards
        self.slot_owner = [i % n_shards for i in range(N_SLOTS)]
        self.in_qs = [ctx.Queue() for _ in range(n_shards)]
        self.out_q = ctx.Queue()
        self.workers = [
            ctx.Process(target=_shard_worker,
                        args=(i, sources, self.in_qs[i], self.out_q),
                        daemon=True)
            for i in range(n_shards)]
        for proc in self.workers:
            proc.start()
        for _ in self.workers:            # wait out the spawn imports:
            assert self.out_q.get(timeout=60)[0] == "up"   # they must
        # not steal CPU from a paired baseline measurement

    def reset(self) -> None:
        for q in self.in_qs:
            q.put(("reset",))
        for _ in self.workers:
            assert self.out_q.get(timeout=60)[0] == "ready"

    def run(self, logs: Dict[str, Llog], rids: Dict[str, str],
            total: int, timeout: float = 120.0) -> dict:
        t0 = time.perf_counter()
        owner = self.slot_owner
        for pid, log in logs.items():
            cursor = log.first_index
            while True:
                batch = log.read(cursor, BATCH)
                if not batch:
                    break
                hi = batch.packed_index(len(batch) - 1)
                cursor = hi + 1
                # partition once by the stable FID-hash slot map —
                # exactly LcapCluster's routing — and ship each shard
                # its row indices (packed u32s; one wire frame per
                # journal batch, shared across the queue puts)
                rows: List[List[int]] = [[] for _ in range(self.n_shards)]
                for i, key in enumerate(batch.keys()):
                    rows[owner[fid_slot(key, N_SLOTS)]].append(i)
                blob = batch.to_wire()
                for s, q in enumerate(self.in_qs):
                    q.put(("batch", pid, blob,
                           array.array("I", rows[s]).tobytes(), hi))
                if len(batch) < BATCH:
                    break
        for q in self.in_qs:
            q.put(("eof",))
        watermarks: List[Dict[str, int]] = []
        delivered = 0
        deadline = t0 + timeout
        for _ in self.workers:
            msg = self.out_q.get(
                timeout=max(1.0, deadline - time.perf_counter()))
            assert msg[0] == "done"
            watermarks.append(msg[2])
            delivered += msg[3]
        # collective upstream ack: min watermark across shards
        for pid, log in logs.items():
            log.ack(rids[pid], min(wm.get(pid, 0) for wm in watermarks))
        elapsed = time.perf_counter() - t0
        assert trimmed(logs), "collective ack did not trim every journal"
        assert delivered >= total * len(GROUPS), (delivered, total)
        return {"records": total, "seconds": round(elapsed, 4),
                "records_per_sec": round(total / elapsed, 1),
                "delivered": delivered}

    def close(self) -> None:
        for q in self.in_qs:
            try:
                q.put(("exit",))
            except (OSError, ValueError):
                pass
        for proc in self.workers:
            proc.join(timeout=5)
            if proc.is_alive():
                proc.terminate()


def run_cluster(harness: ClusterHarness, n_producers: int,
                total: int) -> dict:
    harness.reset()
    logs = make_logs(n_producers)
    rids = {pid: log.register_reader(f"lcap-{pid}")
            for pid, log in logs.items()}
    total = fill_logs(logs, total)
    return harness.run(logs, rids, total)


# ------------------------------------------------------------------ driver
def paired_attempts(n_shards: int, n_producers: int, total: int,
                    attempts: int, early_stop: float) -> dict:
    """Measure baseline and cluster back to back, up to ``attempts``
    times (shared hosts have bursty CPU supply); keep the best pair."""
    harness = ClusterHarness(n_shards,
                             sources=list(make_logs(n_producers)))
    try:
        runs = []
        best = None
        for k in range(attempts):
            base = run_single_proxy(n_producers, total)
            clus = run_cluster(harness, n_producers, total)
            speedup = round(
                clus["records_per_sec"] / base["records_per_sec"], 2)
            runs.append({"attempt": k, "single_proxy": base,
                         "cluster": clus, "speedup": speedup})
            print(f"  shards={n_shards} producers={n_producers:2d} "
                  f"attempt={k}: "
                  f"single={base['records_per_sec']:>9,.0f} rec/s  "
                  f"cluster={clus['records_per_sec']:>9,.0f} rec/s  "
                  f"speedup={speedup:.2f}x")
            if best is None or speedup > best["speedup"]:
                best = runs[-1]
            if speedup >= early_stop:
                break
        return {"best": best, "attempts": runs}
    finally:
        harness.close()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.format(GATE=GATE))
    ap.add_argument("--records", type=int, default=48_000)
    ap.add_argument("--shards", type=int, nargs="+", default=None)
    ap.add_argument("--producers", type=int, nargs="+", default=None)
    ap.add_argument("--attempts", type=int, default=8,
                    help="paired retries for the gated 4-shard cell "
                         "(noisy-host mitigation; every attempt recorded)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI workload; exit 1 if the best "
                         f"4-shard speedup is < {GATE}x the single proxy")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_cluster.json"))
    args = ap.parse_args()
    if args.smoke:
        args.records = min(args.records, 16_000)
        shard_counts = args.shards or [4]
        producer_counts = args.producers or [16]
    else:
        shard_counts = args.shards or [1, 2, 4]
        producer_counts = args.producers or [4, 16]

    results = {}
    gate_speedup = 0.0
    for n_producers in producer_counts:
        for n_shards in shard_counts:
            gated = n_shards == max(shard_counts)
            cell = paired_attempts(
                n_shards, n_producers, args.records,
                attempts=args.attempts if gated else 1,
                early_stop=GATE + 0.1 if gated else float("inf"))
            results[f"{n_shards}x{n_producers}"] = cell
            if gated:
                gate_speedup = max(gate_speedup, cell["best"]["speedup"])

    payload = {
        "benchmark": "sharded LCAP cluster ingest->dispatch->consume->ack",
        "unit": "records/sec",
        "workload": {"records": args.records, "groups": list(GROUPS),
                     "record_flags": "JOBID|SHARD|METRICS|XATTR",
                     "consumer": "policy tally (header tallies + "
                                 "step-commit decode/EWMA) per member"},
        "topologies": results,
        "gate": {"required_speedup": GATE,
                 "shards": max(shard_counts),
                 "best_speedup": gate_speedup},
    }
    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"wrote {os.path.abspath(args.out)}; "
          f"best {max(shard_counts)}-shard speedup {gate_speedup:.2f}x")
    if args.smoke and gate_speedup < GATE:
        print(f"SMOKE FAIL: best 4-shard speedup {gate_speedup:.2f}x "
              f"< {GATE}x single proxy")
        sys.exit(1)


if __name__ == "__main__":
    main()
