"""End-to-end LCAP proxy throughput: ingest -> dispatch -> ack.

Measures records/sec through the batch-native pipeline, driven through
the Session API (connect / subscribe / fetch / commit — the consumer
surface every real client uses), against a faithful re-implementation
of the seed's per-record path (unpack every record at ingest, repack it
into the buffer, unpack again at dispatch to read one u64, remap per
consumer, decode at the reader, ack record by record) — the
architecture this refactor replaced.

Run:  PYTHONPATH=src python benchmarks/bench_proxy.py
      PYTHONPATH=src python benchmarks/bench_proxy.py --smoke

A third pipeline (``columnar``) drives the proxy API directly with a
full-drain consumer, keeping every pump batch-shaped end to end — the
columnar dispatch fast path (whole-batch deliver/stamp, chunked outbox,
bulk commit/ack over header columns).

``--smoke`` is the CI mode: a reduced workload that fails (exit 1) when
the Session-API hot path drops below {SMOKE_MIN_SPEEDUP}x the seed
per-record path, or the columnar path below {COLUMNAR_MIN_SPEEDUP}x the
seed path (3x the pre-columnar batch path), so hot-path regressions
fail the build, not just tier-1 tests.  Writes BENCH_proxy.json
(consumed by CI as an artifact).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from collections import deque
from typing import Dict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import records as R                       # noqa: E402
from repro.core.ack import AckTracker                     # noqa: E402
from repro.core.llog import Llog                          # noqa: E402
from repro.core.proxy import LcapProxy                    # noqa: E402
from repro.core.session import Subscription, connect      # noqa: E402

SMOKE_MIN_SPEEDUP = 3.0
#: the columnar dispatch fast path must stay >= this multiple of the
#: seed per-record path (CI gate for the vectorized kernels).  The
#: pre-columnar batch path ran ~6x the seed path on the same machine,
#: so 18x seed == 3x that baseline — measured against the seed run of
#: the same invocation, which normalizes runner speed out of the gate.
COLUMNAR_MIN_SPEEDUP = 18.0

# Consumers ask for exactly what the producers write: the common case a
# deployment converges to, and the one the proxy's remap fast path serves.
FLAGS = R.CLF_JOBID | R.CLF_SHARD


def fill_logs(n_producers: int, total_records: int) -> Dict[str, Llog]:
    per = total_records // n_producers
    logs = {}
    for p in range(n_producers):
        log = Llog(f"mdt{p}")
        logs[f"mdt{p}"] = log
    return logs, per


def feed(logs: Dict[str, Llog], per: int) -> int:
    n = 0
    for p, log in enumerate(logs.values()):
        for i in range(per):
            log.log(R.ChangelogRecord(
                type=R.CL_CREATE, tfid=R.Fid(1, i, 0), pfid=R.Fid(1, 0, 0),
                name=b"f%08d" % i, jobid=b"bench-job", shard=(0, p, 0, 0)))
            n += 1
    return n


# --------------------------------------------------------------- batch path
def run_batch(n_producers: int, total_records: int) -> dict:
    logs, per = fill_logs(n_producers, total_records)
    proxy = LcapProxy(logs)
    stream = connect(proxy).subscribe(Subscription(
        group="bench", flags=FLAGS, auto_commit=False, max_records=4096))
    total = feed(logs, per)

    t0 = time.perf_counter()
    done = 0
    while done < total:
        proxy.pump()
        moved = 0
        for pid, batch in stream.fetch():
            moved += len(batch)
        stream.commit()
        if not moved:
            proxy.flush_upstream()
        done += moved
    elapsed = time.perf_counter() - t0

    assert all(log.first_index == log.last_index + 1 for log in logs.values())
    segments_dropped = sum(log.stats["segments_dropped"]
                           for log in logs.values())
    return {"records": total, "seconds": elapsed,
            "records_per_sec": total / elapsed,
            "segments_dropped": segments_dropped}


# ------------------------------------------------------------ columnar path
def run_columnar(n_producers: int, total_records: int) -> dict:
    """The columnar dispatch fast path, driven at the proxy API: one
    consumer that always drains its outbox fully, so every pump's whole
    ingest burst stays batch-shaped end to end (ingest -> whole-batch
    deliver/stamp -> chunked outbox -> bulk commit -> bulk ack)."""
    logs, per = fill_logs(n_producers, total_records)
    proxy = LcapProxy(logs, batch_size=4096)
    cid = proxy.subscribe("bench", flags=FLAGS)
    total = feed(logs, per)

    t0 = time.perf_counter()
    done = 0
    while done < total:
        moved = proxy.pump()
        while True:
            batches = proxy.fetch_batches(cid, 1 << 30)
            if not batches:
                break
            for pid, batch in batches:
                proxy.commit(cid, {pid: batch.indices()})
                done += len(batch)
        if not moved:
            proxy.flush_upstream()
    elapsed = time.perf_counter() - t0

    assert all(log.first_index == log.last_index + 1 for log in logs.values())
    return {"records": total, "seconds": elapsed,
            "records_per_sec": total / elapsed,
            "segments_dropped": sum(log.stats["segments_dropped"]
                                    for log in logs.values())}


# ---------------------------------------------------------- seed-style path
class SeedPipeline:
    """The seed's per-record hot path, reproduced: every record is fully
    decoded and re-encoded at ingest, decoded again at dispatch for its
    index, remapped per consumer, decoded once more at the reader, and
    acknowledged one index at a time."""

    def __init__(self, logs: Dict[str, Llog], flags: int = FLAGS,
                 batch_size: int = 1024):
        self.logs = logs
        self.flags = flags
        self.batch_size = batch_size
        self.rids = {pid: log.register_reader(f"seed-{pid}")
                     for pid, log in logs.items()}
        self.cursors = {pid: log.first_index for pid, log in logs.items()}
        self.trackers = {pid: AckTracker() for pid in logs}
        self.acked = {pid: 0 for pid in logs}
        self.buffer = deque()
        self.outbox = deque()
        self.in_flight = {}

    def pump(self) -> int:
        n = 0
        for pid, log in self.logs.items():
            while True:
                batch = log.read(self.cursors[pid], self.batch_size)
                if not batch:
                    break
                recs = [R.unpack(b) for b in batch]      # full decode
                hi = max(r.index for r in recs)
                self.cursors[pid] = hi + 1
                for rec in recs:
                    self.buffer.append((pid, R.pack(rec)))   # re-encode
                n += len(recs)
                if len(recs) < self.batch_size:
                    break
        while self.buffer:
            pid, buf = self.buffer.popleft()
            idx = R.unpack(buf).index                    # decode for one u64
            self.trackers[pid].deliver(idx)
            out = R.remap(buf, R.packed_flags(buf) & self.flags)
            self.outbox.append((pid, idx, out))
            self.in_flight[(pid, idx)] = buf
        return n

    def consume(self, max_records: int = 4096) -> int:
        n = 0
        while self.outbox and n < max_records:
            pid, idx, buf = self.outbox.popleft()
            rec = R.unpack(R.remap(buf, self.flags))     # reader-side decode
            assert rec.index == idx
            self.in_flight.pop((pid, idx), None)
            w = self.trackers[pid].ack(idx)              # ack per record
            if w > self.acked[pid]:
                self.logs[pid].ack(self.rids[pid], w)
                self.acked[pid] = w
            n += 1
        return n


def run_seed(n_producers: int, total_records: int) -> dict:
    logs, per = fill_logs(n_producers, total_records)
    pipe = SeedPipeline(logs)
    total = feed(logs, per)

    t0 = time.perf_counter()
    done = 0
    while done < total:
        pipe.pump()
        done += pipe.consume(1 << 30)
    elapsed = time.perf_counter() - t0

    assert all(log.first_index == log.last_index + 1 for log in logs.values())
    return {"records": total, "seconds": elapsed,
            "records_per_sec": total / elapsed}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.format(
        SMOKE_MIN_SPEEDUP=SMOKE_MIN_SPEEDUP,
        COLUMNAR_MIN_SPEEDUP=COLUMNAR_MIN_SPEEDUP))
    ap.add_argument("--records", type=int, default=64_000,
                    help="total records per topology")
    ap.add_argument("--producers", type=int, nargs="+", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI workload; exit 1 if the Session-API "
                         f"path is < {SMOKE_MIN_SPEEDUP}x the seed path")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_proxy.json"))
    args = ap.parse_args()
    if args.smoke:
        args.records = min(args.records, 20_000)
        producers = args.producers or [1, 4]
    else:
        producers = args.producers or [1, 4, 16]

    results = {}
    for n in producers:
        batch = run_batch(n, args.records)
        seed = run_seed(n, args.records)
        columnar = run_columnar(n, args.records)
        speedup = batch["records_per_sec"] / seed["records_per_sec"]
        col_speedup = (columnar["records_per_sec"]
                       / seed["records_per_sec"])
        if args.smoke and speedup < SMOKE_MIN_SPEEDUP:
            # one retry: a shared CI runner can stall a single
            # measurement; a real regression fails both
            batch2 = run_batch(n, args.records)
            speedup2 = batch2["records_per_sec"] / seed["records_per_sec"]
            if speedup2 > speedup:
                batch, speedup = batch2, speedup2
        if args.smoke and col_speedup < COLUMNAR_MIN_SPEEDUP:
            columnar2 = run_columnar(n, args.records)
            if columnar2["records_per_sec"] > columnar["records_per_sec"]:
                columnar = columnar2
                col_speedup = (columnar["records_per_sec"]
                               / seed["records_per_sec"])
        results[str(n)] = {"batch": batch, "seed_per_record": seed,
                           "columnar": columnar,
                           "speedup": round(speedup, 2),
                           "columnar_speedup": round(col_speedup, 2)}
        print(f"producers={n:3d}  batch={batch['records_per_sec']:>12,.0f} rec/s  "
              f"seed={seed['records_per_sec']:>12,.0f} rec/s  "
              f"columnar={columnar['records_per_sec']:>12,.0f} rec/s  "
              f"speedup={speedup:.2f}x  columnar_speedup={col_speedup:.2f}x  "
              f"segments_dropped={batch['segments_dropped']}")

    payload = {
        "benchmark": "lcap proxy ingest->dispatch->ack",
        "unit": "records/sec",
        "flags": "CLF_JOBID|CLF_SHARD",
        "total_records": args.records,
        "results": results,
        "min_speedup": min(r["speedup"] for r in results.values()),
        "min_columnar_speedup": min(r["columnar_speedup"]
                                    for r in results.values()),
    }
    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"wrote {os.path.abspath(args.out)}")
    if args.smoke and payload["min_speedup"] < SMOKE_MIN_SPEEDUP:
        print(f"SMOKE FAIL: min speedup {payload['min_speedup']:.2f}x < "
              f"{SMOKE_MIN_SPEEDUP}x — Session-API hot path regressed")
        sys.exit(1)
    if args.smoke and payload["min_columnar_speedup"] < COLUMNAR_MIN_SPEEDUP:
        print(f"SMOKE FAIL: columnar speedup "
              f"{payload['min_columnar_speedup']:.2f}x < "
              f"{COLUMNAR_MIN_SPEEDUP}x — columnar dispatch regressed")
        sys.exit(1)


if __name__ == "__main__":
    main()
