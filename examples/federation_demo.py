"""Federated multi-tenant audit over two changelog clusters.

Two filesystems (2-shard ``LcapCluster`` each) join one ``Federation``;
two tenants share them.  Three audit consumers subscribe up front:

- ``acme``  — scoped to ``jobid`` prefix ``acme.``   (tenant-isolated)
- ``orbit`` — scoped to ``jobid`` prefix ``orbit.``  (tenant-isolated)
- ``site``  — unscoped (the trusted operator view)

Tenant isolation is *server-side*: the proxies evaluate each scope as
a columnar pushdown over the jobid column, so a scoped audit trail can
only ever contain that tenant's activity — out-of-scope records are
acknowledged in place and never copied into its outbox.  The ``acme``
tenant also runs under a delivery quota; when it bursts past the
token bucket its groups park on the ordinary backpressure path (and
resume once the demo lifts the quota — delayed, never lost), which
the demo surfaces via the ``lcap_tenant_*`` metrics merged across the
federation.

Run:  PYTHONPATH=src python examples/federation_demo.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.cluster import LcapCluster
from repro.core.federation import Federation
from repro.core.records import CL_CREATE, CL_MKDIR
from repro.core.tenancy import TenantPrincipal
from repro.obs import MetricsRegistry
from repro.track.audit import AuditTrail
from repro.track.tracker import ActivityTracker

ACME = TenantPrincipal("acme", prefixes=[b"acme."])
ORBIT = TenantPrincipal("orbit", prefixes=[b"orbit."])


def build_cluster(fsname: str, jobs) -> LcapCluster:
    """One filesystem: a tracker per (host, jobid) feeding 2 shards."""
    trackers = [
        ActivityTracker(run_id=i + 1, host_id=i, jobid=job,
                        shard=(0, i, 0, 0))
        for i, job in enumerate(jobs)
    ]
    logs = {f"{fsname}-{t.llog.producer_id}": t.llog for t in trackers}
    cluster = LcapCluster(logs, n_shards=2)
    cluster.trackers = trackers          # keep the producers reachable
    return cluster


def drive(cluster: LcapCluster, rounds: int) -> None:
    step = 0
    for _ in range(rounds):
        for t in cluster.trackers:
            step += 1
            t.step_commit(step, loss=1.0 / step, step_time_s=0.2,
                          tokens=4096)
            t.fs_op(CL_CREATE, oid=step, name=b"out-%06d" % step)
            if step % 7 == 0:
                t.fs_op(CL_MKDIR, oid=step, name=b"dir-%06d" % step)


def main() -> int:
    # jobids follow the Lustre procname_uid convention, prefixed by
    # the owning tenant: "<tenant>.<procname>.<uid>"
    fs0 = build_cluster("fs0", ["acme.train.1000", "orbit.sim.2000"])
    fs1 = build_cluster("fs1", ["acme.index.1001", "orbit.sim.2000"])
    for fs in (fs0, fs1):        # per-tenant series need a registry
        fs.attach_registry(MetricsRegistry())
    fed = Federation({"fs0": fs0, "fs1": fs1})

    # every consumer group subscribes before activity flows (changelog
    # retention: records are trimmed once every registered group acks)
    acme = AuditTrail(fed, group="audit-acme", tenant=ACME)
    orbit = AuditTrail(fed, group="audit-orbit", tenant=ORBIT)
    site = AuditTrail(fed, group="audit-site")

    # a deliberately tiny delivery quota for acme: the first dispatch
    # round spends the burst, and with a 1 rec/s refill every later
    # round that finds acme records pending parks its groups — the
    # quota gates *rounds*, so this is deterministic, not a race
    # against the refill clock
    fed.set_tenant_quota("acme", records_per_s=1, burst_records=25)

    print("driving two tenants across two federated filesystems...\n")
    # interleave producing and pumping: quota is charged per dispatch
    # round, so a steady stream (not one pre-staged backlog) is what
    # exercises the park path
    for _ in range(6):
        for fs in (fs0, fs1):
            drive(fs, rounds=10)
        fed.pump()
        acme.poll()
        orbit.poll()
        site.poll()

    # lift the quota (both rates None clears the buckets): the parked
    # groups resume on the next round and the backlog drains — records
    # were delayed, never lost
    fed.set_tenant_quota("acme")

    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline:
        moved = fed.pump()
        folded = acme.poll() + orbit.poll() + site.poll()
        if not moved and not folded and not site.bootstrapping:
            lag = fed.lag()
            if all(not any(v.values()) for v in lag.values()):
                break
            time.sleep(0.02)

    # -- the operator view ------------------------------------------------
    print(f"{'jobid':24s} {'user':6s} {'records':>8s}  origins")
    for t in site.top():
        origins = ", ".join(f"{o}:{c}" for o, c in sorted(
            t.by_origin.items()))
        print(f"{t.jobid:24s} {t.user:6s} {t.records:>8d}  {origins}")
    print(f"\nsite users: {site.users()}")

    # -- tenant isolation, by construction --------------------------------
    acme_jobs = set(acme.trails)
    orbit_jobs = set(orbit.trails)
    print(f"\nacme trail : {sorted(acme_jobs)}")
    print(f"orbit trail: {sorted(orbit_jobs)}")
    assert all(j.startswith("acme.") for j in acme_jobs)
    assert all(j.startswith("orbit.") for j in orbit_jobs)
    assert not (acme_jobs & orbit_jobs), "cross-tenant leak!"
    print("isolation: no cross-tenant records in either scoped trail")

    # -- per-tenant accounting across the federation ----------------------
    merged = fed.metrics()
    for name in ("lcap_tenant_delivered_records_total",
                 "lcap_tenant_filtered_records_total",
                 "lcap_tenant_quota_blocked_pumps_total"):
        for labels, value in merged[name]["samples"]:
            if value:
                tags = ",".join(f"{k}={v}" for k, v in sorted(
                    labels.items()))
                print(f"{name}{{{tags}}} {value:g}")

    st = fed.stats()
    blocked = sum(
        value
        for labels, value in merged[
            "lcap_tenant_quota_blocked_pumps_total"]["samples"]
        if labels.get("tenant") == "acme")
    folded = sum(t.records for t in site.trails.values())
    print(f"\nfederation: {len(st['per_origin'])} origins, "
          f"{folded} records in the site audit; acme parked "
          f"{blocked:g} pump rounds on its quota before it was lifted")

    ok = (bool(acme_jobs) and bool(orbit_jobs)
          and not (acme_jobs & orbit_jobs) and blocked > 0)
    for a in (acme, orbit, site):
        a.close()
    fed.close()
    for fs in (fs0, fs1):
        fs.close()
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
