"""Policy engine end to end: an age-out purge rule on the changelog.

A tiny Robinhood: files are created and touched on the changelog
fabric; a ``NamespaceMirror`` tracks ground truth (bootstrapping from
the compacted history tier, so it can start *after* the activity it
needs to know about); a ``PolicyRule`` purges anything older than
AGE_OUT_S of stream time; the resulting action chains (NEW -> UPDATE ->
COMPLETED -> PURGED) flow back through the proxy as first-class
changelog records any consumer can subscribe to; and the reconciler
proves the stream-derived action state matches the engine's ground
truth.

Run:  PYTHONPATH=src python examples/policy_demo.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import records as R
from repro.core.llog import Llog
from repro.core.proxy import LcapProxy
from repro.core.session import Subscription, connect
from repro.policy import (NamespaceMirror, PolicyEngine, PolicyRule,
                          reconcile)

AGE_OUT_S = 3600.0          # purge anything older than an hour
T0 = 1_700_000_000 * 10**9  # an arbitrary stream epoch (ns)


def log_at(log, rtype, oid, at_s, name=b"", **kw):
    log.log(R.ChangelogRecord(type=rtype, tfid=R.Fid(1, oid, 0),
                              pfid=R.Fid(1, 0, 0), name=name,
                              time=T0 + int(at_s * 1e9), **kw))


def main() -> int:
    workdir = tempfile.mkdtemp(prefix="policy_demo.")
    log = Llog("mdt0", path=os.path.join(workdir, "journal"),
               segment_records=16, history=True)
    proxy = LcapProxy({"mdt0": log})

    # -- activity happens *before* the policy engine exists ---------------
    for i in range(8):
        log_at(log, R.CL_CREATE, i, at_s=i * 60.0, name=b"scratch-%d" % i)
    log_at(log, R.CL_UNLINK, 3, at_s=500.0, name=b"scratch-3")
    proxy.pump()

    # -- the engine arrives late and bootstraps from history --------------
    mirror = NamespaceMirror(proxy)                 # replay=True default
    engine = PolicyEngine(
        mirror,
        [PolicyRule("age-out", action="purge", min_age_s=AGE_OUT_S)],
        target=proxy, path=os.path.join(workdir, "actions"))
    # an independent consumer watches the action stream (pushdown: only
    # CL_ACTION_* records ever reach its outbox)
    watcher = connect(proxy).subscribe(Subscription(
        group="watcher", types=R.CL_ACTION_TYPES, auto_commit=False))

    mirror.bootstrap()
    print(f"mirror bootstrapped: {len(mirror.entries)} live entries, "
          f"{mirror.stream.replayed} history records replayed")

    # -- time passes: a new touch advances the stream clock ---------------
    log_at(log, R.CL_CREATE, 100, at_s=2 * 3600.0, name=b"fresh")
    proxy.pump()
    mirror.poll()

    matched = engine.evaluate()
    print(f"rule matched {len(matched)} entries "
          f"(everything older than {AGE_OUT_S:.0f}s of stream time)")
    engine.run_pending()                            # start + complete
    swept = engine.janitor_sweep()                  # purge closed chains
    proxy.pump()

    seen = []
    for _pid, batch in watcher.fetch(4096):
        seen.extend(batch.to_records())
    watcher.commit()
    by_type = {}
    for r in seen:
        by_type[r.type_name] = by_type.get(r.type_name, 0) + 1
    print(f"watcher consumed {len(seen)} action records: {by_type}")
    print(f"janitor purged {swept} completed chains")

    report = reconcile(engine, proxy)
    print(report)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
