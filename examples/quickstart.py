"""Quickstart: train a reduced-config model end-to-end on CPU with the
full stack — sharded data pipeline, pjit train step, LCAP activity
tracking, async checkpointing, metrics DB, straggler detection.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

from repro import configs as C                                  # noqa: E402
from repro.runtime.train_loop import Trainer                    # noqa: E402


def main() -> None:
    cfg = C.get_smoke("granite-8b")
    workdir = tempfile.mkdtemp(prefix="repro_quickstart_")
    trainer = Trainer(cfg, workdir=workdir, global_batch=8, seq_len=32,
                      n_hosts=2, ckpt_every=5)
    history = trainer.run(15)
    trainer.ckpt.wait()
    trainer.pump_consumers()

    print(f"workdir: {workdir}")
    print(f"loss: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")
    rows = trainer.metrics[0].query(
        "SELECT type, COUNT(*) FROM events GROUP BY type ORDER BY type")
    print("activity records in the shared metrics DB (type -> count):")
    for t, n in rows:
        print(f"  {t:3d} -> {n}")
    print(f"committed checkpoint: step {trainer.committer.latest_committed()}")
    assert history[-1]["loss"] < history[0]["loss"], "loss should drop"
    trainer.close()
    print("OK")


if __name__ == "__main__":
    main()
