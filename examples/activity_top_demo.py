"""Live ``top`` over a 2-shard changelog cluster.

Two training jobs (one ``ActivityTracker`` per host) log step commits,
checkpoint writes, heartbeats and a little filesystem churn; a 2-shard
``LcapCluster`` routes the merged stream; an ``ActivityAggregator``
folds it into 100 ms windows; and ``ActivityTop`` repaints the
busiest-jobs/ops/shards view with consumer lag and shard health —
the whole observability plane in one process.

The same data is exported both ways at the end: a Prometheus scrape
(served over HTTP, excerpted) and a Ganglia-shaped push.

Run:  PYTHONPATH=src python examples/activity_top_demo.py
"""

import os
import random
import sys
import time
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.cluster import LcapCluster
from repro.core.records import CL_CREATE
from repro.core.session import connect
from repro.obs import (ActivityAggregator, ActivityTop, GangliaPusher,
                       MetricsRegistry, PrometheusExporter)
from repro.track.tracker import ActivityTracker

WINDOW_NS = 100_000_000          # 100 ms panes: a fast demo still rolls
ROUNDS = 12


def main() -> int:
    rng = random.Random(7)
    trackers = [
        ActivityTracker(run_id=1, host_id=0, jobid="train-alpha",
                        shard=(0, 0, 0, 0)),
        ActivityTracker(run_id=2, host_id=1, jobid="train-beta",
                        shard=(0, 1, 0, 0)),
    ]
    logs = {t.llog.producer_id: t.llog for t in trackers}

    # the cluster registers the journal readers — build it before any
    # activity happens, or the llogs drop the records (changelog
    # semantics: no reader, no retention)
    cluster = LcapCluster(logs, n_shards=2)
    registry = MetricsRegistry()
    cluster.attach_registry(registry)
    agg = ActivityAggregator(cluster, window_ns=WINDOW_NS, retention=64)
    registry.register_collector(agg.collector())
    session = connect(cluster)
    top = ActivityTop(agg, session=session, cluster=cluster,
                      k=4, sliding=5)

    print("driving two jobs over a 2-shard cluster "
          f"({ROUNDS} rounds, {WINDOW_NS / 1e6:.0f} ms panes)...\n")
    step = 0
    for _ in range(ROUNDS):
        for t in trackers:
            # train-alpha runs hotter than train-beta
            bursts = 6 if t.host_id == 0 else 2
            for _b in range(bursts):
                step += 1
                t.step_commit(step, loss=rng.uniform(0.5, 2.0),
                              step_time_s=rng.uniform(0.1, 0.4),
                              tokens=rng.randrange(1 << 16))
                t.heartbeat(step, step_time_s=0.2)
                t.fs_op(CL_CREATE, oid=step, name=b"shard-%d" % step)
            if step % 5 == 0:
                t.ckpt_write(step, shard_id=t.host_id,
                             nbytes=rng.randrange(1 << 24),
                             path=f"/ckpt/{step}", total_shards=2)
        cluster.pump()
        agg.run_once()
        time.sleep(WINDOW_NS / 1e9 / 4)

    # one final frame (run() would repaint in place on a live terminal)
    print(top.render())

    exporter = PrometheusExporter(registry=registry).start()
    try:
        with urllib.request.urlopen(exporter.url, timeout=5) as resp:
            body = resp.read().decode()
    finally:
        exporter.stop()
    interesting = [ln for ln in body.splitlines()
                   if ln.startswith(("lcap_cluster_routed_total",
                                     "lcap_window_records",
                                     "lcap_agg_records_total"))]
    print(f"\nPrometheus scrape: {len(body.splitlines())} lines from "
          f"{exporter.url}; e.g.")
    for ln in interesting[:6]:
        print(f"  {ln}")

    pusher = GangliaPusher(registry=registry)
    n = pusher.push()
    print(f"Ganglia push: {n} metrics "
          f"(e.g. {', '.join(m['name'] for m in pusher.sent[:3])}, ...)")

    ok = agg.stats["records"] > 0 and not agg.stats["late_dropped"]
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
