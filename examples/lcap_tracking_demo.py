"""LCAP demo — the paper's system end to end, over TCP:

- 3 producers (simulated MDTs / training hosts) journal filesystem-style
  and training events;
- the LCAP service aggregates them (greedy batched reads) and publishes
  to two persistent consumer GROUPS (load-balanced within each) plus an
  EPHEMERAL observer that attaches mid-stream;
- compensating creat/unlink pairs are compacted by a proxy module;
- collective acknowledgement trims the producer journals only when both
  groups acked.

    PYTHONPATH=src python examples/lcap_tracking_demo.py
"""

import time

from repro.core import records as R
from repro.core.llog import Llog
from repro.core.modules import CancelCompensating
from repro.core.proxy import LcapProxy
from repro.core.reader import RemoteReader
from repro.core.server import LcapService
from repro.track import ActivityTracker


def main() -> None:
    trackers = [ActivityTracker(run_id=7, host_id=h, jobid=f"demo-job-{h}")
                for h in range(3)]
    proxy = LcapProxy({t.llog.producer_id: t.llog for t in trackers},
                      modules=[CancelCompensating()])
    svc = LcapService(proxy).start()
    print(f"LCAP service on {svc.address}")

    # persistent groups: 2x metrics + 1x audit; ephemeral: dashboard
    metrics = [RemoteReader(svc.address, "metrics") for _ in range(2)]
    audit = RemoteReader(svc.address, "audit")

    for step in range(3):
        for t in trackers:
            t.step_commit(step, loss=2.0 - 0.3 * step, step_time_s=0.1,
                          tokens=4096)
    # compensating pair -> compacted by the module, never delivered
    trackers[0].fs_op(R.CL_CREATE, oid=99, name=b"scratch.tmp")
    trackers[0].fs_op(R.CL_UNLINK, oid=99, name=b"scratch.tmp")

    dashboard = RemoteReader(svc.address, None, mode="ephemeral")
    trackers[1].heartbeat(3, step_time_s=0.12)   # emitted after attach

    time.sleep(0.3)
    got_m = [m.fetch(100) for m in metrics]
    got_a = audit.fetch(100)
    got_d = dashboard.fetch(100)

    print(f"metrics group: {len(got_m[0])} + {len(got_m[1])} records "
          f"(load-balanced, total {len(got_m[0]) + len(got_m[1])})")
    print(f"audit group:   {len(got_a)} records (same stream, own copy)")
    print(f"ephemeral dashboard: {len(got_d)} records (no history)")
    assert len(got_d) < len(got_a), "ephemeral reader must miss history"

    for pid, rec in got_m[0]:
        metrics[0].ack(pid, rec.index)
    for pid, rec in got_m[1]:
        metrics[1].ack(pid, rec.index)
    time.sleep(0.2)
    first = trackers[0].llog.first_index
    print(f"after metrics-only acks, journal trim point: {first} "
          f"(audit group still owes acks)")
    for pid, rec in got_a:
        audit.ack(pid, rec.index)
    time.sleep(0.3)
    print(f"after audit acks too, journal trimmed to: "
          f"{trackers[0].llog.first_index}..{trackers[0].llog.last_index}")
    print(f"proxy stats: {proxy.stats}")

    for r in (*metrics, audit, dashboard):
        r.close()
    svc.stop()
    print("OK")


if __name__ == "__main__":
    main()
