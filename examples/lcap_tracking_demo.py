"""LCAP demo — the unified Session API end to end, over TCP:

- 3 producers (simulated MDTs / training hosts) journal filesystem-style
  and training events;
- the LCAP service aggregates them and publishes to declarative
  subscriptions: a load-balanced *metrics* group consuming everything, a
  *durable* checkpoint auditor with an op-type mask pushed down to the
  proxy (CKPT_WRITE records only — nothing else is ever copied into its
  outbox), and an EPHEMERAL dashboard that attaches mid-stream;
- the durable auditor crashes mid-flight and resumes under the same
  name at its exact ack cursor — no group-wide redelivery storm;
- collective acknowledgement trims the producer journals only when
  every group acked.

    PYTHONPATH=src python examples/lcap_tracking_demo.py
"""

import time

from repro.core import records as R
from repro.core.proxy import LcapProxy
from repro.core.server import LcapService
from repro.core.session import Subscription, connect
from repro.track import ActivityTracker


def main() -> None:
    trackers = [ActivityTracker(run_id=7, host_id=h, jobid=f"demo-job-{h}")
                for h in range(3)]
    proxy = LcapProxy({t.llog.producer_id: t.llog for t in trackers})
    svc = LcapService(proxy).start()
    print(f"LCAP service on {svc.address}")

    # one Session per consumer process; declarative subscriptions on it
    metric_sessions = [connect(svc.address) for _ in range(2)]
    metrics = [s.subscribe("metrics")
               for s in metric_sessions]               # load-balanced group
    audit_session = connect(svc.address)
    audit = audit_session.subscribe(Subscription(
        group="ckpt-audit", name="auditor-0",          # durable identity
        types={R.CL_CKPT_WRITE},                       # op-type pushdown
        flags=R.CLF_JOBID | R.CLF_XATTR))              # field projection

    for step in range(3):
        for t in trackers:
            t.step_commit(step, loss=2.0 - 0.3 * step, step_time_s=0.1,
                          tokens=4096)
            t.ckpt_write(step, shard_id=t.host_id, nbytes=1 << 20,
                         path=f"/ckpt/s{t.host_id}", total_shards=3)

    dash_session = connect(svc.address)
    dashboard = dash_session.subscribe(mode="ephemeral")
    trackers[1].heartbeat(3, step_time_s=0.12)         # emitted after attach

    time.sleep(0.3)
    got_m = [list(m) for m in metrics]                 # iterate = auto-commit
    print(f"metrics group: {sum(len(b) for _, b in got_m[0])} + "
          f"{sum(len(b) for _, b in got_m[1])} records (load-balanced)")

    # the durable auditor consumes part of its filtered stream, commits
    # it, fetches more without committing, then crashes mid-flight
    early = audit.fetch(3)
    audit.commit()
    unacked = audit.fetch(100)
    total = sum(len(b) for _, b in early + unacked)
    print(f"auditor got {total} CKPT_WRITE records (proxy filtered "
          f"everything else: filtered_out={proxy.stats['filtered_out']})")
    audit.close(failed=True)                           # socket drops, no bye
    time.sleep(0.1)

    # ...and resumes under the same durable name: only its own unacked
    # records are replayed, the metrics group never sees a redelivery
    resume_session = connect(svc.address)
    resumed = resume_session.resume("ckpt-audit", "auditor-0")
    replay = [idx for _, b in resumed.fetch(100) for idx in b.indices()]
    print(f"resumed at cursor {resumed.resume_token}; replayed "
          f"{len(replay)} unacked records; group redeliveries: "
          f"{proxy.stats['redelivered']}")
    resumed.commit()

    got_d = list(dashboard)
    print(f"ephemeral dashboard: {sum(len(b) for _, b in got_d)} records "
          f"(no history)")

    time.sleep(0.3)
    first = trackers[0].llog.first_index
    last = trackers[0].llog.last_index
    print(f"journals after both groups acked: trimmed to {first}..{last}")
    print(f"proxy stats: {proxy.stats}")

    for s in (*metric_sessions, resume_session, dash_session):
        s.close()                       # releases consumers + connections
    svc.stop()
    print("OK")


if __name__ == "__main__":
    main()
