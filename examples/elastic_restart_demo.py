"""Elastic scaling demo: a host 'fails' mid-run; the ElasticController
observes the LEAVE record, replans the mesh, and training resumes from
the async checkpoint on the smaller mesh — then scales back up.

Runs as two subprocesses (different simulated device counts must be set
before jax initializes).

    PYTHONPATH=src python examples/elastic_restart_demo.py
"""

import json
import os
import subprocess
import sys
import tempfile
import textwrap

PHASE = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={sys.argv[1]}"
    import jax
    from repro import configs as C
    from repro.core.proxy import LcapProxy
    from repro.runtime.train_loop import Trainer
    from repro.track import ActivityTracker, ElasticController

    n_dev, wd, phase = int(sys.argv[1]), sys.argv[2], sys.argv[3]
    cfg = C.get_smoke("starcoder2-3b")
    t = Trainer(cfg, workdir=wd, global_batch=4, seq_len=16, n_hosts=2,
                ckpt_every=2)
    mesh_shape = dict(t.mesh.shape)

    # elastic controller watching JOIN/LEAVE records
    ctl = ElasticController(t.proxy, chips_per_host=n_dev // 2)
    for tr in t.trackers:
        tr.elastic(joined=True, n_hosts=2, step=t.step)
    if phase == "degraded":
        t.trackers[1].elastic(joined=False, n_hosts=1, step=t.step)
    t.proxy.pump(); ctl.poll()

    hist = t.run(4)
    t.ckpt.wait()
    print(json.dumps({"phase": phase, "devices": n_dev,
                      "mesh": mesh_shape,
                      "plan": ctl.plan(),
                      "resumed_at": hist[0]["step"],
                      "ended_at": hist[-1]["step"],
                      "loss": round(hist[-1]["loss"], 3)}))
    t.close()
""")


def run_phase(devices: int, workdir: str, phase: str) -> dict:
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", PHASE, str(devices),
                        workdir, phase],
                       capture_output=True, text=True, env=env)
    if r.returncode != 0:
        print(r.stderr[-3000:])
        raise SystemExit(1)
    return json.loads(r.stdout.strip().splitlines()[-1])


def main() -> None:
    wd = tempfile.mkdtemp(prefix="repro_elastic_")
    print("phase 1: full fleet (4 devices, 2 hosts)")
    p1 = run_phase(4, wd, "full")
    print(" ", p1)
    print("phase 2: host lost -> restart on 2 devices, resume from ckpt")
    p2 = run_phase(2, wd, "degraded")
    print(" ", p2)
    assert p2["resumed_at"] > 1, "must resume from checkpoint, not step 0"
    print("phase 3: host recovered -> scale back to 4 devices")
    p3 = run_phase(4, wd, "recovered")
    print(" ", p3)
    assert p3["resumed_at"] > p2["resumed_at"]
    print("OK — state survived two mesh changes via mesh-agnostic "
          "checkpoints + changelog replay")


if __name__ == "__main__":
    main()
