"""Serving demo: batched prefill + KV-cache decode on a reduced config,
plus LCAP-driven cache invalidation between replicas (paper §IV-C-1).

    PYTHONPATH=src python examples/serve_demo.py
"""

import sys

from repro.launch import serve


def main() -> None:
    sys.argv = [sys.argv[0], "--arch", "granite-8b", "--smoke",
                "--batch", "4", "--prompt-len", "12", "--gen-len", "6"]
    serve.main()


if __name__ == "__main__":
    main()
