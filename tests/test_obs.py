"""Observability plane: metrics registry semantics, windowed
aggregation equivalence (vs scalar decode and vs an offline MetricsDB
SQL aggregation of the same 4-shard run), Prometheus/Ganglia export
validity, the metrics/lag wire verbs, consumer-lag behavior across a
shard kill, scalar-vs-columnar dispatch stats parity, and the top
dashboard renderer."""

import re
import sqlite3
import urllib.request
from collections import Counter

import numpy as np
import pytest

from repro.core import records as R
from repro.core import transport
from repro.core.cluster import LcapCluster
from repro.core.llog import Llog
from repro.core.proxy import LcapProxy
from repro.core.server import LcapService
from repro.core.session import Subscription, connect
from repro.obs import (ActivityAggregator, ActivityTop, GangliaPusher,
                       MetricsRegistry, PrometheusExporter,
                       merge_snapshots, render_prometheus)
from repro.track.consumers import MetricsDB

T0 = 1_700_000_000_000_000_000        # stream epoch (ns)
WIN = 1_000_000_000                   # 1 s panes


def mk_logs(n=2):
    return {f"mdt{i}": Llog(f"mdt{i}") for i in range(n)}


def feed_varied(logs, n_each=60, jobs=4, with_rename=True):
    """A deliberately messy workload: mixed op types, records with and
    without jobid/shard/metrics, and CLF_RENAME records (which shift
    every later extension's offset — the case the vectorized payload
    gathers must get right)."""
    types = [R.CL_CREATE, R.CL_CLOSE, R.CL_HEARTBEAT, R.CL_STEP_COMMIT]
    fed = []
    for p, (pid, log) in enumerate(sorted(logs.items())):
        for i in range(n_each):
            kw = {}
            if i % 5 != 4:
                kw["jobid"] = f"job-{i % jobs}".encode()
            if i % 7 != 6:
                kw["shard"] = (p, i % 3, 0, 0)
            if i % 3 == 0:
                kw["metrics"] = (float(i), 0.5)
            if with_rename and i % 11 == 0:
                kw["sfid"] = R.Fid(9, i, 0)
                kw["spfid"] = R.Fid(9, 0, 0)
                kw["sname"] = b"old"
            rec = R.ChangelogRecord(
                type=types[i % len(types)], tfid=R.Fid(1, i % 17, 0),
                pfid=R.Fid(1, 0, 0), name=f"{pid}-{i}".encode(),
                time=T0 + (i % 10) * WIN + (i % 10) * 1000, **kw)
            if log.log(rec) is not None:
                fed.append((pid, rec))
    return fed


def expected_fold(fed, window_ns=WIN):
    """Offline scalar reference of the aggregator's fold."""
    counts, vsums = Counter(), Counter()
    for pid, rec in fed:
        key = (rec.time // window_ns,
               (rec.type, (rec.jobid or b"").decode(), pid,
                rec.shard[1] if rec.shard else 0))
        counts[key] += 1
        vsums[key] += rec.metrics[0] if rec.metrics else 0.0
    return counts, vsums


def drain(proxy, agg, rounds=50):
    for _ in range(rounds):
        moved = proxy.pump()
        got = agg.run_once()
        proxy.flush_upstream()
        if not moved and not got:
            break


# ===================================================================== registry
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "a counter")
    c.inc()
    c.inc(4)
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g", "a gauge")
    g.set(10)
    g.dec(3)
    h = reg.histogram("h_seconds", "a histogram", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(99.0)                      # above every bucket: +Inf only
    snap = reg.snapshot()
    assert snap["c_total"]["samples"] == [[{}, 5.0]]
    assert snap["g"]["samples"] == [[{}, 7.0]]
    hs = snap["h_seconds"]["samples"][0][1]
    assert hs["buckets"] == [[0.1, 1], [1.0, 2]]     # cumulative
    assert hs["count"] == 3 and hs["sum"] == pytest.approx(99.55)


def test_labeled_families_cache_children_and_reject_conflicts():
    reg = MetricsRegistry()
    fam = reg.counter("ops_total", labels=("op",))
    fam.labels(op="create").inc(2)
    fam.labels(op="close").inc()
    assert fam.labels(op="create") is fam.labels(op="create")
    with pytest.raises(ValueError):
        fam.labels(nope="x")
    assert reg.counter("ops_total", labels=("op",)) is fam   # idempotent
    with pytest.raises(ValueError):
        reg.gauge("ops_total")                               # kind conflict
    samples = {tuple(sorted(l.items())): v
               for l, v in reg.snapshot()["ops_total"]["samples"]}
    assert samples == {(("op", "create"),): 2.0, (("op", "close"),): 1.0}


def test_snapshot_folds_in_collectors():
    reg = MetricsRegistry()
    reg.register_collector(
        lambda: [("live_depth", "gauge", "depth", {"q": "a"}, 7)])
    snap = reg.snapshot()
    assert snap["live_depth"]["samples"] == [[{"q": "a"}, 7]]


def test_merge_snapshots_sums_counters_and_labels_gauges():
    a = {"n_total": {"type": "counter", "help": "", "samples": [[{}, 3]]},
         "depth": {"type": "gauge", "help": "", "samples": [[{}, 5]]}}
    b = {"n_total": {"type": "counter", "help": "", "samples": [[{}, 4]]},
         "depth": {"type": "gauge", "help": "", "samples": [[{}, 9]]}}
    merged = merge_snapshots({"0": a, "1": b})
    assert merged["n_total"]["samples"] == [[{}, 7]]
    by_shard = {l["shard"]: v for l, v in merged["depth"]["samples"]}
    assert by_shard == {"0": 5, "1": 9}


# ============================================================ payload columns
def test_payload_columns_match_scalar_unpack():
    logs = mk_logs(1)
    proxy = LcapProxy(logs)                       # registers the reader
    fed = feed_varied(logs, n_each=80)
    batch = logs["mdt0"].read(1, 4096)
    recs = [R.unpack(bytes(batch.packed(i))) for i in range(len(batch))]
    assert len(recs) == len(fed)

    jm = batch.jobid_col()
    pod, host = batch.shard_cols()
    m0 = batch.metric0_col()
    for i, rec in enumerate(recs):
        assert bytes(jm[i]).rstrip(b"\0") == (rec.jobid or b"")
        assert (int(pod[i]), int(host[i])) == \
            ((rec.shard[0], rec.shard[1]) if rec.shard else (0, 0))
        assert m0[i] == (rec.metrics[0] if rec.metrics else 0.0)


# ================================================================= aggregator
def test_aggregator_matches_scalar_reference():
    logs = mk_logs(2)
    proxy = LcapProxy(logs)
    agg = ActivityAggregator(proxy, window_ns=WIN, retention=64)
    fed = feed_varied(logs, n_each=60)
    drain(proxy, agg)

    counts, vsums = expected_fold(fed)
    got_counts, got_vsums = {}, {}
    for w in agg.window_ids():
        for key, (c, vs) in agg.counters(w).items():
            got_counts[(w, key)] = c
            got_vsums[(w, key)] = vs
    assert got_counts == dict(counts)
    for key in vsums:
        assert got_vsums[key] == pytest.approx(vsums[key])
    assert agg.stats["records"] == len(fed)
    # the journals trimmed: the aggregator group acked everything
    assert all(log.first_index == log.last_index + 1
               for log in logs.values())


def test_sliding_windows_and_top_trends():
    logs = mk_logs(1)
    proxy = LcapProxy(logs)
    agg = ActivityAggregator(proxy, window_ns=WIN)
    log = logs["mdt0"]
    # pane 0: 2 records for job-a; pane 1: 5 for job-a, 1 for job-b
    for win, job, n in ((0, b"a", 2), (1, b"a", 5), (1, b"b", 1)):
        for i in range(n):
            log.log(R.ChangelogRecord(type=R.CL_CREATE,
                                      tfid=R.Fid(1, i, win),
                                      name=b"f", jobid=job,
                                      time=T0 + win * WIN + i))
    drain(proxy, agg)

    w0 = T0 // WIN
    both = agg.sliding(2, end=w0 + 1)
    assert both[(R.CL_CREATE, "a", "mdt0", 0)][0] == 7
    assert both[(R.CL_CREATE, "b", "mdt0", 0)][0] == 1
    top = agg.top("jobid", k=2, window=w0 + 1)
    assert top[0]["label"] == "a" and top[0]["count"] == 5
    assert top[0]["delta"] == 3          # 5 now vs 2 in the previous pane
    assert top[0]["rate"] == pytest.approx(5.0)
    assert top[1] == {"label": "b", "count": 1, "value_sum": 0.0,
                      "rate": 1.0, "delta": 1}
    assert agg.rate(w0 + 1) == pytest.approx(6.0)


def test_ring_retention_evicts_and_counts_late_records():
    logs = mk_logs(1)
    proxy = LcapProxy(logs)
    agg = ActivityAggregator(proxy, window_ns=WIN, retention=3)
    log = logs["mdt0"]
    for win in range(6):
        log.log(R.ChangelogRecord(type=R.CL_CREATE, tfid=R.Fid(1, win, 0),
                                  name=b"f", time=T0 + win * WIN))
    drain(proxy, agg)
    assert len(agg.window_ids()) == 3
    assert agg.stats["windows_evicted"] == 3
    # a straggler older than the evicted horizon is dropped, not revived
    log.log(R.ChangelogRecord(type=R.CL_CREATE, tfid=R.Fid(1, 99, 0),
                              name=b"late", time=T0))
    drain(proxy, agg)
    assert agg.stats["late_dropped"] == 1
    assert len(agg.window_ids()) == 3


def test_replay_bootstrap_warm_starts_the_aggregator():
    """An aggregator started after the stream has been running bootstraps
    its windows from the journal's retained history (replay=True) — the
    viewer's warm-start handoff — and then tails live with no gap.
    The journal carries a non-compacting history tier so the trimmed
    prefix stays replayable record-for-record."""
    from repro.core.history import HistoryStore
    logs = {"mdt0": Llog("mdt0", history=HistoryStore(compactor=None))}
    proxy = LcapProxy(logs)
    first = ActivityAggregator(proxy, group="first", window_ns=WIN)
    fed = feed_varied(logs, n_each=40, with_rename=False)
    drain(proxy, first)

    late = ActivityAggregator(proxy, group="late", window_ns=WIN,
                              replay=True)
    more = feed_varied(logs, n_each=10, with_rename=False)
    drain(proxy, late)
    counts, _ = expected_fold(fed + more)
    got = {}
    for w in late.window_ids():
        for key, (c, _vs) in late.counters(w).items():
            got[(w, key)] = c
    assert got == dict(counts)


# ======================================================== stats parity (sat 1)
def run_dispatch_workload(force_scalar):
    """One workload, two paths: the columnar whole-batch fast path vs
    the per-record scalar loop (forced by disabling _fast_eligible).
    Observable behavior — every stats counter and the per-group
    delivered multisets — must be identical."""
    logs = mk_logs(2)
    proxy = LcapProxy(logs, batch_size=64)
    if force_scalar:
        proxy._fast_eligible = lambda *a, **kw: False
    # two persistent groups (one type-masked member each + one open),
    # a masked group nobody else overlaps, and a masked ephemeral
    sess = connect(proxy)
    streams = {
        "all": sess.subscribe(Subscription(group="all", auto_commit=False)),
        "mixed": sess.subscribe(Subscription(
            group="mixed", types={R.CL_CREATE, R.CL_CLOSE},
            auto_commit=False)),
        "rare": sess.subscribe(Subscription(
            group="rare", types={R.CL_MKDIR}, auto_commit=False)),
        "eph": sess.subscribe(Subscription(
            mode="ephemeral", types={R.CL_HEARTBEAT}, auto_commit=False)),
    }
    feed_varied(logs, n_each=50)
    delivered = {name: Counter() for name in streams}
    for _ in range(60):
        moved = proxy.pump()
        pulled = 0
        for name, stream in streams.items():
            for pid, batch in stream.fetch(4096):
                delivered[name].update(
                    (pid, int(i)) for i in batch.indices())
                pulled += len(batch)
            stream.commit()
        proxy.flush_upstream()
        if not moved and not pulled:
            break
    stats = dict(proxy.stats)
    sess.close()
    return stats, delivered


def test_scalar_and_columnar_dispatch_stats_agree():
    col_stats, col_seen = run_dispatch_workload(force_scalar=False)
    sc_stats, sc_seen = run_dispatch_workload(force_scalar=True)
    assert col_seen == sc_seen                       # same records, same homes
    for key in ("ingested", "dispatched", "filtered_out", "ephemeral_drops",
                "dropped_by_modules", "redelivered"):
        assert col_stats[key] == sc_stats[key], \
            f"stats[{key}] drifted: columnar={col_stats[key]} " \
            f"scalar={sc_stats[key]}"
    # record-granular cross-check: dispatched == what the persistent
    # groups received (ephemeral hand-offs are counted separately,
    # under ephemeral_drops when nobody polls — never in dispatched)
    total_seen = sum(sum(c.values())
                     for name, c in col_seen.items() if name != "eph")
    assert col_stats["dispatched"] == total_seen


def test_zero_fill_opt_out_skips_the_scalar_remap():
    """A mixed-flags stream (some records lack CLF_METRICS) forces the
    default local remap onto its per-record zero-fill path.  A columnar
    consumer opting out (zero_fill=False) gets strip-only delivery:
    original flags survive untouched, and when the proxy projection
    already matched, the very same batch object — no copy at all."""
    mask = R.CLF_JOBID | R.CLF_SHARD | R.CLF_METRICS
    logs = mk_logs(1)
    proxy = LcapProxy(logs)
    sess = connect(proxy)
    filled = sess.subscribe(Subscription(group="filled", flags=mask,
                                         auto_commit=False))
    raw = sess.subscribe(Subscription(group="raw", flags=mask,
                                      auto_commit=False, zero_fill=False))
    feed_varied(logs, n_each=20, with_rename=False)
    proxy.pump()
    filled_flags, raw_flags = [], []
    for _pid, batch in filled.fetch(4096):
        filled_flags.extend(batch.flags_np().tolist())
    for _pid, batch in raw.fetch(4096):
        raw_flags.extend(batch.flags_np().tolist())
        # strip-only and nothing to strip: the unprojected wire batch
        assert not any(f & ~mask for f in batch.flags_np().tolist())
    assert len(filled_flags) == len(raw_flags) == 20
    # default: every requested extension materialized on every record
    assert all(f == mask for f in filled_flags)
    # opt-out: records that lacked an extension still lack it
    assert any(f != mask for f in raw_flags)
    assert {f & mask for f in raw_flags} == set(raw_flags)
    sess.close()


# ============================================================== metrics / lag
def test_proxy_lag_tracks_outstanding_and_converges():
    logs = mk_logs(1)
    proxy = LcapProxy(logs)
    sess = connect(proxy)
    stream = sess.subscribe(Subscription(group="g", auto_commit=False))
    log = logs["mdt0"]
    for i in range(20):
        log.log(R.ChangelogRecord(type=R.CL_CREATE, tfid=R.Fid(1, i, 0),
                                  name=b"f", time=T0))
    proxy.pump()
    lag0 = proxy.lag()["g"]["mdt0"]
    assert lag0["dispatch_hw"] == 20 and lag0["lag"] == 20
    fetched = stream.fetch(4096)
    lag1 = proxy.lag()["g"]["mdt0"]
    assert lag1["lag"] == 20 and lag1["in_flight"] == 20   # uncommitted
    stream.requeue(fetched)
    for _pid, _b in stream.fetch(4096):
        pass
    stream.commit()
    lag2 = proxy.lag()["g"]["mdt0"]
    assert lag2 == {"dispatch_hw": 20, "ack": 20, "lag": 0, "in_flight": 0}
    sess.close()


def test_metrics_and_lag_verbs_over_the_wire():
    logs = mk_logs(1)
    proxy = LcapProxy(logs)
    reg = MetricsRegistry()
    proxy.attach_registry(reg)
    service = LcapService(proxy).start()
    try:
        sess = connect(service.address)
        stream = sess.subscribe(Subscription(group="g", auto_commit=True))
        for i in range(10):
            logs["mdt0"].log(R.ChangelogRecord(
                type=R.CL_CREATE, tfid=R.Fid(1, i, 0), name=b"f", time=T0))
        seen = 0
        for _ in range(100):
            seen += sum(len(b) for _p, b in stream.fetch(64))
            if seen >= 10:
                break
        assert seen == 10
        remote = sess.metrics()
        assert remote["lcap_proxy_ingested_total"]["samples"][0][1] >= 10
        assert "lcap_pump_latency_seconds" in remote
        lag = sess.lag()
        assert lag["g"]["mdt0"]["lag"] >= 0
        # stats verb still serves the raw dict
        assert sess.stats()["ingested"] >= 10
        sess.close()
    finally:
        service.stop()


def test_transport_counters_when_instrumented():
    reg = MetricsRegistry()
    transport.instrument(reg)
    try:
        logs = mk_logs(1)
        proxy = LcapProxy(logs)
        service = LcapService(proxy).start()
        try:
            sess = connect(service.address)
            sess.stats()
            sess.close()
        finally:
            service.stop()
        snap = reg.snapshot()
        by_dir = {l["direction"]: v for l, v in
                  snap["lcap_transport_messages_total"]["samples"]}
        assert by_dir["sent"] >= 2 and by_dir["received"] >= 2
        assert all(v > 0 for _l, v in
                   snap["lcap_transport_bytes_total"]["samples"])
    finally:
        transport._METRICS = None        # don't leak into other tests


def test_cluster_session_aggregates_metrics_and_lag():
    logs = mk_logs(2)
    cluster = LcapCluster(logs, n_shards=2)
    reg = MetricsRegistry()
    cluster.attach_registry(reg)
    sess = connect(cluster)
    stream = sess.subscribe(Subscription(group="g", auto_commit=False))
    feed_varied(logs, n_each=30, with_rename=False)
    for _ in range(50):
        cluster.pump()
        moved = sum(len(b) for _p, b in stream.fetch(4096))
        stream.commit()
        if not moved:
            break
    lag = sess.lag()
    assert set(lag["per_shard"]) == {0, 1}
    assert lag["g"]["mdt0"]["lag"] == 0
    merged = cluster.metrics()
    assert merged["lcap_cluster_routed_total"]["samples"][0][1] == 60
    # per-shard gauges stayed distinguishable
    shards = {l.get("shard") for l, _v in
              merged["lcap_shard_alive"]["samples"]}
    assert shards == {"0", "1"}
    sess.close()


# ===================================================== lag across kill (sat 3)
def test_lag_across_shard_kill_never_negative_and_converges():
    logs = mk_logs(2)
    cluster = LcapCluster(logs, n_shards=3)
    sess = connect(cluster)
    stream = sess.subscribe(Subscription(group="g", auto_commit=False))
    feed_varied(logs, n_each=40, with_rename=False)
    # route + dispatch but do NOT commit: every shard holds in-flight
    cluster.pump()
    fetched = stream.fetch(1 << 30)
    assert fetched
    before = sess.lag()
    for pids in (v for k, v in before.items() if k != "per_shard"):
        for ent in pids.values():
            assert ent["lag"] >= 0

    cluster.kill_shard(0)
    # the dead shard's backlog was re-offered to survivors; lag must be
    # reported against the survivors' re-routed watermarks only
    after = sess.lag()
    assert set(after["per_shard"]) == {1, 2}
    for pids in (v for k, v in after.items() if k != "per_shard"):
        for ent in pids.values():
            assert ent["lag"] >= 0
    assert any(ent["lag"] > 0 for ent in after["g"].values())

    # drain: fetch (redeliveries included), commit, repeat -> lag hits 0
    stream.requeue(fetched)
    for _ in range(80):
        cluster.pump()
        moved = sum(len(b) for _p, b in stream.fetch(1 << 30))
        stream.commit()
        final = sess.lag()
        lags = [ent["lag"] for k, pids in final.items() if k != "per_shard"
                for ent in pids.values()]
        assert all(l >= 0 for l in lags)
        if not moved and all(l == 0 for l in lags):
            break
    else:
        pytest.fail(f"lag never converged to zero: {final}")
    sess.close()


# =================================== 4-shard equivalence vs MetricsDB (accept)
def test_cluster_aggregator_matches_metricsdb_sql(tmp_path):
    """Acceptance: a 4-shard cluster run with the aggregator attached
    reports per-(op, jobid, producer, shard-host, window) counters that
    exactly match an offline SQL aggregation (MetricsDB) of the same
    run."""
    logs = mk_logs(3)
    cluster = LcapCluster(logs, n_shards=4)
    db = str(tmp_path / "metrics.sqlite")
    mdb = MetricsDB(cluster, db)
    agg = ActivityAggregator(cluster, window_ns=WIN, retention=256)
    fed = feed_varied(logs, n_each=50)
    for _ in range(80):
        moved = cluster.pump()
        moved += mdb.poll(1 << 20)
        moved += agg.run_once()
        if not moved and all(log.first_index == log.last_index + 1
                             for log in logs.values()):
            break
    assert agg.stats["records"] == len(fed)

    sql = {}
    for (t, j, p, h, w, c, vs) in mdb.query(
            "SELECT type, jobid, producer, host, time / ? AS win, "
            "COUNT(*), COALESCE(SUM(m0), 0) FROM events "
            "GROUP BY type, jobid, producer, host, win", (WIN,)):
        sql[(w, (t, j, p, h))] = (c, vs)
    got = {}
    for w in agg.window_ids():
        for key, (c, vs) in agg.counters(w).items():
            got[(w, key)] = (c, vs)
    assert set(got) == set(sql)
    for key in sql:
        assert got[key][0] == sql[key][0], key
        assert got[key][1] == pytest.approx(sql[key][1]), key
    mdb.close()


# ==================================================================== export
_PROM_LINE = re.compile(
    r"^(# (HELP|TYPE) [a-zA-Z_:][a-zA-Z0-9_:]* .*"
    r"|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[a-zA-Z_][a-zA-Z0-9_]*="
    r"\"(?:[^\"\\]|\\.)*\"(,[a-zA-Z_][a-zA-Z0-9_]*=\"(?:[^\"\\]|\\.)*\")*\})?"
    r" -?[0-9.eE+\-]+(inf|nan)?)$")


def _assert_valid_exposition(text):
    assert text.endswith("\n")
    for line in text.rstrip("\n").split("\n"):
        assert _PROM_LINE.match(line), f"invalid exposition line: {line!r}"


def mk_observed_world():
    logs = mk_logs(2)
    proxy = LcapProxy(logs)
    reg = MetricsRegistry()
    proxy.attach_registry(reg)
    agg = ActivityAggregator(proxy, window_ns=WIN)
    reg.register_collector(agg.collector())
    feed_varied(logs, n_each=40)
    drain(proxy, agg)
    return logs, proxy, reg, agg


def test_prometheus_render_is_valid_exposition_format():
    _logs, _proxy, reg, _agg = mk_observed_world()
    text = render_prometheus(reg.snapshot())
    _assert_valid_exposition(text)
    assert "# TYPE lcap_proxy_dispatched_total counter" in text
    assert "# TYPE lcap_pump_latency_seconds histogram" in text
    assert 'le="+Inf"' in text
    assert re.search(r'lcap_window_records\{[^}]*jobid="job-0"', text)
    # label escaping
    weird = {"m": {"type": "gauge", "help": "quote \" test",
                   "samples": [[{"l": 'a"b\\c\nd'}, 1]]}}
    _assert_valid_exposition(render_prometheus(weird))


def test_prometheus_http_endpoint_serves_scrapes():
    _logs, _proxy, reg, _agg = mk_observed_world()
    exporter = PrometheusExporter(registry=reg).start()
    try:
        with urllib.request.urlopen(exporter.url, timeout=5) as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"].startswith("text/plain")
            body = resp.read().decode()
        _assert_valid_exposition(body)
        assert "lcap_proxy_ingested_total" in body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                exporter.url.replace("/metrics", "/nope"), timeout=5)
    finally:
        exporter.stop()


def test_ganglia_pusher_maps_names_like_gmond():
    _logs, _proxy, reg, _agg = mk_observed_world()
    pusher = GangliaPusher(registry=reg)
    n = pusher.push()
    assert n == len(pusher.sent) > 0
    names = {m["name"] for m in pusher.sent}
    assert any(name.startswith("lcap.dispatched") for name in names)
    assert any(".count" in name for name in names)       # histogram split
    for m in pusher.sent:
        assert set(m) == {"name", "value", "type", "units", "group"}
        assert m["type"] in ("counter", "gauge")
        assert re.match(r"^[A-Za-z0-9_.\-]+$", m["name"]), m["name"]


# ================================================================== dashboard
def test_dashboard_renders_all_sections():
    logs = mk_logs(2)
    cluster = LcapCluster(logs, n_shards=2)
    sess = connect(cluster)
    agg = ActivityAggregator(cluster, window_ns=WIN)
    feed_varied(logs, n_each=30, with_rename=False)
    for _ in range(40):
        moved = cluster.pump()
        moved += agg.run_once()
        if not moved:
            break
    # sliding=10 spans every retained pane: feed_varied's newest pane
    # (i % 10 == 9) only carries jobid-less records (9 ≡ 4 mod 5), so a
    # 1-pane view would legitimately show just the empty jobid
    top = ActivityTop(agg, session=sess, cluster=cluster, k=3, sliding=10)
    frame = top.render()
    assert "lcap top" in frame
    assert "BUSIEST JOBS" in frame and "job-0" in frame
    assert "BUSIEST OPS" in frame
    assert "CONSUMER LAG" in frame and "obs" in frame
    assert "shard0[UP" in frame and "shard1[UP" in frame
    snap = top.snapshot()
    assert snap["lag"]["obs"]["mdt0"]["lag"] == 0
    cluster.kill_shard(1)
    assert "shard1[DOWN" in top.render()
    sess.close()
