"""Record format: pack/unpack round-trip, offsets, remapping (paper §IV-A)."""

import struct

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import records as R


def mk(name=b"file0", **kw):
    return R.ChangelogRecord(type=R.CL_CREATE, index=7, prev=3, time=123456789,
                             tfid=R.Fid(1, 2, 3), pfid=R.Fid(4, 5, 6),
                             name=name, **kw)


def test_header_is_64_bytes():
    assert R.HDR_SIZE == 64
    assert len(R.pack(R.ChangelogRecord())) == 64


def test_roundtrip_minimal():
    rec = mk()
    out = R.unpack(R.pack(rec))
    assert out.type == R.CL_CREATE and out.index == 7 and out.prev == 3
    assert out.tfid == R.Fid(1, 2, 3) and out.pfid == R.Fid(4, 5, 6)
    assert out.name == b"file0" and out.flags == 0


def test_roundtrip_all_extensions():
    rec = mk(sfid=R.Fid(9, 9, 9), spfid=R.Fid(8, 8, 8), sname=b"oldname",
             jobid=b"train-step-17", shard=(1, 12, 3, 4),
             metrics=(1.5, -2.25), xattr={"k": "v", "n": 3})
    out = R.unpack(R.pack(rec))
    assert out.sfid == R.Fid(9, 9, 9) and out.spfid == R.Fid(8, 8, 8)
    assert out.sname == b"oldname"
    assert out.jobid == b"train-step-17"
    assert out.shard == (1, 12, 3, 4)
    assert out.metrics == (1.5, -2.25)
    assert out.xattr == {"k": "v", "n": 3}
    assert out.flags == R.CLF_SUPPORTED


def test_offsets_skip_absent_fields():
    """No disk/bandwidth is spent on fields a record does not carry."""
    small = R.pack(mk())
    with_jobid = R.pack(mk(jobid=b"j"))
    assert len(with_jobid) == len(small) + 32
    # jobid lives immediately after the header when CLF_RENAME is absent
    assert R.rec_offset(R.CLF_JOBID, R.CLF_JOBID) == R.HDR_SIZE
    # ...and after the two extra fids when it is present
    assert R.rec_offset(R.CLF_RENAME | R.CLF_JOBID, R.CLF_JOBID) == R.HDR_SIZE + 32


def test_remap_strip_fields():
    """Remote remap: newer server -> older client drops unknown fields."""
    buf = R.pack(mk(jobid=b"job42", metrics=(3.0,)))
    old = R.remap(buf, R.CLF_V20)
    rec = R.unpack(old)
    assert rec.jobid is None and rec.metrics is None
    assert rec.name == b"file0" and rec.index == 7
    assert len(old) < len(buf)


def test_remap_add_fields_zero_filled():
    """Local remap: older server -> newer client zero-fills."""
    buf = R.pack(mk())
    new = R.remap(buf, R.CLF_JOBID | R.CLF_SHARD)
    rec = R.unpack(new)
    assert rec.jobid == b""            # zero-filled, stripped of NULs
    assert rec.shard == (0, 0, 0, 0)
    assert rec.name == b"file0"


def test_remap_rename_tail_handling():
    rec = mk(sfid=R.Fid(1, 1, 1), spfid=R.Fid(2, 2, 2), sname=b"src")
    buf = R.pack(rec)
    # strip rename: sname tail must go away with the fids
    stripped = R.unpack(R.remap(buf, 0))
    assert stripped.sfid is None and stripped.sname == b""
    assert stripped.name == b"file0"
    # add rename to a record without it: NUL + empty sname
    plain = R.pack(mk())
    added = R.unpack(R.remap(plain, R.CLF_RENAME))
    assert added.sfid == R.Fid(0, 0, 0) and added.sname == b""


def test_remap_identity_is_noop():
    buf = R.pack(mk(jobid=b"x"))
    assert R.remap(buf, R.CLF_JOBID) is buf


def test_v27_compat_mask():
    """The v2.7 struct (fig. 3) == rename fids + jobid."""
    rec = mk(sfid=R.Fid(0, 0, 0), spfid=R.Fid(0, 0, 0), jobid=b"qsub-1",
             metrics=(9.0,))
    v27 = R.unpack(R.remap(R.pack(rec), R.CLF_V27))
    assert v27.jobid == b"qsub-1" and v27.metrics is None


# ------------------------------------------------------------- RecordBatch
def test_batch_zero_copy_header_columns():
    recs = [mk(name=b"n%d" % i, jobid=b"J%d" % i) for i in range(8)]
    for i, r in enumerate(recs):
        r.index = i + 1
        r.tfid = R.Fid(1, i, 0)
    batch = R.RecordBatch.from_records(recs)
    assert len(batch) == 8
    assert batch.indices() == list(range(1, 9))
    assert batch.types() == [R.CL_CREATE] * 8
    assert batch.keys() == [(1, i, 0) for i in range(8)]
    assert batch.packed_flags(0) == R.CLF_JOBID
    # iteration yields the packed bytes (list-of-bytes compatible)
    assert [R.unpack(b).name for b in batch] == [b"n%d" % i for i in range(8)]


def test_batch_select_is_view_and_preserves_rows():
    batch = R.RecordBatch.from_records(
        [mk(name=b"x%d" % i) for i in range(5)])
    for i in range(5):
        assert batch.record(i).name == b"x%d" % i
    sub = batch.select([4, 2, 0])
    assert sub.buf is batch.buf                  # shared payload buffer
    assert [r.name for r in sub.to_records()] == [b"x4", b"x2", b"x0"]
    assert len(batch) == 5                       # original untouched


def test_batch_wire_roundtrip():
    batch = R.RecordBatch.from_records(
        [mk(name=b"w%d" % i, metrics=(float(i),)) for i in range(6)])
    out = R.RecordBatch.from_wire(batch.to_wire())
    assert out == batch
    assert out.nbytes == batch.nbytes
    assert R.RecordBatch.from_wire(R.RecordBatch.empty().to_wire()) == []


def test_batch_lazy_decode_caches():
    batch = R.RecordBatch.from_records([mk(xattr={"k": 1})])
    assert batch.record(0) is batch.record(0)
    assert batch.record(0).xattr == {"k": 1}


def test_batch_remap_uses_plan_and_matches_generic():
    batch = R.RecordBatch.from_records(
        [mk(jobid=b"J"), mk(shard=(1, 2, 3, 4)), mk()])
    out = batch.remap(R.CLF_JOBID)
    for i in range(len(batch)):
        assert out.packed(i) == R.remap(batch.packed(i), R.CLF_JOBID)
    # all-match fast path returns the same object
    uniform = R.RecordBatch.from_records([mk(jobid=b"a"), mk(jobid=b"b")])
    assert uniform.remap(R.CLF_JOBID) is uniform


def test_remap_cached_exhaustive_all_mask_pairs():
    """Satellite: remap round-trips across all 32 x 32 flag-mask pairs —
    cached plans agree byte-for-byte with the generic path, and fields
    surviving both masks round-trip."""
    for src in range(R.CLF_SUPPORTED + 1):
        rec = mk()
        if src & R.CLF_RENAME:
            rec.sfid, rec.spfid, rec.sname = (R.Fid(1, 1, 1),
                                              R.Fid(2, 2, 2), b"s")
        if src & R.CLF_JOBID:
            rec.jobid = b"JOB"
        if src & R.CLF_SHARD:
            rec.shard = (1, 2, 3, 4)
        if src & R.CLF_METRICS:
            rec.metrics = (1.5, -2.0)
        if src & R.CLF_XATTR:
            rec.xattr = {"a": 1}
        buf = R.pack(rec)
        for dst in range(R.CLF_SUPPORTED + 1):
            out = R.remap_cached(buf, dst)
            assert out == R.remap(buf, dst), (src, dst)
            assert R.packed_flags(out) == dst
            parsed = R.unpack(out)
            assert parsed.name == rec.name and parsed.index == rec.index
            if src & dst & R.CLF_JOBID:
                assert parsed.jobid == b"JOB"
            if src & dst & R.CLF_SHARD:
                assert parsed.shard == (1, 2, 3, 4)
            if src & dst & R.CLF_METRICS:
                assert parsed.metrics == (1.5, -2.0)
            if src & dst & R.CLF_XATTR:
                assert parsed.xattr == {"a": 1}
            if src & dst & R.CLF_RENAME:
                assert parsed.sfid == R.Fid(1, 1, 1)
            # remapping back preserves everything in src & dst
            back = R.unpack(R.remap_cached(out, src & dst))
            assert back.name == rec.name


if not HAVE_HYPOTHESIS:                   # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_roundtrip():
        ...

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_remap_masks():
        ...

else:
    names = st.binary(min_size=0, max_size=64).filter(lambda b: b"\0" not in b)
    fids = st.builds(R.Fid, st.integers(0, 2**64 - 1),
                     st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))

    @settings(max_examples=200, deadline=None)
    @given(
        rtype=st.sampled_from(sorted(R.TYPE_NAMES)),
        index=st.integers(0, 2**63), tfid=fids, pfid=fids, name=names,
        jobid=st.none() | st.binary(max_size=32),
        shard=st.none() | st.tuples(*[st.integers(0, 2**16 - 1)] * 4),
        metrics=st.none() | st.tuples(st.floats(allow_nan=False)),
        rename=st.booleans(), sname=names,
    )
    def test_property_roundtrip(rtype, index, tfid, pfid, name, jobid, shard,
                                metrics, rename, sname):
        rec = R.ChangelogRecord(type=rtype, index=index, tfid=tfid, pfid=pfid,
                                name=name, jobid=jobid, shard=shard,
                                metrics=metrics)
        if rename:
            rec.sfid, rec.spfid, rec.sname = (R.Fid(1, 2, 3), R.Fid(4, 5, 6),
                                              sname)
        out = R.unpack(R.pack(rec))
        assert out.name == name and out.type == rtype and out.index == index
        assert out.jobid == (jobid.rstrip(b"\0") if jobid is not None
                             else None)
        assert out.shard == shard
        assert out.metrics == metrics
        if rename:
            assert out.sname == sname

    @settings(max_examples=200, deadline=None)
    @given(src=st.integers(0, R.CLF_SUPPORTED),
           dst=st.integers(0, R.CLF_SUPPORTED))
    def test_property_remap_masks(src, dst):
        """remap is total over all (src, dst) flag-mask pairs and the result
        parses with exactly the dst mask."""
        rec = mk()
        if src & R.CLF_RENAME:
            rec.sfid, rec.spfid, rec.sname = (R.Fid(1, 1, 1), R.Fid(2, 2, 2),
                                              b"s")
        if src & R.CLF_JOBID:
            rec.jobid = b"J"
        if src & R.CLF_SHARD:
            rec.shard = (1, 2, 3, 4)
        if src & R.CLF_METRICS:
            rec.metrics = (1.0, 2.0)
        if src & R.CLF_XATTR:
            rec.xattr = {"a": 1}
        buf = R.pack(rec)
        assert R.packed_flags(buf) == src
        out = R.remap(buf, dst)
        assert R.packed_flags(out) == dst
        parsed = R.unpack(out)
        assert parsed.name == rec.name
        if src & dst & R.CLF_JOBID:
            assert parsed.jobid == b"J"
        if src & dst & R.CLF_METRICS:
            assert parsed.metrics == (1.0, 2.0)
        # double remap to the same mask is idempotent
        assert R.remap(out, dst) == R.remap(R.remap(out, dst), dst)
