"""Optimizer + distributed-optimization tricks: AdamW descent, cosine
schedule, clipping; error-feedback int8 gradient compression across a
shard_map DP axis (convergence parity with exact psum)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


def test_adamw_descends_quadratic():
    params = {"w": jnp.zeros(4), "b": jnp.zeros(3)}
    state = adamw.init(params)
    for _ in range(200):
        grads = jax.grad(quad_loss)(params)
        params, state, gnorm = adamw.update(grads, state, params, lr=5e-2,
                                            weight_decay=0.0)
    assert quad_loss(params) < 1e-2
    assert int(state.step) == 200


def test_cosine_schedule_shape():
    lrs = [float(adamw.cosine_lr(jnp.asarray(s), peak=1.0, warmup=10,
                                 total=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6          # warmup rises
    assert abs(max(lrs) - 1.0) < 0.11             # hits peak
    assert lrs[-1] < 0.2                          # decays
    assert lrs[-1] >= 0.099                       # floor


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 100.0)}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(200.0)
    norm = float(jnp.linalg.norm(clipped["a"]))
    assert norm == pytest.approx(1.0, rel=1e-3)


def test_compressed_psum_matches_exact_within_tolerance():
    """int8 EF compression: single-step error bounded; multi-step error
    feedback keeps the *accumulated* descent direction unbiased."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.optim.compress import compressed_psum, plain_psum_mean

        if hasattr(jax, "shard_map"):                # jax >= 0.5
            shard_map, replication_kw = jax.shard_map, {"check_vma": False}
        else:
            from jax.experimental.shard_map import shard_map
            replication_kw = {"check_rep": False}

        mesh = jax.make_mesh((4,), ("dp",))
        key = jax.random.PRNGKey(0)
        g_global = jax.random.normal(key, (4, 64))   # per-device grads

        @partial(shard_map, mesh=mesh, in_specs=(P("dp"), P("dp")),
                 out_specs=(P("dp"), P("dp")), **replication_kw)
        def step(g, e):
            gq, e = compressed_psum({"g": g}, {"g": e}, "dp")
            return gq["g"], e["g"]

        exact = np.asarray(g_global.mean(0))
        err = jnp.zeros((4, 64))
        acc_q = np.zeros(64)
        for it in range(8):
            gq, err = step(g_global, err)
            gq0 = np.asarray(gq[0:1]).reshape(-1)
            acc_q += gq0
            # single-step quantization error is bounded by the int8 grid
            assert np.max(np.abs(gq0 - exact)) < 0.05, it
        # with error feedback the mean of quantized steps converges
        assert np.max(np.abs(acc_q / 8 - exact)) < 0.02
        print("OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_compression_ratio_is_8x():
    """int8 payload is 4x smaller than f32 per element (8x vs f64) —
    verify the wire-size arithmetic used in DESIGN.md."""
    from repro.optim.compress import _quantize
    g = jnp.linspace(-1, 1, 1024)
    q, scale = _quantize(g)
    assert q.dtype == jnp.int8 and q.nbytes * 4 == g.nbytes
    deq = q.astype(jnp.float32) * scale
    assert float(jnp.max(jnp.abs(deq - g))) < 1.0 / 127
