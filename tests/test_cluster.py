"""Sharded LCAP cluster (tentpole): FID-hash routing determinism,
fan-in subscriptions over every shard, collective upstream ack across
shards, and shard failure -> slot re-routing + backlog redelivery with
at-least-once delivery preserved."""

import time

import pytest

from repro.core import records as R
from repro.core.cluster import (DEFAULT_SLOTS, LcapCluster,
                                LcapClusterService, fid_slot)
from repro.core.errors import ClusterError
from repro.core.llog import Llog
from repro.core.session import Subscription, connect


def rec(oid=1, ver=0, t=R.CL_CREATE, name=b"f", **kw):
    return R.ChangelogRecord(type=t, tfid=R.Fid(1, oid, ver),
                             pfid=R.Fid(1, 0, 0), name=name, **kw)


def mk_cluster(n_producers=2, n_shards=3, **kw):
    logs = {f"mdt{i}": Llog(f"mdt{i}") for i in range(n_producers)}
    return LcapCluster(logs, n_shards=n_shards, **kw), logs


def feed(logs, n_each=20, oids=7):
    for pid, log in logs.items():
        for i in range(n_each):
            log.log(rec(oid=i % oids, name=f"{pid}-{i}".encode()))


def drain_until(cluster, stream, logs, expect, rounds=200):
    """Pump + fetch + commit until ``expect`` (pid, index) pairs were
    seen and every journal trimmed; returns the seen set."""
    seen = set()
    for _ in range(rounds):
        cluster.pump()
        moved = 0
        for pid, batch in stream.fetch(4096):
            seen.update((pid, i) for i in batch.indices())
            moved += len(batch)
        stream.commit()
        if not moved and seen >= expect and all(
                log.first_index == log.last_index + 1
                for log in logs.values()):
            break
    return seen


# ------------------------------------------------------------- routing
def test_fid_slot_is_deterministic_and_uniform():
    keys = [(s, o, v) for s in range(3) for o in range(40) for v in range(3)]
    slots = [fid_slot(k) for k in keys]
    assert slots == [fid_slot(k) for k in keys]       # stable across calls
    assert all(0 <= s < DEFAULT_SLOTS for s in slots)
    hit = set(slots)
    assert len(hit) > DEFAULT_SLOTS // 2              # spreads, no clumping


def test_records_of_one_target_never_split_across_shards():
    """cr_prev chains stay intact: every record of one target FID lands
    on the same shard, so per-target ordering is preserved."""
    cluster, logs = mk_cluster(n_producers=2, n_shards=4)
    sess = connect(cluster)
    stream = sess.subscribe("g", auto_commit=False)
    feed(logs, 40, oids=11)
    owner_by_target = {}
    for _ in range(50):
        cluster.pump()
        moved = 0
        # fetch from each child separately to observe the owning shard
        for shard_idx, child in stream._children:
            for pid, batch in child.fetch(4096):
                for i in range(len(batch)):
                    key = (pid,) + tuple(batch.packed_tfid(i))
                    prev = owner_by_target.setdefault(key, shard_idx)
                    assert prev == shard_idx, \
                        f"target {key} split across shards {prev}/{shard_idx}"
                moved += len(batch)
        stream.commit()
        if not moved and all(log.first_index == log.last_index + 1
                             for log in logs.values()):
            break
    assert owner_by_target                       # something was routed
    assert len({s for s in owner_by_target.values()}) > 1  # actually sharded
    # the routing matches the cluster's published slot map
    for (pid, seq, oid, ver), shard in owner_by_target.items():
        assert cluster.shard_of((seq, oid, ver)) == shard


def test_per_target_order_is_preserved_within_a_shard():
    cluster, logs = mk_cluster(n_producers=1, n_shards=3)
    sess = connect(cluster)
    stream = sess.subscribe("g", auto_commit=False)
    feed(logs, 60, oids=5)
    order_by_target = {}
    for _ in range(50):
        cluster.pump()
        moved = 0
        for pid, batch in stream.fetch(4096):
            for i in range(len(batch)):
                key = batch.packed_tfid(i)
                order_by_target.setdefault(key, []).append(
                    batch.packed_index(i))
            moved += len(batch)
        stream.commit()
        if not moved:
            break
    for key, indices in order_by_target.items():
        assert indices == sorted(indices), key


# ------------------------------------------------------- fan-in + acks
def test_every_group_sees_every_record_and_all_journals_trim():
    cluster, logs = mk_cluster(n_producers=3, n_shards=3)
    sess = connect(cluster)
    s1 = sess.subscribe("g1", auto_commit=False)
    s2 = sess.subscribe("g2", auto_commit=False)
    feed(logs, 25)
    expect = {(pid, i) for pid in logs for i in range(1, 26)}
    seen1, seen2 = set(), set()
    for _ in range(200):
        cluster.pump()
        moved = 0
        for stream, seen in ((s1, seen1), (s2, seen2)):
            for pid, batch in stream.fetch(4096):
                for i in batch.indices():
                    assert (pid, i) not in seen   # exactly once per group
                    seen.add((pid, i))
                moved += len(batch)
            stream.commit()
        if not moved and seen1 == expect and seen2 == expect:
            break
    assert seen1 == expect and seen2 == expect
    # cross-shard collective ack: min watermark across shards trims
    # every journal completely
    for log in logs.values():
        assert log.first_index == log.last_index + 1


def test_fan_in_load_balances_one_group_across_members():
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    sess = connect(cluster)
    members = [sess.subscribe("g", auto_commit=False) for _ in range(3)]
    feed(logs, 90, oids=30)
    counts = [0] * len(members)
    for _ in range(100):
        cluster.pump()
        moved = 0
        for k, stream in enumerate(members):
            for pid, batch in stream.fetch(4096):
                counts[k] += len(batch)
                moved += len(batch)
            stream.commit()
        if not moved and sum(counts) >= 90:
            break
    assert sum(counts) == 90
    assert all(c > 0 for c in counts)     # spread across the group


def test_producer_registered_once_late_producer_routes():
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    sess = connect(cluster)
    stream = sess.subscribe("g", auto_commit=False)
    extra = Llog("late")
    cluster.add_producer("late", extra)
    extra.log(rec(oid=3))
    feed(logs, 2)
    expect = {("mdt0", 1), ("mdt0", 2), ("late", 1)}
    seen = drain_until(cluster, stream, {**logs, "late": extra}, expect)
    assert seen == expect
    assert extra.first_index == extra.last_index + 1


def test_ephemeral_subscription_fans_in_without_blocking_trim():
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    sess = connect(cluster)
    group = sess.subscribe("g", auto_commit=False)
    feed(logs, 5)                          # history
    cluster.pump()
    eph = sess.subscribe(mode="ephemeral", auto_commit=False)
    for i in range(5, 8):
        logs["mdt0"].log(rec(oid=i))
    expect = {("mdt0", i) for i in range(1, 9)}
    seen = drain_until(cluster, group, logs, expect)
    assert seen == expect
    got = {i for _, b in eph.fetch(4096) for i in b.indices()}
    assert got.issubset({6, 7, 8})         # no history (§IV-B)
    # the ephemeral never acked, yet every journal trimmed
    assert logs["mdt0"].first_index == logs["mdt0"].last_index + 1


# ------------------------------------------------------------- failure
def test_shard_kill_redelivers_backlog_no_loss_and_trims():
    cluster, logs = mk_cluster(n_producers=2, n_shards=3)
    sess = connect(cluster)
    stream = sess.subscribe("g", auto_commit=False)
    feed(logs, 50, oids=17)
    cluster.pump()
    # fetch some records without committing: they are in flight on
    # their shards when shard 0 dies
    precrash = stream.fetch(30)
    seen = {(pid, i) for pid, b in precrash for i in b.indices()}
    cluster.kill_shard(0)
    assert cluster.alive[0] is False
    assert all(owner != 0 for owner in cluster.slot_owner)  # re-routed
    stream.commit()                        # acks for shard 0 are dropped
    expect = {(pid, i) for pid in logs for i in range(1, 51)}
    for _ in range(200):
        cluster.pump()
        moved = 0
        for pid, batch in stream.fetch(4096):
            seen.update((pid, i) for i in batch.indices())
            moved += len(batch)
        stream.commit()
        if not moved and seen >= expect and all(
                log.first_index == log.last_index + 1
                for log in logs.values()):
            break
    assert expect - seen == set()          # at-least-once: nothing lost
    assert stream.lost == [0]              # fan-in dropped the dead child
    for log in logs.values():              # dead shard no longer gates trim
        assert log.first_index == log.last_index + 1
    assert cluster.stats["shards_failed"] == 1
    assert cluster.stats["failover_redelivered"] > 0


def test_new_records_after_kill_route_to_survivors():
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    sess = connect(cluster)
    stream = sess.subscribe("g", auto_commit=False)
    cluster.kill_shard(1)
    feed(logs, 20, oids=19)                # all slots now owned by shard 0
    expect = {("mdt0", i) for i in range(1, 21)}
    seen = drain_until(cluster, stream, logs, expect)
    assert seen == expect


def test_killing_the_last_shard_raises():
    cluster, logs = mk_cluster(n_producers=1, n_shards=1)
    with pytest.raises(ClusterError):
        cluster.kill_shard(0)


def test_subscribe_after_kill_attaches_only_to_survivors():
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    cluster.kill_shard(0)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    assert stream.shards == [1]


# ------------------------------------------------------------- daemons
def test_cluster_service_wire_fan_in_and_shard_aware_subscribe():
    logs = {f"h{i}": Llog(f"h{i}") for i in range(2)}
    cluster = LcapCluster(logs, n_shards=2)
    svc = LcapClusterService(cluster).start()
    try:
        assert len(svc.addresses) == 2     # each shard its own daemon
        sess = connect(svc)
        stream = sess.subscribe(Subscription(group="g", auto_commit=False))
        # the cluster-aware subscribe verb stamped each shard's position
        assert sorted(stream.shards) == [0, 1]
        for pid, log in logs.items():
            for i in range(30):
                log.log(rec(oid=i % 5, name=b"wire"))
        expect = {(pid, i) for pid in logs for i in range(1, 31)}
        seen = set()
        deadline = time.time() + 20
        while time.time() < deadline:
            moved = 0
            for pid, batch in stream.fetch(4096):
                seen.update((pid, i) for i in batch.indices())
                moved += len(batch)
            stream.commit()
            if seen == expect and all(log.first_index == log.last_index + 1
                                      for log in logs.values()):
                break
            if not moved:
                time.sleep(0.005)
        assert seen == expect
        for log in logs.values():
            assert log.first_index == log.last_index + 1
        sess.close()
    finally:
        svc.stop()


def test_cluster_stats_aggregate_across_shards():
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    sess = connect(cluster)
    stream = sess.subscribe("g", auto_commit=False)
    feed(logs, 10)
    expect = {("mdt0", i) for i in range(1, 11)}
    drain_until(cluster, stream, logs, expect)
    stats = sess.stats()
    assert stats["dispatched"] == 10       # summed across both shards
    assert set(stats["per_shard"]) == {0, 1}
