"""Client/server operation over TCP (paper fig. 1): remote readers,
load-balanced groups over the network, crash-disconnect redelivery."""

import time

import pytest

from repro.core import records as R
from repro.core.llog import Llog
from repro.core.proxy import LcapProxy
from repro.core.reader import RemoteReader
from repro.core.server import LcapService


def rec(oid, name=b"f"):
    return R.ChangelogRecord(type=R.CL_CREATE, tfid=R.Fid(1, oid, 0),
                             pfid=R.Fid(1, 0, 0), name=name,
                             jobid=b"job-%d" % oid)


@pytest.fixture()
def service():
    logs = {"mdt0": Llog("mdt0"), "mdt1": Llog("mdt1")}
    proxy = LcapProxy(logs)
    svc = LcapService(proxy, poll_interval=0.001).start()
    yield svc, logs
    svc.stop()


def fetch_until(reader, want, timeout=5.0):
    got = []
    deadline = time.time() + timeout
    while len(got) < want and time.time() < deadline:
        batch = reader.fetch()
        if batch:
            got.extend(batch)
        else:
            time.sleep(0.002)
    return got


def test_remote_roundtrip_and_ack(service):
    svc, logs = service
    r = RemoteReader(svc.address, "g")
    for i in range(10):
        logs["mdt0"].log(rec(i))
        logs["mdt1"].log(rec(i))
    got = fetch_until(r, 20)
    assert len(got) == 20
    assert {pid for pid, _ in got} == {"mdt0", "mdt1"}
    for pid, record in got:
        r.ack(pid, record.index)
    deadline = time.time() + 5
    while logs["mdt0"].first_index != 11 and time.time() < deadline:
        time.sleep(0.005)
    assert logs["mdt0"].first_index == 11
    assert logs["mdt1"].first_index == 11
    r.close()


def test_remote_group_load_balancing(service):
    svc, logs = service
    rs = [RemoteReader(svc.address, "g") for _ in range(3)]
    for i in range(60):
        logs["mdt0"].log(rec(i))
    per = [fetch_until(r, 60 // 3 - 5) for r in rs]
    total = sum(len(p) for p in per)
    # give stragglers one more chance to drain the remainder
    deadline = time.time() + 5
    while total < 60 and time.time() < deadline:
        for r, p in zip(rs, per):
            p.extend(r.fetch())
        total = sum(len(p) for p in per)
    assert total == 60
    assert all(len(p) > 0 for p in per)
    for r in rs:
        r.close()


def test_remote_flags_strip(service):
    svc, logs = service
    old = RemoteReader(svc.address, "old", flags=0)
    logs["mdt0"].log(rec(1))
    (pid, record), = fetch_until(old, 1)
    assert record.jobid is None           # stripped remotely
    old.close()


def test_crash_disconnect_triggers_redelivery(service):
    svc, logs = service
    a = RemoteReader(svc.address, "g")
    b = RemoteReader(svc.address, "g")
    for i in range(30):
        logs["mdt0"].log(rec(i))
    got_a = fetch_until(a, 10)
    assert got_a
    a.close(failed=True)                  # socket drop, no deregister
    seen = {r.index for _, r in fetch_until(b, 30, timeout=10)}
    deadline = time.time() + 10
    while len(seen) < 30 and time.time() < deadline:
        seen |= {r.index for _, r in b.fetch()}
        time.sleep(0.005)
    assert seen == set(range(1, 31))
    b.close()


def test_remote_error_reporting(service):
    svc, _ = service
    r = RemoteReader(svc.address, "g")
    reply = r.rpc.call({"op": "ack", "cid": "nope", "pid": "mdt0", "index": 1})
    assert "err" in reply
    r.close()
