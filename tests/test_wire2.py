"""Wire v2 (column-bearing record frames): roundtrip equivalence vs
v1, zero-copy receive, the vectorized remap/project rebuild, and the
per-connection negotiation fallback that keeps old peers on v1."""

import time

import numpy as np
import pytest

from repro.core import records as R
from repro.core.cluster import (LcapCluster, LcapClusterService,
                                RemoteShard)
from repro.core.llog import Llog
from repro.core.proxy import LcapProxy
from repro.core.server import LcapService
from repro.core.session import Subscription, connect
from repro.track.consumers import MetricsDB


def mk(i, **kw):
    kw.setdefault("type", R.CL_STEP_COMMIT)
    kw.setdefault("tfid", R.Fid(5, 100 + i, i))
    kw.setdefault("pfid", R.Fid(7, 8, 9))
    return R.ChangelogRecord(index=i, time=1000 + i, name=b"n%d" % i, **kw)


def mixed_batch():
    """One record per extension shape, including both variable-size
    extensions and a rename tail."""
    return R.RecordBatch.from_records([
        mk(1),
        mk(2, jobid=b"job-a", shard=(1, 2, 3, 4)),
        mk(3, metrics=(0.5, 1.25, 4096.0), xattr={"n": 3}),
        mk(4, sfid=R.Fid(1, 2, 3), spfid=R.Fid(4, 5, 6), sname=b"oldname",
           jobid=b"job-b", metrics=(9.0,), xattr={}),
        mk(5, shard=(0, 1, 0, 0), xattr={"k": "v", "z": [1, 2]}),
        mk(6, jobid=b"x" * 32, metrics=()),
    ])


# ---------------------------------------------------------------- frames
def test_wire2_roundtrip_equivalence_vs_v1():
    batch = mixed_batch()
    v1 = R.RecordBatch.from_wire(batch.to_wire())
    v2 = R.RecordBatch.from_wire(batch.to_wire(version=R.WIRE_V2))
    assert v1 == batch and v2 == batch
    assert list(v1) == list(v2)                  # payload bit-for-bit
    # the shipped header table matches the re-gathered one exactly
    assert np.array_equal(v2.header(), v1.header())
    assert np.array_equal(v2.header(), batch.header())


def test_wire2_empty_batch():
    e = R.RecordBatch.empty()
    out = R.RecordBatch.from_wire(e.to_wire(version=R.WIRE_V2))
    assert len(out) == 0 and out == e
    assert len(out.header()) == 0


def test_wire2_u64_edge_fids():
    batch = R.RecordBatch.from_records([
        mk(1, tfid=R.Fid(2**64 - 1, 2**32 - 1, 2**32 - 1)),
        R.ChangelogRecord(type=R.CL_MARK, index=2**64 - 1,
                          time=2**64 - 1, tfid=R.Fid(0, 0, 0)),
        mk(3, tfid=R.Fid(2**63, 1, 2**31)),
    ])
    out = R.RecordBatch.from_wire(batch.to_wire(version=R.WIRE_V2))
    assert out == batch
    seq, oid, ver = out.tfid_cols()
    assert seq.tolist() == [2**64 - 1, 0, 2**63]
    assert out.indices_np().tolist() == [1, 2**64 - 1, 3]


def test_wire2_rename_records_keep_sname_tail():
    batch = R.RecordBatch.from_records([
        R.ChangelogRecord(type=R.CL_RENAME, index=1, tfid=R.Fid(1, 2, 3),
                          name=b"to-there", sfid=R.Fid(9, 9, 9),
                          spfid=R.Fid(8, 8, 8), sname=b"from-here"),
    ])
    out = R.RecordBatch.from_wire(batch.to_wire(version=R.WIRE_V2))
    rec = out.record(0)
    assert rec.sname == b"from-here" and rec.name == b"to-there"
    assert rec.sfid == R.Fid(9, 9, 9)


def test_wire2_attaches_columns_without_regather():
    batch = mixed_batch()
    out = R.RecordBatch.from_wire(batch.to_wire(version=R.WIRE_V2))
    # the columns arrive attached — no lazy gather pending
    assert out._hdr is not None
    assert np.array_equal(out._hdr, batch.header())
    # and no record was ever decoded to produce them
    assert out._recs == {}


def test_from_wire_readonly_memoryview_is_zero_copy():
    batch = mixed_batch()
    for version in (R.WIRE_V1, R.WIRE_V2):
        frame = batch.to_wire(version=version)
        mv = memoryview(frame).toreadonly()
        out = R.RecordBatch.from_wire(mv)
        assert type(out.buf) is memoryview       # no bytes(frame) copy
        assert out == batch
        # columnar accessors work straight off the view
        assert np.array_equal(out.header(), batch.header())
        assert out.name_col() == batch.name_col()
    # a writable buffer is still frozen defensively
    out = R.RecordBatch.from_wire(bytearray(batch.to_wire()))
    assert type(out.buf) is bytes and out == batch


# ------------------------------------------------- vectorized remap path
def test_vectorized_remap_project_match_per_record_reference():
    batch = mixed_batch()
    for dst in range(R.CLF_SUPPORTED + 1):
        out = batch.remap(dst)
        ref = [R.remap(batch.packed(i), dst) for i in range(len(batch))]
        assert list(out) == ref, f"remap mask {dst:#x}"
        proj = batch.project(dst)
        refp = [R.remap_cached(batch.packed(i),
                               batch.packed_flags(i) & dst)
                for i in range(len(batch))]
        assert list(proj) == refp, f"project mask {dst:#x}"


def test_rebuilt_batch_carries_patched_columns():
    batch = mixed_batch()
    dst = R.CLF_JOBID | R.CLF_METRICS
    out = batch.remap(dst)
    assert out._hdr is not None                  # no re-gather needed
    assert out.flags_np().tolist() == [dst] * len(batch)
    assert np.array_equal(out.indices_np(), batch.indices_np())
    assert np.array_equal(out.tfid_cols()[0], batch.tfid_cols()[0])


def test_columnar_gathers_match_record_decode():
    batch = mixed_batch()
    recs = batch.to_records()
    assert batch.name_col() == [r.name for r in recs]
    assert batch.xattrs_col() == [r.xattr for r in recs]
    mat, cnt = batch.metrics_cols(3)
    for i, r in enumerate(recs):
        m = list(r.metrics or [])
        assert cnt[i] == len(m)
        for j in range(min(3, len(m))):
            assert mat[i, j] == m[j]


def test_metricsdb_columnar_rows_match_scalar_rows():
    batch = mixed_batch()
    scalar = [MetricsDB._row("p", batch.record(i))
              for i in range(len(batch))]
    assert MetricsDB._rows("p", batch) == scalar


# ----------------------------------------------------------- negotiation
class OldLcapService(LcapService):
    """A pre-v2 daemon: no ``caps``/``offer_many`` verbs, ignores the
    ``wire`` negotiation key, always frames fetches as v1."""

    def _handle(self, msg, session):
        if msg.get("op") in ("caps", "offer_many"):
            return {"err": f"unknown op {msg.get('op')!r}",
                    "err_type": "SessionError"}
        msg = {k: v for k, v in msg.items() if k != "wire"}
        reply = super()._handle(msg, session)
        reply.pop("wire", None)
        return reply


def _drain_wire(stream, logs, expect, deadline=20.0):
    seen = set()
    end = time.time() + deadline
    while time.time() < end:
        moved = 0
        for pid, batch in stream.fetch(4096):
            seen.update((pid, i) for i in batch.indices())
            moved += len(batch)
        stream.commit()
        if seen >= expect and all(log.first_index == log.last_index + 1
                                  for log in logs.values()):
            break
        if not moved:
            time.sleep(0.005)
    return seen


def test_remote_shard_falls_back_to_v1_peer():
    """Coordinator + consumer against an old daemon: caps probing
    degrades to the shallow v1 path and traffic still flows end to
    end, journals trimming to empty."""
    logs = {"m0": Llog("m0")}
    proxy = LcapProxy({})
    svc = OldLcapService(proxy).start()
    try:
        shard = RemoteShard(svc.address)
        cluster = LcapCluster(logs, shards=[shard])
        sess = connect([svc.address])
        stream = sess.subscribe(Subscription(group="g", auto_commit=False))
        for i in range(40):
            logs["m0"].log(mk(0, jobid=b"j", metrics=(1.0,),
                              tfid=R.Fid(1, i % 7, 0)))
        expect = {("m0", i) for i in range(1, 41)}
        end = time.time() + 20
        seen = set()
        while time.time() < end:
            cluster.pump()
            for pid, batch in stream.fetch(4096):
                seen.update((pid, i) for i in batch.indices())
            stream.commit()
            if seen == expect and logs["m0"].first_index \
                    == logs["m0"].last_index + 1:
                break
            time.sleep(0.002)
        assert seen == expect
        assert logs["m0"].first_index == logs["m0"].last_index + 1
        assert shard.caps() == {"wire": R.WIRE_V1, "deep": False}
        sess.close()
    finally:
        svc.stop()


def test_remote_shard_negotiates_deep_v2_peer():
    logs = {"m0": Llog("m0")}
    proxy = LcapProxy({})
    svc = LcapService(proxy).start()
    try:
        shard = RemoteShard(svc.address)
        cluster = LcapCluster(logs, shards=[shard])
        assert shard.caps() == {"wire": R.WIRE_V2, "deep": True}
        sess = connect([svc.address])
        stream = sess.subscribe(Subscription(group="g", auto_commit=False,
                                             zero_fill=False))
        for i in range(30):
            logs["m0"].log(mk(0, jobid=b"j", xattr={"i": i},
                              tfid=R.Fid(1, i % 5, 0)))
        expect = {("m0", i) for i in range(1, 31)}
        end = time.time() + 20
        seen = set()
        columns_attached = []
        while time.time() < end:
            cluster.pump()
            for pid, batch in stream.fetch(4096):
                columns_attached.append(batch._hdr is not None
                                        and not batch._recs)
                seen.update((pid, i) for i in batch.indices())
            stream.commit()
            if seen == expect and logs["m0"].first_index \
                    == logs["m0"].last_index + 1:
                break
            time.sleep(0.002)
        assert seen == expect
        # every delivered batch arrived with columns attached and zero
        # per-record decodes pending — the columnar delivery path
        assert columns_attached and all(columns_attached)
        sess.close()
    finally:
        svc.stop()


# ------------------------------------------- cluster-path equivalence
def _run_cluster_workload(n_records=120):
    """Drive one fixed workload through a 2-shard cluster service and
    return the delivered payloads + MetricsDB rows, sorted."""
    logs = {f"m{i}": Llog(f"m{i}") for i in range(2)}
    cluster = LcapCluster(logs, n_shards=2)
    svc = LcapClusterService(cluster).start()
    rows = []
    packed = []
    try:
        sess = connect(svc)
        stream = sess.subscribe(Subscription(group="g", auto_commit=False,
                                             zero_fill=False))
        for k, (pid, log) in enumerate(sorted(logs.items())):
            for i in range(n_records // 2):
                log.log(mk(0, tfid=R.Fid(1, i % 11, k),
                           jobid=b"fleet", shard=(0, k, 0, 0),
                           metrics=(0.5, float(i)), xattr={"i": i % 3}))
        expect = {(pid, i) for pid in logs
                  for i in range(1, n_records // 2 + 1)}
        seen = set()
        end = time.time() + 30
        while time.time() < end:
            moved = 0
            for pid, batch in stream.fetch(4096):
                rows.extend(MetricsDB._rows(pid, batch))
                packed.extend((pid, bytes(b)) for b in batch)
                seen.update((pid, i) for i in batch.indices())
                moved += len(batch)
            stream.commit()
            if seen == expect and all(log.first_index == log.last_index + 1
                                      for log in logs.values()):
                break
            if not moved:
                time.sleep(0.005)
        assert seen == expect
        sess.close()
    finally:
        svc.stop()
    return sorted(rows), sorted(packed)


def test_cluster_equivalence_v1_vs_v2_wire(monkeypatch):
    """The same workload down the v2 (columnar) and v1 (legacy) wire
    paths delivers identical records and identical consumer rows."""
    rows_v2, packed_v2 = _run_cluster_workload()
    # clamp negotiation server-side: every subscribe/caps answers v1,
    # so all frames (offer and fetch) travel the legacy format
    import repro.core.server as server_mod
    monkeypatch.setattr(server_mod, "WIRE_V2", R.WIRE_V1)
    rows_v1, packed_v1 = _run_cluster_workload()
    assert packed_v1 == packed_v2                # payload bit-for-bit
    assert rows_v1 == rows_v2                    # consumer-visible rows


def test_proxy_offer_many_single_call():
    proxy = LcapProxy({})
    proxy.add_source("p", 1)
    b1 = R.RecordBatch.from_records([mk(1, tfid=R.Fid(1, 1, 0)),
                                     mk(2, tfid=R.Fid(1, 2, 0))])
    b2 = R.RecordBatch.from_records([mk(3, tfid=R.Fid(1, 3, 0))])
    admitted = proxy.offer_many([("p", b1, 2), ("p", b2, 3)])
    assert admitted == 3
