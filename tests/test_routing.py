"""Epoch-versioned routing plane: RoutingTable snapshots, live slot
migration (zero loss, zero duplication), shard add/split under load,
forced migration through kill_shard, consumer-side epoch discovery
(in-process and over the wire), parked-durable resume across topology
churn, and retention SLOs (StreamJanitor over the history tier).

The interleaving fuzz runs as an always-on seeded-random driver;
hypothesis widens the schedule space when installed (guarded, like
test_records.py / test_columnar.py).
"""

import random
import time

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import records as R
from repro.core.cluster import LcapCluster, LcapClusterService
from repro.core.errors import ClusterError
from repro.core.history import StreamJanitor
from repro.core.llog import Llog
from repro.core.routing import RoutingTable
from repro.core.session import Subscription, connect


def rec(oid=1, ver=0, t=R.CL_CREATE, name=b"f", **kw):
    return R.ChangelogRecord(type=t, tfid=R.Fid(1, oid, ver),
                             pfid=R.Fid(1, 0, 0), name=name, **kw)


def mk_cluster(n_producers=2, n_shards=3, **kw):
    logs = {f"mdt{i}": Llog(f"mdt{i}") for i in range(n_producers)}
    return LcapCluster(logs, n_shards=n_shards, **kw), logs


def feed(logs, lo, hi, oids=13):
    for pid, log in logs.items():
        for i in range(lo, hi):
            log.log(rec(oid=i % oids, name=f"{pid}-{i}".encode()))


def drain(cluster, stream, seen, want, rounds=300, forbid_dup=False):
    """Pump + fetch + commit until ``seen`` covers ``want``; returns
    the number of duplicate deliveries observed."""
    dups = 0
    for _ in range(rounds):
        cluster.pump()
        moved = 0
        for pid, batch in stream.fetch(4096):
            for i in batch.indices():
                if (pid, i) in seen:
                    dups += 1
                    assert not forbid_dup, f"duplicate delivery {(pid, i)}"
                seen.add((pid, i))
            moved += len(batch)
        stream.commit()
        if not moved and seen >= want:
            break
    return dups


def settle(cluster, rounds=100):
    """Pump until the in-flight migration (if any) commits."""
    for _ in range(rounds):
        cluster.pump()
        if cluster._migration is None:
            return
    raise AssertionError("migration never committed")


# ------------------------------------------------------------ RoutingTable
def test_routing_table_initial_stripes_and_is_immutable():
    t = RoutingTable.initial(8, 3)
    assert t.epoch == 0
    assert t.slot_owner == (0, 1, 2, 0, 1, 2, 0, 1)
    assert t.counts(3) == [3, 3, 2]
    assert tuple(t.slots_of(2)) == (2, 5)
    with pytest.raises(AttributeError):
        t.epoch = 5
    with pytest.raises(TypeError):
        t.slot_owner[0] = 1
    arr = t.owner_array()
    assert not arr.flags.writeable


def test_routing_table_evolution_bumps_epoch_each_step():
    t = RoutingTable.initial(8, 2)
    d = t.drain([0, 2], target=1)
    assert d.epoch == 1
    assert d.slot_owner == t.slot_owner          # ownership unchanged
    assert d.draining == {0: 1, 2: 1}
    assert bool(d.draining_mask()[0]) and not bool(d.draining_mask()[1])
    c = d.commit_drain()
    assert c.epoch == 2
    assert c.slot_owner[0] == 1 and c.slot_owner[2] == 1
    assert not c.draining
    x = d.cancel_drain()
    assert x.epoch == 2 and x.slot_owner == t.slot_owner and not x.draining
    r = c.reassign({1: 0, 3: 0})
    assert r.epoch == 3 and r.slot_owner[1] == 0 and r.slot_owner[3] == 0
    b = r.bumped()
    assert b.epoch == 4 and b.slot_owner == r.slot_owner
    # originals untouched throughout
    assert t.epoch == 0 and t.slot_owner == (0, 1, 0, 1, 0, 1, 0, 1)


# ------------------------------------------------------- graceful migration
def test_live_migration_zero_loss_zero_dup():
    cluster, logs = mk_cluster(n_producers=2, n_shards=2)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 40)
    cluster.pump()
    moved = cluster.migrate_slots(cluster.routing.slots_of(0)[:16], 1)
    assert moved == 16
    assert cluster.epoch >= 1
    feed(logs, 40, 60)                   # traffic lands while draining
    seen = set()
    want = {(pid, i) for pid in logs for i in range(1, 61)}
    drain(cluster, stream, seen, want, forbid_dup=True)
    assert seen == want
    assert cluster._migration is None
    assert cluster.stats["migrations_completed"] == 1
    assert cluster.stats["slots_migrated"] == 16
    # the epoch invariant: drain and commit each bumped once at least
    assert cluster.stats["epoch_bumps"] >= 2
    for log in logs.values():
        assert log.first_index == log.last_index + 1


def test_migration_on_idle_cluster_commits_immediately():
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    connect(cluster).subscribe("g", auto_commit=False)
    slots = cluster.routing.slots_of(0)
    cluster.migrate_slots(slots, 1)
    assert cluster._migration is None     # nothing in flight to drain
    assert all(o == 1 for o in cluster.slot_owner)


def test_one_migration_in_flight_at_a_time():
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 30)
    cluster.pump()
    cluster.migrate_slots(cluster.routing.slots_of(0)[:8], 1)
    if cluster._migration is not None:
        with pytest.raises(ClusterError):
            cluster.migrate_slots([0], 1)
    with pytest.raises(ClusterError):
        cluster.migrate_slots([0], 7)     # no such shard
    with pytest.raises(ClusterError):
        cluster.migrate_slots([999], 1 if cluster._migration is None else 0)


def test_park_cap_backpressures_journal_reads():
    cluster, logs = mk_cluster(n_producers=1, n_shards=2, park_cap=8)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 10)
    cluster.pump()
    cluster.migrate_slots(cluster.routing.slots_of(0), 1)
    feed(logs, 10, 300)
    cluster._route()
    assert cluster._parked_count <= 8 + cluster.batch_size
    # routing stopped early: the cursor has not consumed the journal
    if cluster._migration is not None:
        assert cluster.cursors["mdt0"] <= logs["mdt0"].last_index + 1
    seen = set()
    want = {("mdt0", i) for i in range(1, 301)}
    drain(cluster, stream, seen, want, forbid_dup=True)
    assert seen == want


# ------------------------------------------------------- shard add / split
def test_add_shard_under_load_consumer_discovers_it():
    cluster, logs = mk_cluster(n_producers=2, n_shards=2)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 40)
    seen = set()
    want = {(pid, i) for pid in logs for i in range(1, 41)}
    drain(cluster, stream, seen, want, forbid_dup=True)
    e0 = cluster.epoch
    new = cluster.add_shard()
    assert new == 2
    assert cluster.epoch == e0 + 1
    assert cluster.routing.counts(3)[new] == 0   # joins with zero slots
    cluster.migrate_slots(cluster.routing.slots_of(0)[:10], new)
    feed(logs, 40, 90)
    want = {(pid, i) for pid in logs for i in range(1, 91)}
    drain(cluster, stream, seen, want, forbid_dup=True)
    assert seen == want
    assert new in stream.shards          # fan-in re-resolved on the bump
    assert stream.epoch == cluster.epoch
    # the new shard never drags the collective ack
    for log in logs.values():
        assert log.first_index == log.last_index + 1


def test_split_shard_halves_the_most_loaded_shard():
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 50)
    cluster.pump()
    before = cluster.routing.counts(2)
    new = cluster.split_shard()
    seen = set()
    want = {("mdt0", i) for i in range(1, 51)}
    drain(cluster, stream, seen, want, forbid_dup=True)
    settle(cluster)
    after = cluster.routing.counts(3)
    src = before.index(max(before))
    assert after[new] == max(before) // 2
    assert after[src] == max(before) - max(before) // 2
    assert cluster.stats["shards_added"] == 1


def test_groups_replicated_to_new_shard_before_records_flow():
    """The loss window this guards: records offered to a just-added
    shard before the consumer's fan-in subscribes there must park in
    the replicated group, not be consumed-and-acked."""
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    new = cluster.add_shard()
    proxy = cluster.shards[new].proxy
    assert "g" in proxy.groups           # replicated at join time
    cluster.migrate_slots(cluster.routing.slots_of(0), new)
    feed(logs, 0, 40)
    seen = set()
    want = {("mdt0", i) for i in range(1, 41)}
    drain(cluster, stream, seen, want, forbid_dup=True)
    assert seen == want


# ------------------------------------------------- forced migration (kill)
def test_kill_is_a_forced_migration_same_invariant():
    cluster, logs = mk_cluster(n_producers=2, n_shards=3)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 50, oids=17)
    cluster.pump()
    pre = stream.fetch(30)
    seen = {(pid, i) for pid, b in pre for i in b.indices()}
    e0 = cluster.epoch
    cluster.kill_shard(0)
    assert cluster.epoch == e0 + 1       # reassignment bumped once
    stream.commit()
    want = {(pid, i) for pid in logs for i in range(1, 51)}
    drain(cluster, stream, seen, want)   # dups allowed: at-least-once
    assert want - seen == set()
    assert stream.lost == [0]
    assert cluster.stats["failover_redelivered"] > 0
    for log in logs.values():
        assert log.first_index == log.last_index + 1


def test_kill_during_migration_cancels_and_loses_nothing():
    cluster, logs = mk_cluster(n_producers=1, n_shards=3)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 50, oids=23)
    cluster.pump()
    cluster.migrate_slots(cluster.routing.slots_of(0), 1)
    feed(logs, 50, 80, oids=23)
    cluster._route()                     # park records for draining slots
    assert cluster._migration is not None
    pre = stream.fetch(25)
    seen = {(pid, i) for pid, b in pre for i in b.indices()}
    cluster.kill_shard(0)                # a migration source dies
    assert cluster.stats["migrations_cancelled"] == 1
    assert cluster._migration is None
    stream.commit()
    want = {("mdt0", i) for i in range(1, 81)}
    drain(cluster, stream, seen, want)
    assert want - seen == set()
    assert logs["mdt0"].first_index == logs["mdt0"].last_index + 1


def test_kill_migration_target_cancels_and_loses_nothing():
    cluster, logs = mk_cluster(n_producers=1, n_shards=3)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 40, oids=23)
    cluster.pump()
    target = 2
    cluster.migrate_slots(cluster.routing.slots_of(0), target)
    feed(logs, 40, 60, oids=23)
    cluster._route()
    seen = set()
    cluster.kill_shard(target)
    assert cluster._migration is None
    want = {("mdt0", i) for i in range(1, 61)}
    drain(cluster, stream, seen, want)
    assert want - seen == set()


# ------------------------------------------ interleaving fuzz (satellite 2)
def _churn_schedule(cluster, logs, stream, ops, feed_per_op=12):
    """Drive a random interleaving of elastic ops against live traffic;
    returns (seen set, dup count, whether any kill happened)."""
    seen, dups, killed = set(), 0, False
    next_idx = {pid: 1 for pid in logs}

    def emit():
        for pid, log in logs.items():
            for _ in range(feed_per_op):
                log.log(rec(oid=next_idx[pid] % 29,
                            name=f"{pid}-{next_idx[pid]}".encode()))
                next_idx[pid] += 1

    def consume():
        nonlocal dups
        cluster.pump()
        for pid, batch in stream.fetch(4096):
            for i in batch.indices():
                if (pid, i) in seen:
                    dups += 1
                seen.add((pid, i))
        stream.commit()

    for op, arg in ops:
        emit()
        consume()
        live = [i for i in range(len(cluster.shards)) if cluster.alive[i]]
        if op == "migrate" and cluster._migration is None and len(live) > 1:
            src = live[arg % len(live)]
            dst = live[(arg + 1) % len(live)]
            slots = cluster.routing.slots_of(src)
            if slots and src != dst:
                cluster.migrate_slots(slots[:max(1, len(slots) // 2)], dst)
        elif op == "add":
            if len(cluster.shards) < 6:
                cluster.add_shard()
        elif op == "kill" and len(live) > 1:
            victim = live[arg % len(live)]
            cluster.kill_shard(victim)
            killed = True
        consume()
    want = {(pid, i) for pid in logs for i in range(1, next_idx[pid])}
    for _ in range(300):
        cluster.pump()
        moved = 0
        for pid, batch in stream.fetch(4096):
            for i in batch.indices():
                if (pid, i) in seen:
                    dups += 1
                seen.add((pid, i))
            moved += len(batch)
        stream.commit()
        if not moved and seen >= want:
            break
    return seen, want, dups, killed


def _check_schedule(ops):
    logs = {"mdt0": Llog("mdt0"), "mdt1": Llog("mdt1")}
    cluster = LcapCluster(logs, n_shards=3)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    seen, want, dups, killed = _churn_schedule(cluster, logs, stream, ops)
    assert want - seen == set(), f"lost {len(want - seen)} records"
    if not killed:
        assert dups == 0, f"{dups} duplicates without any shard death"
    # per-target cr_prev order survives the churn: indices of one
    # target arrive in journal order on whichever shard owns it
    order = {}
    for pid, i in sorted(seen):
        order.setdefault(pid, []).append(i)
    for pid, idxs in order.items():
        assert idxs == sorted(idxs)
    for log in logs.values():
        assert log.first_index == log.last_index + 1


OPS = ("migrate", "add", "kill", "none")


def test_fuzz_random_churn_interleavings_seeded():
    for seed in range(6):
        rng = random.Random(0xE19 + seed)
        ops = [(rng.choice(OPS), rng.randrange(6)) for _ in range(7)]
        _check_schedule(ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(OPS), st.integers(0, 5)),
                    min_size=1, max_size=8))
    def test_fuzz_random_churn_interleavings_hypothesis(ops):
        _check_schedule(ops)


# ------------------------------------------------ wire-path epoch discovery
def test_tcp_fan_in_sees_epoch_bump_and_reresolves():
    """Satellite 3: a live TCP consumer mid-iteration observes the
    shard-set change (piggybacked epoch), opens a child on the new
    daemon, and cursor/commit routing lands on the new owner — no
    restart."""
    logs = {"p0": Llog("p0"), "p1": Llog("p1")}
    cluster = LcapCluster(logs, n_shards=2)
    svc = LcapClusterService(cluster).start()
    try:
        sess = connect(svc)
        stream = sess.subscribe(Subscription(group="g", auto_commit=False))
        assert sorted(stream.shards) == [0, 1]
        e0 = stream.epoch
        feed(logs, 0, 30, oids=9)
        seen = set()
        deadline = time.time() + 15
        while time.time() < deadline and len(seen) < 60:
            for pid, batch in stream.fetch(4096):
                seen.update((pid, i) for i in batch.indices())
            stream.commit()
            time.sleep(0.002)
        assert len(seen) == 60
        new = svc.add_shard()            # grow the daemon set live
        with cluster._lock:
            cluster.migrate_slots(cluster.routing.slots_of(0)[:20], new)
        feed(logs, 30, 70, oids=9)
        deadline = time.time() + 20
        while time.time() < deadline and len(seen) < 140:
            for pid, batch in stream.fetch(4096):
                seen.update((pid, i) for i in batch.indices())
            stream.commit()
            time.sleep(0.002)
        assert len(seen) == 140
        assert stream.epoch > e0         # bump observed on the wire
        assert new in stream.shards      # child opened on the new daemon
        child = dict(stream._children)[new]
        assert child.cursors             # commits route to the new owner
        for log in logs.values():
            deadline = time.time() + 10
            while (time.time() < deadline
                   and log.first_index != log.last_index + 1):
                time.sleep(0.005)
            assert log.first_index == log.last_index + 1
        sess.close()
    finally:
        svc.stop()


def test_topology_verb_served_by_every_shard():
    logs = {"p": Llog("p")}
    cluster = LcapCluster(logs, n_shards=2)
    svc = LcapClusterService(cluster).start()
    try:
        sess = connect(list(svc.addresses))   # raw addresses, no callable
        stream = sess.subscribe(Subscription(group="g", auto_commit=False))
        topo = sess._topology_snapshot()
        assert topo is not None
        assert topo["shards"] == 2 and len(topo["addresses"]) == 2
        # raw-address clients also discover growth, via the verb
        new = svc.add_shard()
        feed(logs, 0, 10)
        deadline = time.time() + 10
        while time.time() < deadline and new not in stream.shards:
            stream.fetch(4096)
            stream.commit()
            time.sleep(0.005)
        assert new in stream.shards
        sess.close()
    finally:
        svc.stop()


# --------------------------------------------- durable resume across churn
def test_parked_durable_resumes_onto_migrated_slots():
    """Satellite 3b: a durable consumer parks, the cluster migrates and
    grows, and resume lands on the *new* topology — parked state where
    it exists, fresh attach on shards that joined while it was away."""
    logs = {"p": Llog("p")}
    cluster = LcapCluster(logs, n_shards=2)
    sess = connect(cluster)
    st = sess.subscribe("g", name="worker-1", auto_commit=False)
    feed(logs, 0, 20, oids=7)
    cluster.pump()
    got = {("p", i) for _, b in st.fetch(4096) for i in b.indices()}
    st.commit()
    st.detach()                          # park on both shards
    cluster.migrate_slots(cluster.routing.slots_of(0)[:10], 1)
    settle(cluster)
    new = cluster.add_shard()
    cluster.migrate_slots(cluster.routing.slots_of(1)[:10], new)
    settle(cluster)
    feed(logs, 20, 40, oids=7)
    cluster.pump()
    st2 = sess.resume("g", "worker-1", auto_commit=False)
    assert st2.resumed
    assert new in st2.shards             # fresh attach on the young shard
    seen = set(got)
    want = {("p", i) for i in range(1, 41)}
    drain(cluster, stream=st2, seen=seen, want=want)
    assert seen == want


def test_cluster_resume_raises_only_when_no_shard_has_state():
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    sess = connect(cluster)
    from repro.core.errors import UnknownConsumerError
    with pytest.raises(UnknownConsumerError):
        sess.resume("g", "never-existed")


# --------------------------------------------------- retention (satellites)
def test_janitor_trims_history_behind_live_cursors():
    logs = {"q": Llog("q", history=True)}
    cluster = LcapCluster(logs, n_shards=2)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 300, oids=31)
    seen = set()
    want = {("q", i) for i in range(1, 301)}
    drain(cluster, stream, seen, want, forbid_dup=True)
    hist = logs["q"].history
    assert hist.covered_lo == 1
    jan = StreamJanitor(cluster, floor=64)
    out = jan.sweep()
    assert out["q"]["dropped"] > 0
    assert hist.covered_lo == out["q"]["horizon"]
    assert jan.stats["sweeps"] == 1
    assert jan.stats["records_dropped"] == out["q"]["dropped"]
    # idempotent: nothing moved, nothing more trimmed
    assert jan.sweep()["q"]["dropped"] == 0
    # replay=True after the trim clamps to the retained floor
    st2 = connect(cluster).subscribe("g2", replay=True, auto_commit=False)
    got = set()
    for _ in range(200):
        cluster.pump()
        moved = 0
        for pid, batch in st2.fetch(4096):
            got.update(batch.indices())
            moved += len(batch)
        st2.commit()
        if not moved and not st2.replaying:
            break
    assert got and min(got) == out["q"]["horizon"]


def test_janitor_floor_keeps_a_tail_even_when_fully_acked():
    logs = {"q": Llog("q", history=True)}
    cluster = LcapCluster(logs, n_shards=1)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 100)
    seen = set()
    drain(cluster, stream, seen, {("q", i) for i in range(1, 101)},
          forbid_dup=True)
    jan = StreamJanitor(cluster, floor=40)
    jan.sweep()
    hist = logs["q"].history
    assert hist.covered_hi - hist.covered_lo + 1 >= 40


def test_retention_horizon_held_back_by_replay_and_migration():
    logs = {"q": Llog("q", history=True)}
    cluster = LcapCluster(logs, n_shards=2)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 120, oids=31)
    seen = set()
    drain(cluster, stream, seen, {("q", i) for i in range(1, 121)},
          forbid_dup=True)
    # an unfinished replay bootstrap pins the horizon at its rewind
    st2 = connect(cluster).subscribe("g2", replay=True, auto_commit=False)
    cluster.pump()
    h = cluster.retention_horizons()
    assert h["q"] == 1                   # replay_lo of the bootstrap
    # an in-flight migration pins the horizon at its handoff
    feed(logs, 120, 140, oids=31)
    cluster.pump()
    pre = stream.fetch(5)
    cluster.migrate_slots(cluster.routing.slots_of(0), 1)
    if cluster._migration is not None:
        h2 = cluster.retention_horizons()
        handoff = min(cluster._migration.handoff.values())
        assert h2["q"] <= handoff + 1
    seen.update((pid, i) for pid, b in pre for i in b.indices())
    stream.commit()
    # drain BOTH groups: g2's acks gate the collective watermark (and
    # with it the migration handoff), so it must keep consuming — a
    # stalled persistent group is exactly what holds retention back
    want = {("q", i) for i in range(1, 141)}
    got2 = set()
    for _ in range(400):
        cluster.pump()
        moved = 0
        for pid, batch in stream.fetch(4096):
            seen.update((pid, i) for i in batch.indices())
            moved += len(batch)
        stream.commit()
        for pid, batch in st2.fetch(4096):
            got2.update((pid, i) for i in batch.indices())
            moved += len(batch)
        st2.commit()
        if not moved and seen >= want and got2 >= want \
                and not st2.replaying:
            break
    assert seen >= want
    assert got2 >= want
    # with both consumers caught up and the migration settled, nothing
    # pins the horizon any more
    settle(cluster)
    assert cluster._migration is None
    assert cluster.retention_horizons()["q"] > 1


# ----------------------------------------------------------- observability
def test_epoch_and_migration_gauges_exported():
    from repro.obs.registry import MetricsRegistry
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    reg = MetricsRegistry()
    cluster.attach_registry(reg)
    stream = connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 30)
    seen = set()
    drain(cluster, stream, seen, {("mdt0", i) for i in range(1, 31)},
          forbid_dup=True)
    cluster.migrate_slots(cluster.routing.slots_of(0)[:8], 1)
    snap = reg.snapshot()
    assert snap["lcap_routing_epoch"]["samples"][0][1] == cluster.epoch
    owned = {s[0].get("shard"): s[1]
             for s in snap["lcap_shard_slots_owned"]["samples"]}
    assert sum(owned.values()) == cluster.n_slots
    lag = snap["lcap_shard_dispatch_lag"]["samples"]
    assert {s[0].get("shard") for s in lag} == {"0", "1"}
    if cluster._migration is not None:
        assert snap["lcap_migration_in_flight"]["samples"][0][1] == 1
    settle(cluster)
    snap = reg.snapshot()
    assert snap["lcap_migration_in_flight"]["samples"][0][1] == 0


def test_autoscale_signals_per_live_shard():
    cluster, logs = mk_cluster(n_producers=1, n_shards=2)
    connect(cluster).subscribe("g", auto_commit=False)
    feed(logs, 0, 20)
    cluster._route()                     # routed but not yet dispatched
    sig = cluster.autoscale_signals()
    assert set(sig) == {"0", "1"}
    for ent in sig.values():
        assert set(ent) == {"offer_queue_depth", "dispatch_lag",
                            "slots_owned"}
    assert sum(e["slots_owned"] for e in sig.values()) == cluster.n_slots
    assert sum(e["dispatch_lag"] for e in sig.values()) > 0
    cluster.kill_shard(0)
    assert set(cluster.autoscale_signals()) == {"1"}
