"""Journal semantics (paper §II): registration arms logging, masks,
index/prev chaining, per-reader acks, trim at the collective watermark,
persistence across reopen."""

import os

import pytest

from repro.core import records as R
from repro.core.llog import Llog


def rec(t=R.CL_CREATE, oid=1, name=b"f"):
    return R.ChangelogRecord(type=t, tfid=R.Fid(1, oid, 0),
                             pfid=R.Fid(1, 0, 0), name=name)


def test_not_logged_without_reader():
    log = Llog("mdt0")
    assert log.log(rec()) is None
    assert log.last_index == 0


def test_registration_arms_logging_and_indices_increase():
    log = Llog("mdt0")
    log.register_reader()
    idx = [log.log(rec(oid=i)) for i in range(5)]
    assert idx == [1, 2, 3, 4, 5]


def test_mask_selects_operations():
    log = Llog("mdt0", mask={R.CL_CREATE})
    log.register_reader()
    assert log.log(rec(R.CL_CREATE)) == 1
    assert log.log(rec(R.CL_UNLINK)) is None
    assert log.log(rec(R.CL_CREATE)) == 2


def test_prev_chains_same_target():
    log = Llog("mdt0")
    log.register_reader()
    log.log(rec(oid=1))          # idx 1
    log.log(rec(oid=2))          # idx 2
    log.log(rec(oid=1))          # idx 3, prev=1
    bufs = log.read(1, 10)
    parsed = [R.unpack(b) for b in bufs]
    assert parsed[2].prev == 1 and parsed[1].prev == 0


def test_read_from_index_and_batching():
    log = Llog("mdt0")
    log.register_reader()
    for i in range(10):
        log.log(rec(oid=i))
    assert len(log.read(1, 4)) == 4
    assert [R.unpack(b).index for b in log.read(7, 100)] == [7, 8, 9, 10]
    assert log.read(11) == []


def test_trim_requires_all_readers():
    """Records are kept until acknowledged by ALL registered readers."""
    log = Llog("mdt0")
    r1 = log.register_reader()
    r2 = log.register_reader()
    for i in range(6):
        log.log(rec(oid=i))
    log.ack(r1, 4)
    assert log.first_index == 1          # r2 still owes acks
    log.ack(r2, 2)
    assert log.first_index == 3          # min(4, 2) = 2 trimmed
    log.ack(r2, 6)
    assert log.first_index == 5
    log.ack(r1, 6)
    assert log.first_index == 7 and log.read(1) == []


def test_deregister_releases_horizon():
    log = Llog("mdt0")
    r1 = log.register_reader()
    r2 = log.register_reader()
    for i in range(4):
        log.log(rec(oid=i))
    log.ack(r1, 4)
    assert log.first_index == 1
    log.deregister_reader(r2)            # slow reader goes away
    assert log.first_index == 5


def test_new_reader_owes_only_future_records():
    log = Llog("mdt0")
    r1 = log.register_reader()
    log.log(rec(oid=1))
    log.ack(r1, 1)
    r2 = log.register_reader()
    log.log(rec(oid=2))
    log.ack(r1, 2)
    assert log.first_index == 1 + 1      # idx1 trimmed; idx2 awaits r2
    log.ack(r2, 2)
    assert log.first_index == 3


def test_persistence_roundtrip(tmp_path):
    p = str(tmp_path / "mdt0.llog")
    log = Llog("mdt0", path=p)
    rid = log.register_reader()
    for i in range(5):
        log.log(rec(oid=i, name=f"f{i}".encode()))
    log.ack(rid, 2)
    log.close()

    log2 = Llog("mdt0", path=p)
    assert log2.first_index == 3 and log2.last_index == 5
    assert [R.unpack(b).name for b in log2.read(3, 10)] == [b"f2", b"f3", b"f4"]
    # the reader registry survived; new records continue the index space
    assert log2.log(rec(oid=99)) == 6
    log2.ack(rid, 6)
    assert log2.first_index == 7


def test_duplicate_reader_rejected():
    log = Llog("mdt0")
    log.register_reader("cl1")
    with pytest.raises(ValueError):
        log.register_reader("cl1")


# ------------------------------------------------------- segmented storage
def test_trim_drops_whole_segments_without_rewrite(tmp_path):
    """Satellite/tentpole: trimming drops sealed segment files in O(1);
    the journal is never rewritten."""
    p = str(tmp_path / "mdt0.llog")
    log = Llog("mdt0", path=p, segment_records=4)
    rid = log.register_reader()
    for i in range(10):
        log.log(rec(oid=i))
    assert log.segment_count == 3            # 4 + 4 + 2
    seg_files = sorted(tmp_path.glob("mdt0.llog.seg.*"))
    assert len(seg_files) == 3
    log.ack(rid, 8)                          # covers segments 1 and 2
    assert log.stats["segments_dropped"] == 2
    assert log.first_index == 9
    remaining = sorted(tmp_path.glob("mdt0.llog.seg.*"))
    assert len(remaining) == 1               # dropped files deleted
    # the surviving segment file was never rewritten: still append-only
    assert [R.unpack(b).index for b in log.read(9, 10)] == [9, 10]
    log.ack(rid, 10)
    assert log.first_index == 11
    log.close()


def test_partial_segment_ack_keeps_segment_but_moves_first(tmp_path):
    p = str(tmp_path / "mdt0.llog")
    log = Llog("mdt0", path=p, segment_records=8)
    rid = log.register_reader()
    for i in range(6):
        log.log(rec(oid=i))
    log.ack(rid, 3)                          # mid-segment: no file drop
    assert log.stats["segments_dropped"] == 0
    assert log.first_index == 4              # logical trim point moved
    assert [R.unpack(b).index for b in log.read(1, 10)] == [4, 5, 6]
    log.close()


def test_crash_recovery_drops_truncated_final_record(tmp_path):
    """Satellite: a record half-written at crash time is dropped on
    load, never a parse error; intact records before it survive."""
    p = str(tmp_path / "mdt0.llog")
    log = Llog("mdt0", path=p)
    log.register_reader("r")
    for i in range(3):
        log.log(rec(oid=i, name=f"keep{i}".encode()))
    log.close()
    seg = sorted(tmp_path.glob("mdt0.llog.seg.*"))[0]
    blob = seg.read_bytes()
    # simulate a torn append: length prefix + half a record
    seg.write_bytes(blob + b"\x40\x00\x00\x00" + b"\xab" * 17)

    log2 = Llog("mdt0", path=p)
    assert log2.stats["truncated_dropped"] == 1
    assert log2.last_index == 3
    assert [R.unpack(b).name for b in log2.read(1, 10)] == \
        [b"keep0", b"keep1", b"keep2"]
    # the torn bytes were truncated away; appending again stays parseable
    assert log2.log(rec(oid=9, name=b"after")) == 4
    log2.close()
    log3 = Llog("mdt0", path=p)
    assert [R.unpack(b).name for b in log3.read(1, 10)] == \
        [b"keep0", b"keep1", b"keep2", b"after"]
    log3.close()


def test_read_returns_batch_view_across_segments():
    log = Llog("mdt0", segment_records=3)
    log.register_reader()
    for i in range(8):
        log.log(rec(oid=i))
    batch = log.read(2, 5)
    assert isinstance(batch, R.RecordBatch)
    assert batch.indices() == [2, 3, 4, 5, 6]
    # single-segment reads share the segment buffer (zero copy)
    one = log.read(4, 2)
    assert one.indices() == [4, 5]


def test_legacy_single_file_journal_migrates(tmp_path):
    """A pre-segmentation journal (one file of length-prefixed records)
    is migrated into segment files on first open."""
    import struct as _s
    p = str(tmp_path / "old.llog")
    bufs = []
    for i in range(4):
        r = rec(oid=i, name=f"old{i}".encode())
        r.index = i + 1
        bufs.append(R.pack(r))
    with open(p, "wb") as fh:
        for b in bufs:
            fh.write(_s.pack("<I", len(b)) + b)
    log = Llog("mdt0", path=p)
    assert log.first_index == 1 and log.last_index == 4
    assert [R.unpack(b).name for b in log.read(1, 10)] == \
        [b"old0", b"old1", b"old2", b"old3"]
    assert not os.path.exists(p)             # legacy file replaced
    assert sorted(tmp_path.glob("old.llog.seg.*"))
    log.close()



def test_over_ack_never_orphans_future_records():
    """Acking beyond last_index must clamp: records logged afterwards
    stay readable (regression: unclamped horizon pushed first_index past
    the index space and made the journal permanently empty)."""
    log = Llog("mdt0")
    rid = log.register_reader()
    for i in range(3):
        log.log(rec(oid=i))
    log.ack(rid, 10)                         # over-ack: only 3 exist
    assert log.first_index == 4              # clamped to last_index + 1
    assert log.log(rec(oid=9)) == 4
    assert [R.unpack(b).index for b in log.read(1, 10)] == [4]
    log.ack(rid, 4)
    assert log.first_index == 5


def test_crash_recovery_truncates_partial_length_prefix(tmp_path):
    """A torn append may leave only 1-3 bytes of the u32 length prefix;
    recovery must truncate them too, or records appended afterwards sit
    behind garbage and are destroyed by the *next* recovery."""
    p = str(tmp_path / "mdt0.llog")
    log = Llog("mdt0", path=p)
    log.register_reader("r")
    for i in range(3):
        log.log(rec(oid=i))
    log.close()
    seg = sorted(tmp_path.glob("mdt0.llog.seg.*"))[0]
    seg.write_bytes(seg.read_bytes() + b"\x40\x00")   # half a length prefix

    log2 = Llog("mdt0", path=p)
    assert log2.stats["truncated_dropped"] == 1
    assert log2.last_index == 3
    assert log2.log(rec(oid=7)) == 4                  # append after recovery
    log2.close()
    log3 = Llog("mdt0", path=p)                       # second restart
    assert log3.last_index == 4                       # record 4 survived
    assert [R.unpack(b).index for b in log3.read(1, 10)] == [1, 2, 3, 4]
    log3.close()


def test_read_binary_search_over_many_segments():
    """Perf-fix regression: ``read`` locates the first live segment by
    bisect instead of scanning the whole segment list; results must be
    identical from every start index, across segment boundaries, after
    trims, and past the end."""
    log = Llog("mdt0", segment_records=4)
    rid = log.register_reader()
    for i in range(103):                      # 26 segments of 4
        log.log(rec(oid=i))
    assert log.segment_count > 20
    for start in (1, 2, 4, 5, 47, 100, 103, 104, 500):
        got = [R.unpack(b).index for b in log.read(start, 7)]
        expect = [i for i in range(start, start + 7) if 1 <= i <= 103][:7]
        assert got == expect, start
    # trim mid-way: bisect must respect the new first live segment
    log.ack(rid, 50)
    assert log.first_index == 51
    for start in (1, 50, 51, 52, 101):
        got = [R.unpack(b).index for b in log.read(start, 5)]
        lo = max(start, 51)
        expect = [i for i in range(lo, lo + 5) if i <= 103]
        assert got == expect, start
    # and a read spanning many segments still concatenates in order
    assert [R.unpack(b).index for b in log.read(60, 30)] == \
        list(range(60, 90))


def test_reader_position_and_has_reader():
    log = Llog("mdt0")
    rid = log.register_reader("lcap-mdt0")
    assert log.has_reader("lcap-mdt0") and not log.has_reader("nope")
    for i in range(5):
        log.log(rec(oid=i))
    assert log.reader_position(rid) == 0
    log.ack(rid, 3)
    assert log.reader_position(rid) == 3
    with pytest.raises(KeyError):
        log.reader_position("nope")
