"""Journal semantics (paper §II): registration arms logging, masks,
index/prev chaining, per-reader acks, trim at the collective watermark,
persistence across reopen."""

import pytest

from repro.core import records as R
from repro.core.llog import Llog


def rec(t=R.CL_CREATE, oid=1, name=b"f"):
    return R.ChangelogRecord(type=t, tfid=R.Fid(1, oid, 0),
                             pfid=R.Fid(1, 0, 0), name=name)


def test_not_logged_without_reader():
    log = Llog("mdt0")
    assert log.log(rec()) is None
    assert log.last_index == 0


def test_registration_arms_logging_and_indices_increase():
    log = Llog("mdt0")
    log.register_reader()
    idx = [log.log(rec(oid=i)) for i in range(5)]
    assert idx == [1, 2, 3, 4, 5]


def test_mask_selects_operations():
    log = Llog("mdt0", mask={R.CL_CREATE})
    log.register_reader()
    assert log.log(rec(R.CL_CREATE)) == 1
    assert log.log(rec(R.CL_UNLINK)) is None
    assert log.log(rec(R.CL_CREATE)) == 2


def test_prev_chains_same_target():
    log = Llog("mdt0")
    log.register_reader()
    log.log(rec(oid=1))          # idx 1
    log.log(rec(oid=2))          # idx 2
    log.log(rec(oid=1))          # idx 3, prev=1
    bufs = log.read(1, 10)
    parsed = [R.unpack(b) for b in bufs]
    assert parsed[2].prev == 1 and parsed[1].prev == 0


def test_read_from_index_and_batching():
    log = Llog("mdt0")
    log.register_reader()
    for i in range(10):
        log.log(rec(oid=i))
    assert len(log.read(1, 4)) == 4
    assert [R.unpack(b).index for b in log.read(7, 100)] == [7, 8, 9, 10]
    assert log.read(11) == []


def test_trim_requires_all_readers():
    """Records are kept until acknowledged by ALL registered readers."""
    log = Llog("mdt0")
    r1 = log.register_reader()
    r2 = log.register_reader()
    for i in range(6):
        log.log(rec(oid=i))
    log.ack(r1, 4)
    assert log.first_index == 1          # r2 still owes acks
    log.ack(r2, 2)
    assert log.first_index == 3          # min(4, 2) = 2 trimmed
    log.ack(r2, 6)
    assert log.first_index == 5
    log.ack(r1, 6)
    assert log.first_index == 7 and log.read(1) == []


def test_deregister_releases_horizon():
    log = Llog("mdt0")
    r1 = log.register_reader()
    r2 = log.register_reader()
    for i in range(4):
        log.log(rec(oid=i))
    log.ack(r1, 4)
    assert log.first_index == 1
    log.deregister_reader(r2)            # slow reader goes away
    assert log.first_index == 5


def test_new_reader_owes_only_future_records():
    log = Llog("mdt0")
    r1 = log.register_reader()
    log.log(rec(oid=1))
    log.ack(r1, 1)
    r2 = log.register_reader()
    log.log(rec(oid=2))
    log.ack(r1, 2)
    assert log.first_index == 1 + 1      # idx1 trimmed; idx2 awaits r2
    log.ack(r2, 2)
    assert log.first_index == 3


def test_persistence_roundtrip(tmp_path):
    p = str(tmp_path / "mdt0.llog")
    log = Llog("mdt0", path=p)
    rid = log.register_reader()
    for i in range(5):
        log.log(rec(oid=i, name=f"f{i}".encode()))
    log.ack(rid, 2)
    log.close()

    log2 = Llog("mdt0", path=p)
    assert log2.first_index == 3 and log2.last_index == 5
    assert [R.unpack(b).name for b in log2.read(3, 10)] == [b"f2", b"f3", b"f4"]
    # the reader registry survived; new records continue the index space
    assert log2.log(rec(oid=99)) == 6
    log2.ack(rid, 6)
    assert log2.first_index == 7


def test_duplicate_reader_rejected():
    log = Llog("mdt0")
    log.register_reader("cl1")
    with pytest.raises(ValueError):
        log.register_reader("cl1")
