"""Framework integration of LCAP (paper usage examples mapped to
training): shared-DB metrics group, checkpoint commit protocol,
straggler detection, elastic membership, cache invalidation, index
bootstrap."""

import os

from repro.core import records as R
from repro.core.llog import Llog
from repro.core.proxy import LcapProxy
from repro.track import (ActivityTracker, CacheInvalidator,
                         CheckpointCommitter, ElasticController, MetricsDB,
                         StragglerDetector, synthesize_index_stream)


def mk_world(n_hosts=4):
    trackers = [ActivityTracker(run_id=1, host_id=h, jobid=f"run-1",
                                shard=(0, h, h // 2, h % 2))
                for h in range(n_hosts)]
    proxy = LcapProxy({t.llog.producer_id: t.llog for t in trackers})
    return trackers, proxy


def pump_all(proxy, workers, rounds=10):
    for _ in range(rounds):
        proxy.pump()
        moved = sum(w.poll() for w in workers)
        proxy.flush_upstream()
        if not moved:
            break


def test_metrics_db_shared_across_group(tmp_path):
    """N MetricsDB instances of one group replicate the stream into one
    shared database — the Robinhood-distributed configuration."""
    trackers, proxy = mk_world(4)
    db = str(tmp_path / "metrics.sqlite")
    workers = [MetricsDB(proxy, db) for _ in range(3)]
    for step in range(5):
        for t in trackers:
            t.step_commit(step, loss=1.0 / (step + 1), step_time_s=0.1,
                          tokens=1024)
    pump_all(proxy, workers)
    rows = workers[0].query("SELECT COUNT(*) FROM events WHERE type=?",
                            (R.CL_STEP_COMMIT,))
    assert rows[0][0] == 20
    # every instance processed a share (load-balanced)
    per = [w.query("SELECT COUNT(*) FROM events")[0][0] for w in workers]
    assert per[0] == 20                       # shared DB: all rows visible
    # and the journals were trimmed (collective ack made it upstream)
    assert all(t.llog.first_index == t.llog.last_index + 1 for t in trackers)
    for w in workers:
        w.close()


def test_checkpoint_commit_protocol(tmp_path):
    """CKPT_WRITE records from all hosts -> committer group publishes the
    manifest exactly when every shard landed."""
    trackers, proxy = mk_world(4)
    committers = [CheckpointCommitter(proxy, str(tmp_path / "manifests"))
                  for _ in range(2)]
    step = 7
    for shard, t in enumerate(trackers[:-1]):
        t.ckpt_write(step, shard_id=shard, nbytes=1 << 20,
                     path=f"/ckpt/s{shard}", total_shards=4)
    pump_all(proxy, committers)
    assert committers[0].latest_committed() is None   # one shard missing
    trackers[-1].ckpt_write(step, shard_id=3, nbytes=1 << 20,
                            path="/ckpt/s3", total_shards=4)
    pump_all(proxy, committers)
    assert committers[0].latest_committed() == step
    assert os.path.exists(committers[0].manifest_path(step))


def test_checkpoint_committer_concurrent_members_no_lost_update(tmp_path):
    """Two load-balanced group members recording *different* shards of
    the same step concurrently must not lose either update.  The old
    shared ``step-*.shards.json`` was a read-modify-write that a
    per-instance lock cannot order across members; per-shard files
    cannot collide."""
    import threading

    trackers, proxy = mk_world(2)
    c1 = CheckpointCommitter(proxy, str(tmp_path / "manifests"))
    c2 = CheckpointCommitter(proxy, str(tmp_path / "manifests"))
    steps = list(range(25))

    def rec_for(step, shard):
        return R.ChangelogRecord(
            type=R.CL_CKPT_WRITE, tfid=R.Fid(1, shard, step),
            name=f"/ckpt/s{shard}".encode(), metrics=(1024.0,),
            xattr={"total_shards": 2})

    barrier = threading.Barrier(2)
    errors = []

    def member(committer, shard):
        try:
            for step in steps:
                barrier.wait()      # maximally overlap the two writers
                committer.handle("host0", rec_for(step, shard))
        except Exception as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=member, args=(c1, 0)),
               threading.Thread(target=member, args=(c2, 1))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    import json
    for step in steps:
        path = c1.manifest_path(step)
        assert os.path.exists(path), f"step {step} never committed"
        with open(path) as fh:
            manifest = json.load(fh)
        assert set(manifest["shards"]) == {"0", "1"}, step
    # committed steps leave no shard-file litter behind (the directory
    # stays bounded by in-flight steps)
    leftovers = [f for f in os.listdir(c1.dir) if ".shard-" in f]
    assert leftovers == []
    # a redelivered record of a committed step neither litters nor
    # rewrites the manifest
    c1.handle("host0", rec_for(steps[0], 0))
    assert not [f for f in os.listdir(c1.dir) if ".shard-" in f]
    c1.close()
    c2.close()


def test_straggler_detection():
    trackers, proxy = mk_world(4)
    det = StragglerDetector(proxy)
    for step in range(10):
        for h, t in enumerate(trackers):
            t.heartbeat(step, step_time_s=0.1 if h != 2 else 0.5)
    pump_all(proxy, [det])
    assert det.flagged == {2}


def test_straggler_evicted_on_leave():
    """flag -> leave -> unflag: a straggler that leaves the fleet
    (ELASTIC_LEAVE) is evicted from the EWMA map so it stops skewing
    the fleet median and ``flagged`` is not pinned forever."""
    trackers, proxy = mk_world(4)
    det = StragglerDetector(proxy)
    for step in range(10):
        for h, t in enumerate(trackers):
            t.heartbeat(step, step_time_s=0.1 if h != 2 else 0.5)
    pump_all(proxy, [det])
    assert det.flagged == {2}
    trackers[2].elastic(joined=False, n_hosts=3, step=10)
    pump_all(proxy, [det])
    assert 2 not in det.ewma
    assert det.flagged == set()
    # the survivors keep reporting; nobody is flagged against a median
    # the departed host no longer distorts
    for step in range(10, 15):
        for h, t in enumerate(trackers):
            if h != 2:
                t.heartbeat(step, step_time_s=0.1)
    pump_all(proxy, [det])
    assert det.flagged == set()


def test_straggler_stale_host_aged_out():
    """A host that silently stops heartbeating (no ELASTIC_LEAVE) is
    aged out once its last sample falls ``stale_after_s`` behind the
    newest sample in the stream."""
    trackers, proxy = mk_world(3)
    det = StragglerDetector(proxy, stale_after_s=30.0)
    t0 = R.now_ns()

    def hb(host, step, dt, at_s):
        trackers[host].llog.log(R.ChangelogRecord(
            type=R.CL_HEARTBEAT, tfid=R.Fid(1, host, step),
            time=t0 + int(at_s * 1e9), metrics=(dt,)))

    for step in range(5):
        for h in range(3):
            hb(h, step, 0.1 if h != 2 else 0.5, at_s=step)
    pump_all(proxy, [det])
    assert det.flagged == {2}
    # 40 stream-seconds later only hosts 0/1 are still alive
    for step in range(5, 8):
        for h in range(2):
            hb(h, step, 0.1, at_s=40 + step)
    pump_all(proxy, [det])
    assert 2 not in det.ewma
    assert det.flagged == set()


def test_elastic_membership_plan():
    trackers, proxy = mk_world(4)
    ctl = ElasticController(proxy, chips_per_host=4)
    for t in trackers:
        t.elastic(joined=True, n_hosts=4, step=0)
    pump_all(proxy, [ctl])
    assert ctl.members == {0, 1, 2, 3}
    assert ctl.plan()["usable"] == 16
    trackers[1].elastic(joined=False, n_hosts=3, step=5)
    pump_all(proxy, [ctl])
    assert ctl.members == {0, 2, 3}
    assert ctl.plan()["usable"] == 8          # 12 chips -> 8 usable


def test_cache_invalidation_ephemeral():
    """Ganesha-style: an ephemeral reader invalidates local cache entries
    on EVICT records, without ever blocking the journal trim."""
    trackers, proxy = mk_world(2)
    from repro.core.reader import LocalReader
    anchor = LocalReader(proxy, "metrics")    # persistent group
    cache = {(5, 1): "page-a", (6, 1): "page-b"}
    inv = CacheInvalidator(proxy, cache)
    trackers[0].evict(5, 1)
    proxy.pump()
    inv.poll()
    assert (5, 1) not in cache and (6, 1) in cache
    assert inv.invalidated == 1
    for pid, rec in anchor.fetch():
        anchor.ack(pid, rec.index)
    assert trackers[0].llog.first_index == trackers[0].llog.last_index + 1


def test_bootstrap_index_traversal(tmp_path):
    """§IV-C-2: a synthetic changelog stream from the object index is
    consumed collaboratively to populate a fresh metrics DB."""
    index = [(i, 1, f"obj{i}", 4096 * i) for i in range(100)]
    log = synthesize_index_stream(index)
    proxy = LcapProxy({"index0": log})
    db = str(tmp_path / "boot.sqlite")
    workers = [MetricsDB(proxy, db) for _ in range(4)]
    pump_all(proxy, workers)
    assert workers[0].query("SELECT COUNT(*) FROM events")[0][0] == 100
    # collaborative: every instance handled part of the traversal
    handled = [proxy.consumers[w.stream.cid].delivered for w in workers]
    assert all(h > 0 for h in handled) and sum(handled) == 100
    for w in workers:
        w.close()


def test_data_consume_records_support_replay():
    trackers, proxy = mk_world(2)
    from repro.core.reader import LocalReader
    r = LocalReader(proxy, "replay")
    trackers[0].data_consume(step=3, shard_id=11, lo=0, hi=512)
    trackers[1].data_consume(step=3, shard_id=12, lo=512, hi=1024)
    proxy.pump()
    got = r.fetch()
    ranges = sorted((rec.xattr["lo"], rec.xattr["hi"]) for _, rec in got)
    assert ranges == [(0, 512), (512, 1024)]


def test_cache_invalidator_requeues_on_handler_failure():
    """A persistent-mode invalidator whose handler dies mid-round must
    not lose the fetched batches: the base poll requeues them and the
    next poll retries from exactly where the failure hit."""
    trackers, proxy = mk_world(2)
    cache = {(oid, 1): f"page-{oid}" for oid in range(8)}
    inv = CacheInvalidator(proxy, cache, mode="persistent")
    for oid in range(8):
        trackers[oid % 2].evict(oid, 1)
    proxy.pump()

    real = inv.handle_batch
    calls = {"n": 0}

    def flaky(pid, batch):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient handler failure")
        real(pid, batch)

    inv.handle_batch = flaky
    try:
        inv.poll()
    except RuntimeError:
        pass
    else:
        raise AssertionError("poll swallowed the handler failure")
    # nothing was acknowledged unhandled; the retry sees every record
    n = 0
    for _ in range(10):
        n += inv.poll()
        proxy.pump()
    assert not cache
    assert inv.invalidated == 8
    inv.close()


def test_metrics_db_failed_close_parks_and_resumes(tmp_path):
    """close(failed=True) on a crashed MetricsDB parks the durable
    cursor (no TypeError from a mismatched override signature); a new
    instance under the same name resumes exactly there."""
    trackers, proxy = mk_world(1)
    db = str(tmp_path / "metrics.sqlite")
    w1 = MetricsDB(proxy, db, name="m0")
    for step in range(10):
        trackers[0].step_commit(step, loss=1.0, step_time_s=0.1, tokens=1)
    proxy.pump()
    w1.poll()                                  # commits: cursor at 10+
    cursor = dict(w1.stream.resume_token)
    for step in range(10, 20):
        trackers[0].step_commit(step, loss=1.0, step_time_s=0.1, tokens=1)
    proxy.pump()                               # dispatched, not yet polled
    w1.close(failed=True)                      # crash: park, don't drop

    w2 = MetricsDB(proxy, db, name="m0")
    assert proxy.stats["resumed"] == 1
    assert w2.stream.resumed
    assert w2.stream.resume_token == cursor    # resumed at the ack cursor
    n = 0
    for _ in range(10):
        n += w2.poll()
        proxy.pump()
    assert n == 10                             # only the unacked backlog
    assert w2.query("SELECT COUNT(*) FROM events")[0][0] == 20
    w2.close()
