"""Columnar hot path: decoded header columns, vectorized masks/hash/
fold, and bulk ack tracking agree bit-for-bit with the per-record
implementations they replaced.

Always-run tests drive seeded-random streams through both paths;
hypothesis property tests (skipped when hypothesis is absent, like
test_records.py) widen the input space.
"""

import random
import struct

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import records as R
from repro.core.ack import AckTracker
from repro.core.cluster import fid_slot, fid_slots, batch_slots
from repro.core.history import Compactor
from repro.core.modules import (CancelCompensating, CoalesceHeartbeats,
                                ReorderByTarget, TypeFilter)

ALL_TYPES = sorted(R.TYPE_NAMES)


def rand_record(rng: random.Random, index: int,
                rtype: int = None) -> R.ChangelogRecord:
    """A random record; extension fields present per a random mask."""
    flags = rng.randrange(R.CLF_SUPPORTED + 1)
    rec = R.ChangelogRecord(
        type=rtype if rtype is not None else rng.choice(ALL_TYPES),
        index=index, prev=max(0, index - rng.randrange(4)),
        time=rng.randrange(1 << 62),
        tfid=R.Fid(rng.randrange(1 << 64), rng.randrange(1 << 32),
                   rng.randrange(1 << 32)),
        pfid=R.Fid(rng.randrange(1 << 64), rng.randrange(1 << 32),
                   rng.randrange(1 << 32)),
        name=bytes(rng.randrange(97, 123) for _ in range(rng.randrange(9))))
    if flags & R.CLF_RENAME:
        rec.sfid, rec.spfid, rec.sname = (R.Fid(1, 2, 3), R.Fid(4, 5, 6),
                                          b"old")
    if flags & R.CLF_JOBID:
        rec.jobid = b"job-%d" % index
    if flags & R.CLF_SHARD:
        rec.shard = (1, 2, 3, index & 0xFFFF)
    if flags & R.CLF_METRICS:
        rec.metrics = (float(index), -1.5)
    if flags & R.CLF_XATTR:
        rec.xattr = {"i": index}
    return rec


def rand_batch(rng: random.Random, n: int, **kw) -> R.RecordBatch:
    return R.RecordBatch.from_records(
        [rand_record(rng, i + 1, **kw) for i in range(n)])


# ---------------------------------------------------------------- decode
def test_header_columns_match_struct_decode():
    rng = random.Random(1)
    batch = rand_batch(rng, 200)
    idx, typ, fl, tm = (batch.indices_np(), batch.types_np(),
                        batch.flags_np(), batch.times_np())
    tseq, toid, tver = batch.tfid_cols()
    pseq, poid, pver = batch.pfid_cols()
    for i in range(len(batch)):
        buf = batch.packed(i)
        namelen, flags, rtype = struct.unpack_from("<HHH", buf, 0)
        index, prev, time = struct.unpack_from("<QQQ", buf, 8)
        ts, to, tv = struct.unpack_from("<QII", buf, 32)
        ps, po, pv = struct.unpack_from("<QII", buf, 48)
        assert (int(idx[i]), int(typ[i]), int(fl[i]), int(tm[i])) == \
            (index, rtype, flags, time)
        assert (int(tseq[i]), int(toid[i]), int(tver[i])) == (ts, to, tv)
        assert (int(pseq[i]), int(poid[i]), int(pver[i])) == (ps, po, pv)
        # per-record accessors read the same cached columns
        assert batch.packed_index(i) == index
        assert batch.packed_type(i) == rtype
        assert batch.packed_tfid(i) == (ts, to, tv)


def test_columns_survive_select_and_concat():
    rng = random.Random(2)
    batch = rand_batch(rng, 64)
    batch.header()                          # force the cache
    rows = [5, 3, 3, 60, 0]
    sub = batch.select(rows)
    assert sub.indices() == [batch.packed_index(i) for i in rows]
    both = R.RecordBatch.concat([sub, batch[10:12]])
    assert both.types() == ([batch.packed_type(i) for i in rows]
                            + [batch.packed_type(10), batch.packed_type(11)])
    assert both.keys() == ([batch.keys()[i] for i in rows]
                           + batch.keys()[10:12])


# ------------------------------------------------------------------ hash
def _edge_fids():
    return [(0, 0, 0), (1, 0, 0), ((1 << 64) - 1, (1 << 32) - 1,
                                   (1 << 32) - 1), (1 << 63, 1, 2)]


def test_fid_slots_matches_scalar():
    rng = random.Random(3)
    keys = [(rng.randrange(1 << 64), rng.randrange(1 << 32),
             rng.randrange(1 << 32)) for _ in range(2000)] + _edge_fids()
    seq = np.array([k[0] for k in keys], dtype=np.uint64)
    oid = np.array([k[1] for k in keys], dtype=np.uint32)
    ver = np.array([k[2] for k in keys], dtype=np.uint32)
    for n_slots in (1, 2, 63, 64, 97, 1024):
        want = [fid_slot(k, n_slots) for k in keys]
        assert fid_slots(seq, oid, ver, n_slots).tolist() == want


def test_batch_slots_matches_scalar_keys():
    rng = random.Random(4)
    batch = rand_batch(rng, 128)
    assert batch_slots(batch, 64).tolist() == \
        [fid_slot(k, 64) for k in batch.keys()]


def test_jax_fid_slots_matches_scalar():
    stream_ops = pytest.importorskip("repro.kernels.stream_ops")
    rng = random.Random(5)
    keys = [(rng.randrange(1 << 64), rng.randrange(1 << 32),
             rng.randrange(1 << 32)) for _ in range(512)] + _edge_fids()
    seq = np.array([k[0] for k in keys], dtype=np.uint64)
    oid = np.array([k[1] for k in keys], dtype=np.uint32)
    ver = np.array([k[2] for k in keys], dtype=np.uint32)
    for n_slots in (3, 64, 65535):
        want = [fid_slot(k, n_slots) for k in keys]
        assert stream_ops.fid_slots(seq, oid, ver, n_slots).tolist() == want
        assert stream_ops.fid_slots_pallas(seq, oid, ver,
                                           n_slots).tolist() == want


# --------------------------------------------------------------- project
def test_project_strips_like_per_record_remap():
    """The dispatch stamp: ``project(flags)`` strips exactly what a
    per-record ``remap(buf, src & flags)`` strips — and never
    zero-fills fields the record did not carry."""
    rng = random.Random(6)
    batch = rand_batch(rng, 100)
    for want in (0, R.CLF_JOBID, R.CLF_JOBID | R.CLF_SHARD,
                 R.CLF_SUPPORTED):
        out = batch.project(want)
        for i in range(len(batch)):
            src = batch.packed_flags(i)
            assert out.packed(i) == R.remap(batch.packed(i), src & want)
            assert out.packed_flags(i) == src & want    # no zero-fill
    # all-subset fast path: nothing to strip -> same object
    uniform = R.RecordBatch.from_records(
        [rand_record(rng, i + 1) for i in range(4)]).project(R.CLF_SUPPORTED)
    assert uniform.project(R.CLF_SUPPORTED) is uniform


def test_remap_zero_fills_where_project_does_not():
    buf = R.pack(R.ChangelogRecord(type=R.CL_CREATE, index=1,
                                   tfid=R.Fid(1, 2, 3), name=b"f"))
    batch = R.RecordBatch.from_packed([buf])
    stamped = batch.project(R.CLF_JOBID | R.CLF_SHARD)
    assert stamped.packed_flags(0) == 0              # strip-only
    widened = batch.remap(R.CLF_JOBID | R.CLF_SHARD)
    rec = R.unpack(widened.packed(0))
    assert rec.jobid == b"" and rec.shard == (0, 0, 0, 0)   # zero-filled


# --------------------------------------------------------------- modules
def _assert_same(out_batch, out_list):
    assert [bytes(b) for b in out_batch] == [R.pack(r) for r in out_list]


def _module_case(rng, n):
    """A stream that exercises every module: heartbeats, create/unlink
    pairs (some hardlinked), ckpt writes, renames."""
    recs = []
    for i in range(n):
        roll = rng.random()
        if roll < 0.25:
            rec = rand_record(rng, i + 1, rtype=R.CL_HEARTBEAT)
            rec.tfid = R.Fid(0, rng.randrange(4), 0)     # few hosts
        elif roll < 0.5:
            rec = rand_record(rng, i + 1, rtype=rng.choice(
                [R.CL_CREATE, R.CL_UNLINK, R.CL_MKDIR, R.CL_RMDIR,
                 R.CL_HARDLINK]))
            rec.tfid = R.Fid(7, rng.randrange(6), 0)     # few targets
        elif roll < 0.7:
            rec = rand_record(rng, i + 1, rtype=R.CL_CKPT_WRITE)
            rec.tfid = R.Fid(1, rng.randrange(3), rng.randrange(2))
        else:
            rec = rand_record(rng, i + 1)
        recs.append(rec)
    return recs


@pytest.mark.parametrize("seed", [7, 8, 9])
def test_modules_columnar_matches_list_path(seed):
    rng = random.Random(seed)
    recs = _module_case(rng, 120)
    modules = [TypeFilter(set(ALL_TYPES) - {R.CL_MARK}),
               CoalesceHeartbeats(), CancelCompensating(),
               ReorderByTarget()]
    for mod in modules:
        batch = R.RecordBatch.from_records([r for r in recs])
        _assert_same(mod(batch), mod(list(recs)))


def test_reorder_by_target_sorts_and_identity():
    rng = random.Random(10)
    batch = rand_batch(rng, 50)
    out = ReorderByTarget()(batch)
    ks = [(k, i) for k, i in zip(out.keys(), out.indices())]
    assert ks == sorted(ks)
    assert ReorderByTarget()(out) is out       # already sorted: no copy


# ------------------------------------------------------------------ fold
def _reference_compact(batch):
    """The pre-columnar Compactor.compact: per-key dict grouping, every
    key folded."""
    comp = Compactor()
    n = len(batch)
    types = batch.types()
    rows_by_key = {}
    for i, k in enumerate(batch.keys()):
        rows_by_key.setdefault(k, []).append(i)
    drop, replace = set(), {}
    for rows in rows_by_key.values():
        comp._compact_key(batch, types, rows, drop, replace)
    out = [replace.get(i, None) or batch.packed(i)
           for i in range(n) if i not in drop]
    stats = {k: v for k, v in comp.stats.items() if k not in
             ("records_in", "records_out")}
    return out, stats


def _fold_case(rng, n):
    recs = []
    for i in range(n):
        roll = rng.random()
        if roll < 0.45:
            rec = rand_record(rng, i + 1, rtype=rng.choice(
                [R.CL_CREATE, R.CL_UNLINK, R.CL_HARDLINK, R.CL_MKDIR,
                 R.CL_RMDIR]))
        elif roll < 0.7:
            rec = rand_record(rng, i + 1, rtype=rng.choice(
                [R.CL_SETATTR, R.CL_HEARTBEAT, R.CL_MARK]))
        elif roll < 0.85:
            rec = rand_record(rng, i + 1, rtype=R.CL_RENAME)
            rec.sfid, rec.spfid, rec.sname = (R.Fid(9, 9, 9),
                                              R.Fid(8, 8, 8),
                                              b"from-%d" % i)
        else:
            rec = rand_record(rng, i + 1)
        rec.tfid = R.Fid(3, rng.randrange(8), 0)         # collide targets
        recs.append(rec)
    return R.RecordBatch.from_records(recs)


@pytest.mark.parametrize("seed", [11, 12, 13, 14])
def test_compactor_fold_matches_reference(seed):
    rng = random.Random(seed)
    batch = _fold_case(rng, 160)
    want, want_stats = _reference_compact(batch)
    comp = Compactor()
    out = comp.compact(batch)
    assert [bytes(b) for b in out] == [bytes(b) for b in want]
    assert comp.stats["records_in"] == len(batch)
    assert comp.stats["records_out"] == len(want)
    for k, v in want_stats.items():
        assert comp.stats[k] == v, k


def test_compactor_hardlinked_lifetime_survives():
    """A hardlinked CREATE+UNLINK pair must NOT annihilate (the unlink
    may have removed only one name) — on both the segment pre-pass and
    the reference path."""
    def rec(i, t):
        return R.ChangelogRecord(type=t, index=i, tfid=R.Fid(1, 1, 1),
                                 name=b"f%d" % i)
    plain = R.RecordBatch.from_records(
        [rec(1, R.CL_CREATE), rec(2, R.CL_UNLINK)])
    assert len(Compactor().compact(plain)) == 0          # annihilated
    linked = R.RecordBatch.from_records(
        [rec(1, R.CL_CREATE), rec(2, R.CL_HARDLINK), rec(3, R.CL_UNLINK)])
    out = Compactor().compact(linked)
    assert out.indices() == [1, 2, 3]                    # kept whole
    want, _ = _reference_compact(linked)
    assert [bytes(b) for b in out] == [bytes(b) for b in want]


def test_compactor_boring_batch_is_identity():
    rng = random.Random(15)
    batch = R.RecordBatch.from_records(
        [rand_record(rng, i + 1, rtype=R.CL_CREATE) for i in range(32)])
    comp = Compactor()
    assert comp.compact(batch) is batch
    assert comp.stats["records_out"] == 32


# ------------------------------------------------------------------- ack
def _drive_trackers(rounds, rng):
    """Scalar-op tracker vs bulk-op tracker over the same stream."""
    scalar, bulk = AckTracker(), AckTracker()
    live = []
    nxt = 1
    for _ in range(rounds):
        burst = list(range(nxt, nxt + rng.randrange(1, 40)))
        nxt = burst[-1] + 1
        rng.shuffle(burst)
        for i in burst:
            scalar.deliver(i)
        assert bulk.deliver_many(burst + burst[:3]) == len(burst)
        live.extend(burst)
        assert scalar.in_flight == bulk.in_flight
        k = rng.randrange(0, len(live) + 1)
        rng.shuffle(live)
        acks, live = live[:k], live[k:]
        for i in acks:
            scalar.ack(i)
        if rng.random() < 0.5:
            bulk.ack_many(acks)
        else:
            bulk.ack_many(np.asarray(sorted(acks), dtype=np.int64)
                          if acks else [])
        assert scalar.watermark == bulk.watermark
        assert scalar.in_flight == bulk.in_flight
        if rng.random() < 0.2 and live:
            thr = rng.choice(live)
            assert scalar.ack_through(thr) == bulk.ack_through(thr)
            live = [i for i in live if i > thr]
            assert scalar.in_flight == bulk.in_flight
    # drain everything: both converge to the same final watermark
    for i in live:
        scalar.ack(i)
    bulk.ack_many(live)
    assert scalar.watermark == bulk.watermark == nxt - 1
    assert scalar.in_flight == bulk.in_flight == 0


@pytest.mark.parametrize("seed", [16, 17, 18])
def test_ack_tracker_bulk_matches_scalar(seed):
    _drive_trackers(60, random.Random(seed))


def test_ack_tracker_bulk_ignores_stale_and_duplicate():
    tr = AckTracker()
    assert tr.deliver_many([3, 1, 2, 2, 3]) == 3
    assert tr.ack_many([1, 2, 3]) == 3
    assert tr.deliver_many([3, 2, 1]) == 0        # all below watermark
    assert tr.in_flight == 0
    tr.deliver_many([5, 7])
    assert tr.ack_many([7]) == 3                  # hole at 5 blocks
    assert tr.ack_many([5]) == 7


# ----------------------------------------------------- hypothesis widening
if not HAVE_HYPOTHESIS:                   # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_fid_slots():
        ...

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_ack_bulk():
        ...

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_compactor_fold():
        ...

else:
    fid_ints = st.tuples(st.integers(0, 2**64 - 1),
                         st.integers(0, 2**32 - 1),
                         st.integers(0, 2**32 - 1))

    @settings(max_examples=100, deadline=None)
    @given(keys=st.lists(fid_ints, min_size=1, max_size=64),
           n_slots=st.integers(1, 4096))
    def test_property_fid_slots(keys, n_slots):
        seq = np.array([k[0] for k in keys], dtype=np.uint64)
        oid = np.array([k[1] for k in keys], dtype=np.uint32)
        ver = np.array([k[2] for k in keys], dtype=np.uint32)
        assert fid_slots(seq, oid, ver, n_slots).tolist() == \
            [fid_slot(k, n_slots) for k in keys]

    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1))
    def test_property_ack_bulk(seed):
        _drive_trackers(12, random.Random(seed))

    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1))
    def test_property_compactor_fold(seed):
        rng = random.Random(seed)
        batch = _fold_case(rng, rng.randrange(1, 80))
        want, _ = _reference_compact(batch)
        out = Compactor().compact(batch)
        assert [bytes(b) for b in out] == [bytes(b) for b in want]
