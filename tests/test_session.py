"""Unified Session/Subscription/Stream API: one implementation over
both bindings, server-side op-type + flag pushdown, durable consumers
with exact-cursor resume, typed errors."""

import time

import pytest

from repro.core import records as R
from repro.core.errors import (SessionError, SubscriptionError,
                               UnknownConsumerError)
from repro.core.llog import Llog
from repro.core.proxy import LcapProxy
from repro.core.server import LcapService
from repro.core.session import Subscription, connect


def rec(t=R.CL_CREATE, oid=1, name=b"f", **kw):
    return R.ChangelogRecord(type=t, tfid=R.Fid(1, oid, 0),
                             pfid=R.Fid(1, 0, 0), name=name,
                             jobid=b"job", **kw)


def mk_proxy(n_producers=1, **kw):
    logs = {f"mdt{i}": Llog(f"mdt{i}") for i in range(n_producers)}
    return LcapProxy(logs, **kw), logs


def feed_types(logs, n_each, types):
    """Round-robin over ``types`` so each appears n_each/len(types) times."""
    for log in logs.values():
        for i in range(n_each):
            log.log(rec(t=types[i % len(types)], oid=i))


def drain_all(stream, max_records=4096):
    got = []
    for pid, batch in stream:
        got.extend((pid, batch.packed_index(i)) for i in range(len(batch)))
        assert len(got) <= max_records
    return got


@pytest.fixture()
def service():
    proxy, logs = mk_proxy(2)
    svc = LcapService(proxy, poll_interval=0.001).start()
    yield svc, proxy, logs
    svc.stop()


def wait_for(cond, timeout=5.0):
    deadline = time.time() + timeout
    while not cond() and time.time() < deadline:
        time.sleep(0.002)
    assert cond()


# ---------------------------------------------------------------- bindings
def test_one_api_both_bindings(service):
    """connect() serves the in-process proxy and the wire through the
    same Session implementation."""
    svc, proxy, logs = service
    local = connect(proxy).subscribe("g-local")
    remote = connect(svc.address).subscribe("g-remote")
    feed_types(logs, 5, [R.CL_CREATE])
    got_local, got_remote = [], []
    wait_for(lambda: (got_local.extend(drain_all(local)),
                      got_remote.extend(drain_all(remote)),
                      len(got_local) == 10 and len(got_remote) == 10)[-1])
    assert remote.cursors["mdt0"] == 5 and remote.cursors["mdt1"] == 5
    local.commit()
    remote.commit()
    proxy.flush_upstream()
    wait_for(lambda: all(log.first_index == 6 for log in logs.values()))


def test_connect_accepts_service_and_host_string(service):
    svc, proxy, logs = service
    host, port = svc.address
    for target in (svc, f"{host}:{port}"):
        stream = connect(target).subscribe("g")
        stream.close()


# ---------------------------------------------------------------- pushdown
def test_op_type_pushdown_copies_one_in_n():
    """A subscription filtering to 1 of N op types makes the proxy copy
    ~1/N of the records into that consumer's outbox; the rest are acked
    in place (never materialized into any outbox)."""
    proxy, logs = mk_proxy(1)
    types = [R.CL_CREATE, R.CL_UNLINK, R.CL_MKDIR, R.CL_SETATTR]
    stream = connect(proxy).subscribe("g", types={R.CL_SETATTR})
    feed_types(logs, 100, types)
    proxy.pump()
    assert proxy.stats["ingested"] == 100
    assert proxy.stats["dispatched"] == 25          # 1 of 4 op types
    assert proxy.stats["filtered_out"] == 75
    assert proxy.consumers[stream.cid].delivered == 25
    got = drain_all(stream)
    assert len(got) == 25
    stream.commit()
    proxy.flush_upstream()
    # filtered records never block the collective ack/trim
    assert logs["mdt0"].first_index == 101


def test_pushdown_filters_within_group_members():
    """Members of one group with different masks: each record goes to a
    member that asked for its type."""
    proxy, logs = mk_proxy(1)
    session = connect(proxy)
    creat = session.subscribe("g", types={R.CL_CREATE})
    other = session.subscribe("g")                  # takes everything
    feed_types(logs, 40, [R.CL_CREATE, R.CL_UNLINK])
    proxy.pump()
    got_creat = drain_all(creat)
    got_other = drain_all(other)
    assert len(got_creat) + len(got_other) == 40
    # feed alternates CREATE/UNLINK, so CREATEs hold the odd indices —
    # the filtered member must never have received an even (UNLINK) one
    assert all(i % 2 == 1 for _, i in got_creat)
    # every UNLINK had to land on the unfiltered member
    assert len(got_other) >= 20


def test_ephemeral_pushdown():
    proxy, logs = mk_proxy(1)
    anchor = connect(proxy).subscribe("g")
    eph = connect(proxy).subscribe(mode="ephemeral",
                                   types={R.CL_UNLINK})
    feed_types(logs, 10, [R.CL_CREATE, R.CL_UNLINK])
    proxy.pump()
    got = drain_all(eph)
    assert len(got) == 5
    drain_all(anchor)


def test_flag_projection_via_session():
    """§IV-A field projection still rides the same subscription."""
    proxy, logs = mk_proxy(1)
    narrow = connect(proxy).subscribe("old", flags=0)
    wide = connect(proxy).subscribe("new")
    logs["mdt0"].log(rec(metrics=(3.5,)))
    proxy.pump()
    ((_, b_old),) = narrow.fetch()
    ((_, b_new),) = wide.fetch()
    assert b_old.record(0).jobid is None and b_old.record(0).metrics is None
    assert b_new.record(0).jobid == b"job" and b_new.record(0).metrics == (3.5,)


# ------------------------------------------------------------- auto-commit
def test_iterate_auto_commits():
    proxy, logs = mk_proxy(1)
    stream = connect(proxy).subscribe("g")
    feed_types(logs, 20, [R.CL_CREATE])
    proxy.pump()
    assert len(drain_all(stream)) == 20
    # the terminal fetch round committed every yielded batch
    assert stream.pending_commit == 0
    proxy.flush_upstream()
    assert logs["mdt0"].first_index == 21
    assert stream.resume_token == {"mdt0": 20}


def test_explicit_commit_mode():
    proxy, logs = mk_proxy(1)
    stream = connect(proxy).subscribe("g", auto_commit=False)
    feed_types(logs, 10, [R.CL_CREATE])
    proxy.pump()
    assert len(drain_all(stream)) == 10
    assert stream.pending_commit == 10
    proxy.flush_upstream()
    assert logs["mdt0"].first_index == 1            # nothing acked yet
    assert stream.commit() == 10
    proxy.flush_upstream()
    assert logs["mdt0"].first_index == 11


# ------------------------------------------------- durable consumer failure
def test_durable_crash_then_resume_exact_cursor():
    """(b) of the failure-semantics contract: a durable consumer that
    reconnects under the same name resumes at its ack cursor — its own
    unacked records are replayed to it alone, with no redelivery storm
    into the surviving members."""
    proxy, logs = mk_proxy(1)
    survivor = connect(proxy).subscribe("g")
    worker = connect(proxy).subscribe("g", name="w0")
    feed_types(logs, 40, [R.CL_CREATE])
    proxy.pump()
    first = worker.fetch(4)
    worker.commit()
    acked = [i for _, b in first for i in b.indices()]
    unacked = [i for _, b in worker.fetch(100) for i in b.indices()]
    survivor_before = proxy.consumers[survivor.cid].delivered
    worker.close(failed=True)                       # crash mid-flight
    proxy.pump()
    assert proxy.stats["parked"] == 1
    assert proxy.stats["redelivered"] == 0          # no storm
    assert proxy.consumers[survivor.cid].delivered == survivor_before

    resumed = connect(proxy).resume("g", "w0")
    assert resumed.resumed
    assert resumed.resume_token == {"mdt0": max(acked)}
    replay = [i for _, b in resumed.fetch(100) for i in b.indices()]
    assert replay == unacked                        # exact cursor resume
    assert proxy.stats["redelivered"] == 0
    resumed.commit()
    survivor_got = drain_all(survivor)
    survivor.commit()
    proxy.flush_upstream()
    assert len(replay) + len(acked) + len(survivor_got) == 40
    assert logs["mdt0"].first_index == 41           # fully trimmed


def test_durable_expiry_redelivers_to_survivors():
    """(a) of the failure-semantics contract: when the durable consumer
    does NOT come back, its backlog goes to the surviving members once
    the park window lapses (at-least-once)."""
    proxy, logs = mk_proxy(1, resume_ttl=0.0)
    survivor = connect(proxy).subscribe("g")
    worker = connect(proxy).subscribe("g", name="w0")
    feed_types(logs, 30, [R.CL_CREATE])
    proxy.pump()
    lost = [i for _, b in worker.fetch(100) for i in b.indices()]
    assert lost
    worker.close(failed=True)
    proxy.pump()                                    # ttl=0: expires now
    assert proxy.stats["parks_expired"] == 1
    assert proxy.stats["redelivered"] == len(lost)
    seen = {i for _, i in drain_all(survivor)}
    survivor.commit()
    proxy.flush_upstream()
    assert seen == set(range(1, 31))                # nothing lost
    assert logs["mdt0"].first_index == 31


def test_durable_forget_redelivers_immediately():
    proxy, logs = mk_proxy(1)
    survivor = connect(proxy).subscribe("g")
    worker = connect(proxy).subscribe("g", name="w0")
    feed_types(logs, 10, [R.CL_CREATE])
    proxy.pump()
    worker.fetch(100)
    worker.close(failed=True)
    proxy.forget("g", "w0")
    assert {i for _, i in drain_all(survivor)} == set(range(1, 11))
    with pytest.raises(UnknownConsumerError):
        proxy.forget("g", "w0")


def test_resume_inherits_parked_subscription_spec():
    """A bare resume(group, name) keeps the filters the consumer
    declared when it first subscribed — flags and op-type mask both."""
    proxy, logs = mk_proxy(1)
    worker = connect(proxy).subscribe("g", name="w0", flags=R.CLF_JOBID,
                                      types={R.CL_SETATTR})
    worker.close(failed=True)
    resumed = connect(proxy).resume("g", "w0")
    cons = proxy.consumers[resumed.cid]
    assert cons.flags == R.CLF_JOBID
    assert cons.types == frozenset({R.CL_SETATTR})
    # ...and explicit overrides win
    resumed.close(failed=True)
    widened = connect(proxy).resume("g", "w0", types={R.CL_SETATTR,
                                                      R.CL_CREATE})
    assert proxy.consumers[widened.cid].types == \
        frozenset({R.CL_SETATTR, R.CL_CREATE})
    assert proxy.consumers[widened.cid].flags == R.CLF_JOBID


def test_resume_with_narrowed_types_routes_excluded_backlog():
    """Explicitly narrowing the op-type mask on resume filters the
    replayed backlog too: excluded records go back through group
    dispatch (another member, or acked in place) — never to the
    narrowed consumer."""
    proxy, logs = mk_proxy(1)
    worker = connect(proxy).subscribe("g", name="w0")   # all types
    feed_types(logs, 10, [R.CL_CREATE, R.CL_SETATTR])
    proxy.pump()
    worker.fetch(100)                                   # all 10 in flight
    worker.close(failed=True)
    resumed = connect(proxy).resume("g", "w0", types={R.CL_SETATTR})
    replay = [i for _, b in resumed.fetch(100) for i in b.indices()]
    assert replay == [2, 4, 6, 8, 10]                   # SETATTRs only
    resumed.commit()
    proxy.flush_upstream()
    # the excluded CREATEs were acked in place (no member wanted them),
    # so the journal still trims completely
    assert logs["mdt0"].first_index == 11


def test_resumed_stream_remaps_with_inherited_flags():
    """The local remap of a resumed stream follows the *effective*
    (inherited) projection: fields the parked spec never requested stay
    absent, not zero-filled into existence."""
    proxy, logs = mk_proxy(1)
    worker = connect(proxy).subscribe("g", name="w0", flags=R.CLF_JOBID)
    logs["mdt0"].log(rec(metrics=(1.5,)))
    logs["mdt0"].log(rec(metrics=(2.5,)))
    proxy.pump()
    ((_, b),) = worker.fetch(1)
    assert b.record(0).metrics is None          # not requested
    worker.close(failed=True)
    resumed = connect(proxy).resume("g", "w0")  # bare: inherit CLF_JOBID
    ((_, b2),) = resumed.fetch(100)
    assert b2.record(0).metrics is None         # still not fabricated
    assert b2.record(0).jobid == b"job"


def test_resume_false_is_honored_on_both_bindings(service):
    """resume=False (never touch parked state) must behave identically
    through the in-process and wire backends."""
    svc, proxy, _ = service
    for tag, target in (("local", proxy), ("wire", svc.address)):
        name = f"w-{tag}"
        worker = connect(target).subscribe("g2", name=name)
        worker.close(failed=True)
        wait_for(lambda: name in proxy.groups["g2"].parked)
        with pytest.raises(SubscriptionError, match="parked state"):
            connect(target).subscribe("g2", name=name, resume=False)
        with pytest.raises(SubscriptionError, match="durable consumer name"):
            connect(target).subscribe("g2", resume=True)   # no name


def test_stream_commit_keeps_acks_across_a_failed_call():
    proxy, logs = mk_proxy(1)
    stream = connect(proxy).subscribe("g", auto_commit=False)
    feed_types(logs, 5, [R.CL_CREATE])
    proxy.pump()
    drain_all(stream)
    orig = stream.session._backend.commit
    calls = []

    def flaky(cid, acks):
        calls.append(cid)
        if len(calls) == 1:
            raise ConnectionError("transient")
        return orig(cid, acks)

    stream.session._backend.commit = flaky
    with pytest.raises(ConnectionError):
        stream.commit()
    assert stream.pending_commit == 5                   # kept, not lost
    assert stream.commit() == 5                         # retry succeeds
    proxy.flush_upstream()
    assert logs["mdt0"].first_index == 6


def test_fully_filtered_producer_still_trims():
    """A producer whose records are ALL filtered by pushdown is trimmed
    by pump() alone — in-place acks propagate upstream without any
    consumer commit or explicit flush."""
    proxy, logs = mk_proxy(1)
    connect(proxy).subscribe("g", types={R.CL_CKPT_WRITE})
    feed_types(logs, 10, [R.CL_CREATE])           # nothing matches
    proxy.pump()
    assert proxy.stats["filtered_out"] == 10
    assert logs["mdt0"].first_index == 11         # trimmed, no flush call


def test_requeue_returns_failed_batches_to_the_stream():
    """Stream.requeue withdraws delivered-but-unprocessed batches from
    the pending set AND hands them out again first on the next fetch —
    a retrying consumer reprocesses instead of wedging or false-acking
    them."""
    proxy, logs = mk_proxy(1)
    stream = connect(proxy).subscribe("g", auto_commit=False)
    feed_types(logs, 6, [R.CL_CREATE])
    proxy.pump()
    batches = stream.fetch(100)
    stream.requeue(batches[1:])                   # "handler failed" on #2+
    kept = sum(len(b) for _, b in batches[:1])
    assert stream.commit() == kept                # only the handled part
    again = stream.fetch(100)                     # requeued come back first
    assert [i for _, b in again for i in b.indices()] == \
        [i for _, b in batches[1:] for i in b.indices()]
    assert stream.commit() == 6 - kept
    proxy.flush_upstream()
    assert logs["mdt0"].first_index == 7


def test_worker_poll_retries_failed_batches_without_false_acks():
    """A _GroupWorker whose handler raises must neither acknowledge the
    unprocessed records nor lose them: the next poll retries exactly
    the same records (at-least-once for a live, retrying worker)."""
    from repro.track.consumers import _GroupWorker

    class Flaky(_GroupWorker):
        def __init__(self, proxy):
            super().__init__(proxy, "g")
            self.fail = True
            self.handled = []

        def handle_batch(self, pid, batch):
            if self.fail:
                raise RuntimeError("db locked")
            self.handled.extend(batch.indices())

    proxy, logs = mk_proxy(1)
    w = Flaky(proxy)
    feed_types(logs, 5, [R.CL_CREATE])
    proxy.pump()
    with pytest.raises(RuntimeError):
        w.poll()
    proxy.flush_upstream()
    assert logs["mdt0"].first_index == 1          # nothing falsely acked
    w.fail = False
    assert w.poll() == 5                          # same records, retried
    assert w.handled == [1, 2, 3, 4, 5]
    proxy.flush_upstream()
    assert logs["mdt0"].first_index == 6          # now acked and trimmed
    w.close()


def test_straggler_survives_truncated_step_commit_metrics():
    from repro.track.consumers import StragglerDetector
    proxy, logs = mk_proxy(1)
    det = StragglerDetector(proxy)
    logs["mdt0"].log(rec(t=R.CL_STEP_COMMIT))             # no metrics
    logs["mdt0"].log(rec(t=R.CL_STEP_COMMIT, metrics=(0.5,)))
    proxy.pump()
    det.poll()                                            # must not raise
    det.close()


def test_commit_unknown_producer_is_typed_error():
    proxy, logs = mk_proxy(1)
    stream = connect(proxy).subscribe("g", auto_commit=False)
    feed_types(logs, 2, [R.CL_CREATE])
    proxy.pump()
    drain_all(stream)
    with pytest.raises(KeyError, match="unknown producer"):
        proxy.commit(stream.cid, {"mdt-typo": [1, 2]})
    # no phantom tracker was created for the bogus producer id
    assert all("mdt-typo" not in g.trackers for g in proxy.groups.values())
    assert stream.commit() == 2


def test_durable_name_conflict_and_detach():
    proxy, logs = mk_proxy(1)
    session = connect(proxy)
    worker = session.subscribe("g", name="w0")
    with pytest.raises(SubscriptionError, match="already attached"):
        session.subscribe("g", name="w0")
    worker.detach()                                 # graceful park
    assert proxy.stats["parked"] == 1
    resumed = session.resume("g", "w0")
    assert resumed.resumed


def test_remote_durable_resume_over_tcp(service):
    """Durable park/resume across real connections: the socket dies,
    the service parks the consumer, a new connection resumes it."""
    svc, proxy, logs = service
    survivor = connect(svc.address).subscribe("g")
    worker = connect(svc.address).subscribe("g", name="w0")
    for i in range(30):
        logs["mdt0"].log(rec(oid=i))
    wait_for(lambda: proxy.stats["dispatched"] >= 30)
    got = [i for _, b in worker.fetch(10) for i in b.indices()]
    assert got
    worker.commit()
    unacked = [i for _, b in worker.fetch(100) for i in b.indices()]
    worker.close(failed=True)                       # drop the socket
    wait_for(lambda: proxy.stats["parked"] == 1)
    assert proxy.stats["redelivered"] == 0

    resumed = connect(svc.address).resume("g", "w0")
    assert resumed.resumed
    assert resumed.resume_token == {"mdt0": max(got)}
    replay = [i for _, b in resumed.fetch(100) for i in b.indices()]
    assert replay == unacked
    resumed.commit()
    seen = set(got) | set(replay) | \
        {i for _, i in drain_all(survivor)}
    survivor.commit()
    wait_for(lambda: logs["mdt0"].first_index == 31)
    assert seen == set(range(1, 31))


# ------------------------------------------------------------ typed errors
def test_typed_errors_local():
    proxy, _ = mk_proxy(1)
    session = connect(proxy)
    with pytest.raises(SubscriptionError):
        session.subscribe(None)                     # persistent needs group
    with pytest.raises(SubscriptionError):
        Subscription(mode="ephemeral", name="w0")   # durable ephemeral
    with pytest.raises(UnknownConsumerError, match="unknown or unsub"):
        proxy.fetch_batches("nope")
    with pytest.raises(UnknownConsumerError):
        session.resume("g", "never-existed")
    # typed errors remain catchable as the builtins the old API raised
    with pytest.raises(KeyError):
        proxy.commit("nope", {"mdt0": [1]})
    with pytest.raises(ValueError):
        session.subscribe("g", mode="bogus")


def test_typed_errors_remote(service):
    svc, proxy, _ = service
    session = connect(svc.address)
    with pytest.raises(UnknownConsumerError, match="unknown or unsub"):
        session._backend.fetch("nope", 10)
    with pytest.raises(SubscriptionError):
        session.subscribe(None)
    with pytest.raises(UnknownConsumerError):
        session.resume("g", "never-existed")


def test_unknown_op_and_version_are_typed(service):
    svc, _, _ = service
    session = connect(svc.address)
    reply = session._backend.rpc.call({"op": "frobnicate"})
    assert reply["err_type"] == "SessionError"
    with pytest.raises(SessionError, match="unknown op"):
        session._backend._call({"op": "frobnicate"})
    with pytest.raises(SessionError, match="protocol version"):
        session._backend._call({"op": "stats", "v": 99})


def test_legacy_register_defaults_to_supported_flags(service):
    """The subscribe-default divergence is gone: a legacy register with
    no flags gets CLF_SUPPORTED, same as every other path."""
    svc, proxy, _ = service
    session = connect(svc.address)
    reply = session._backend.rpc.call({"op": "register", "group": "g"})
    assert proxy.consumers[reply["cid"]].flags == R.CLF_SUPPORTED
    # and unknown bits are masked at the single enforcement point
    cid2 = proxy.subscribe("g", flags=0xFFFF)
    assert proxy.consumers[cid2].flags == R.CLF_SUPPORTED


# ------------------------------------------------------------------ commit
def test_commit_spans_producers_in_one_call():
    proxy, logs = mk_proxy(3)
    stream = connect(proxy).subscribe("g", auto_commit=False)
    feed_types(logs, 5, [R.CL_CREATE])
    proxy.pump()
    drain_all(stream)
    assert stream.pending_commit == 15
    assert stream.commit() == 15                    # one call, 3 producers
    proxy.flush_upstream()
    assert all(log.first_index == 6 for log in logs.values())
    assert stream.resume_token == {f"mdt{i}": 5 for i in range(3)}
