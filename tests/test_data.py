"""Data pipeline: determinism, shard disjointness, replay/restart."""

import numpy as np

from repro.core.proxy import LcapProxy
from repro.core.reader import LocalReader
from repro.data import ShardedTokenPipeline
from repro.track import ActivityTracker


def test_batches_are_deterministic():
    a = ShardedTokenPipeline(1000, 16, 8, 2, 0, seed=3)
    b = ShardedTokenPipeline(1000, 16, 8, 2, 0, seed=3)
    for _ in range(3):
        ba, bb = next(a), next(b)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])


def test_shards_differ_and_seed_matters():
    s0 = next(ShardedTokenPipeline(1000, 16, 8, 2, 0, seed=3))
    s1 = next(ShardedTokenPipeline(1000, 16, 8, 2, 1, seed=3))
    s0b = next(ShardedTokenPipeline(1000, 16, 8, 2, 0, seed=4))
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    assert not np.array_equal(s0["tokens"], s0b["tokens"])


def test_labels_are_shifted_tokens():
    b = next(ShardedTokenPipeline(1000, 16, 8, 2, 0))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_seek_replays_identically():
    p = ShardedTokenPipeline(1000, 16, 8, 2, 0)
    batches = [next(p) for _ in range(5)]
    p.seek(2)
    replay = next(p)
    np.testing.assert_array_equal(replay["tokens"], batches[2]["tokens"])


def test_consumption_records_drive_resume():
    """The DATA_CONSUME records in the journal are sufficient to resume
    at the exact step (exactly-where restart)."""
    tr = ActivityTracker(run_id=1, host_id=0)
    proxy = LcapProxy({tr.llog.producer_id: tr.llog})
    reader = LocalReader(proxy, "replay")
    p = ShardedTokenPipeline(1000, 16, 8, 2, 0, tracker=tr)
    for _ in range(4):
        next(p)
    proxy.pump()
    recs = [rec for _, rec in reader.fetch(100)]
    resume = ShardedTokenPipeline.resume_step_from_records(recs)
    assert resume == 4
    fresh = ShardedTokenPipeline(1000, 16, 8, 2, 0)
    fresh.seek(resume)
    np.testing.assert_array_equal(next(fresh)["tokens"],
                                  p.batch_at(4)["tokens"])
