"""Federated multi-tenant activity plane (tentpole): tenant principals
and server-side scope pushdown, per-tenant quota park/resume, the
origin-tagged v2 wire trailer, GlobalCursor bookkeeping, federation
fan-in over multiple clusters, and the adversarial isolation invariant
— a tenant-scoped consumer never observes an out-of-scope record, no
matter what the topology does (replay bootstrap, live slot migration,
forced shard failover, federation fan-in)."""

import time

import pytest

import repro.core.cluster as cluster_mod
from repro.core import records as R
from repro.core.cluster import LcapCluster
from repro.core.errors import TenantError, UnknownConsumerError
from repro.core.federation import Federation, GlobalCursor
from repro.core.llog import Llog
from repro.core.proxy import LcapProxy
from repro.core.server import LcapService
from repro.core.session import Subscription, connect
from repro.core.tenancy import TenantPrincipal, TokenBucket
from repro.obs.registry import MetricsRegistry
from repro.track import AuditTrail


def rec(oid=1, ver=0, t=R.CL_CREATE, name=b"f", jobid=None, **kw):
    return R.ChangelogRecord(type=t, tfid=R.Fid(1, oid, ver),
                             pfid=R.Fid(1, 0, 0), name=name,
                             jobid=jobid, **kw)


def feed(log, jobid, n, base=0, t=R.CL_CREATE):
    for i in range(n):
        log.log(rec(oid=base + i, t=t, jobid=jobid,
                    name=f"{base + i}".encode()))


ACME = TenantPrincipal("acme", prefixes=[b"acme."])
EVIL = TenantPrincipal("evil", prefixes=[b"evil."])


def drain_scoped(pump, stream, rounds=200):
    """Pump + fetch until quiescent; returns the set of jobids seen and
    (pid, index) delivery pairs."""
    jobids, seen = set(), set()
    idle = 0
    for _ in range(rounds):
        moved = pump() if pump else 0
        got = 0
        for item in stream.fetch(4096):
            pid, batch = item[-2], item[-1]
            for i in range(len(batch)):
                r = batch.record(i)
                jobids.add(bytes(r.jobid or b""))
                seen.add((pid, r.index))
            got += len(batch)
        stream.commit()
        if not moved and not got and not stream.replaying:
            idle += 1
            if idle >= 3:
                break
        else:
            idle = 0
    return jobids, seen


# ------------------------------------------------------------ principals
def test_tenant_principal_validation():
    with pytest.raises(TenantError):
        TenantPrincipal("")                       # no name
    with pytest.raises(TenantError):
        TenantPrincipal("t")                      # empty scope
    with pytest.raises(TenantError):
        TenantPrincipal("t", prefixes=[b""])      # silent widening
    with pytest.raises(TenantError):
        TenantPrincipal("t", jobids=[b""])
    with pytest.raises(TenantError):
        TenantPrincipal("t", jobids=[b"x" * 33])  # > jobid field
    p = TenantPrincipal("t", jobids=["a.1"], prefixes=["b."])
    assert p.allows(b"a.1") and p.allows(b"b.whatever")
    assert not p.allows(b"a.12") and not p.allows(b"")
    # value-object equality + wire round trip
    q = TenantPrincipal.from_wire(p.to_wire())
    assert q == p
    assert TenantPrincipal.from_wire(None) is None
    with pytest.raises(TenantError):
        TenantPrincipal.from_wire({"jobids": ["x"]})   # no name


def test_scope_mask_matches_scalar():
    import numpy as np
    p = TenantPrincipal("t", jobids=[b"exact"], prefixes=[b"pre."])
    jobs = [b"exact", b"exactly", b"pre.a", b"pr", b"", b"other"]
    col = np.zeros((len(jobs), 32), dtype=np.uint8)
    for i, j in enumerate(jobs):
        col[i, :len(j)] = np.frombuffer(j, dtype=np.uint8)
    assert p.scope_mask(col).tolist() == [p.allows(j) for j in jobs]


# ---------------------------------------------------------- scope pushdown
def test_tenant_pushdown_single_proxy():
    log = Llog("mdt0")
    proxy = LcapProxy({"mdt0": log})
    sess = connect(proxy)
    scoped = sess.subscribe(Subscription(group="g", tenant=ACME,
                                         auto_commit=False))
    feed(log, b"acme.job", 5)
    feed(log, b"evil.job", 5, base=100)
    feed(log, None, 3, base=200)          # unattributed: invisible
    jobids, seen = drain_scoped(proxy.pump, scoped)
    assert jobids == {b"acme.job"}
    assert len(seen) == 5
    # out-of-scope records were acked in place, not parked: journal
    # trims once flushed, and the stat attributes them
    assert proxy.stats["tenant_filtered"] == 8
    proxy.flush_upstream()
    assert log.first_index > 1
    acct = proxy.tenants["acme"]
    assert acct.delivered_records == 5
    assert acct.delivered_bytes > 0


def test_tenant_pushdown_columnar_partition():
    # two tenants plus an unscoped consumer in distinct groups: the
    # columnar dispatch partitions each batch by (type, tenant)
    # eligibility; every group sees exactly its slice
    log = Llog("mdt0")
    proxy = LcapProxy({"mdt0": log}, batch_size=256)
    sess = connect(proxy)
    a = sess.subscribe(Subscription(group="ga", tenant=ACME,
                                    auto_commit=False))
    e = sess.subscribe(Subscription(group="ge", tenant=EVIL,
                                    auto_commit=False))
    u = sess.subscribe(Subscription(group="gu", auto_commit=False))
    for i in range(40):
        jid = (b"acme.j", b"evil.j", None)[i % 3]
        log.log(rec(oid=i, jobid=jid))
    ja, sa = drain_scoped(proxy.pump, a)
    je, se = drain_scoped(None, e)
    ju, su = drain_scoped(None, u)
    assert ja == {b"acme.j"} and len(sa) == 14
    assert je == {b"evil.j"} and len(se) == 13
    assert len(su) == 40                  # unscoped sees everything
    assert b"" in ju


def test_tenant_scoped_ephemeral_consumer():
    log = Llog("mdt0")
    proxy = LcapProxy({"mdt0": log})
    sess = connect(proxy)
    eph = sess.subscribe(Subscription(mode="ephemeral", tenant=ACME))
    feed(log, b"acme.x", 3)
    feed(log, b"evil.x", 3, base=50)
    jobids, seen = drain_scoped(proxy.pump, eph)
    assert jobids == {b"acme.x"} and len(seen) == 3


def test_tenant_replay_bootstrap_is_scoped(tmp_path):
    log = Llog("mdt0", path=str(tmp_path / "j"), segment_records=8,
               history=True)
    proxy = LcapProxy({"mdt0": log})
    live = connect(proxy).subscribe("live")
    feed(log, b"acme.old", 10)
    feed(log, b"evil.old", 10, base=100)
    proxy.pump()
    for _ in live:
        pass
    live.commit()
    proxy.flush_upstream()
    assert log.first_index > 1            # history is the only source now
    boot = connect(proxy).subscribe(Subscription(group="boot", tenant=ACME,
                                                 replay=True,
                                                 auto_commit=False))
    jobids, seen = drain_scoped(proxy.pump, boot)
    assert jobids == {b"acme.old"}
    assert len(seen) == 10
    assert boot.replayed == 10            # filtered history never counted
    assert proxy.tenants["acme"].replayed_records == 10


# ------------------------------------------------------- durable identity
def test_resume_inherits_and_guards_tenant():
    log = Llog("mdt0")
    proxy = LcapProxy({"mdt0": log})
    sess = connect(proxy)
    s = sess.subscribe(Subscription(group="g", name="aud", tenant=ACME,
                                    auto_commit=False))
    feed(log, b"acme.a", 4)
    feed(log, b"evil.a", 4, base=50)
    proxy.pump()
    got = s.fetch(2)
    assert got
    s.commit()
    s.detach()                            # park under (g, aud)
    # another tenant cannot steal the cursor…
    with pytest.raises(TenantError):
        sess.subscribe(Subscription(group="g", name="aud", tenant=EVIL),
                       resume=True)
    # …and the failed attempt left the parked state intact: the real
    # tenant resumes (inheriting its scope without restating it)
    s2 = sess.resume("g", "aud", auto_commit=False)
    assert s2.resumed
    jobids, seen = drain_scoped(proxy.pump, s2)
    assert jobids == {b"acme.a"}


def test_rescoping_unscoped_cursor_rejected():
    log = Llog("mdt0")
    proxy = LcapProxy({"mdt0": log})
    sess = connect(proxy)
    s = sess.subscribe(Subscription(group="g", name="n"))
    s.detach()
    with pytest.raises(TenantError):
        sess.subscribe(Subscription(group="g", name="n", tenant=ACME),
                       resume=True)
    assert sess.resume("g", "n").resumed  # unscoped resume still fine


def test_tenant_over_the_wire():
    log = Llog("mdt0")
    proxy = LcapProxy({"mdt0": log})
    svc = LcapService(proxy).start()
    try:
        sess = connect(svc.address)
        s = sess.subscribe(Subscription(group="g", tenant=ACME,
                                        auto_commit=False))
        feed(log, b"acme.w", 4)
        feed(log, b"evil.w", 4, base=50)
        # the service's poller thread pumps; give it scheduler time
        jobids, seen = drain_scoped(
            lambda: time.sleep(0.01) or 0, s, rounds=100)
        assert jobids == {b"acme.w"} and len(seen) == 4
        # malformed principal surfaces as the typed error client-side
        with pytest.raises(TenantError):
            sess._backend._call({"op": "subscribe", "group": "g2",
                                 "tenant": {"jobids": ["x"]}})
        sess.close()
    finally:
        svc.stop()


# ----------------------------------------------------------------- quotas
def test_quota_parks_and_resumes():
    log = Llog("mdt0")
    proxy = LcapProxy({"mdt0": log})
    clock = [0.0]
    proxy._now = lambda: clock[0]
    proxy.set_tenant_quota("acme", records_per_s=10, burst_records=10)
    sess = connect(proxy)
    s = sess.subscribe(Subscription(group="g", tenant=ACME,
                                    auto_commit=False))
    # round 1 spends the whole 10-token burst (quota gates *rounds*:
    # a batch already in flight is charged, not truncated)
    feed(log, b"acme.q", 10)
    proxy.pump()
    _, seen = drain_scoped(None, s, rounds=2)
    assert len(seen) == 10
    acct = proxy.tenants["acme"]
    assert acct.record_bucket.exhausted
    # round 2 parks on the exhausted bucket: nothing reaches the outbox
    feed(log, b"acme.q", 20, base=100)
    proxy.pump()
    proxy.pump()
    assert s.fetch(4096) == []
    assert acct.quota_blocked_pumps > 0
    assert acct.delivered_records == 10
    # refill un-parks the group and the backlog drains
    clock[0] += 10.0
    proxy.pump()
    _, seen2 = drain_scoped(proxy.pump, s, rounds=5)
    assert len(seen2) == 20
    assert not (seen & seen2)             # exactly once across the park
    assert acct.delivered_records == 30


def test_token_bucket_refill_and_debt():
    b = TokenBucket(rate=5, burst=10)
    b.refill(0.0)
    b.charge(25)                          # batch overshoot -> debt
    assert b.exhausted and b.level == -15
    b.refill(2.0)                         # +10 tokens
    assert b.exhausted
    b.refill(4.0)
    assert not b.exhausted                # back above zero
    b.refill(100.0)
    assert b.level == 10                  # capped at burst


# ---------------------------------------------------------- origin tagging
def test_origin_trailer_wire_roundtrip():
    batch = R.RecordBatch.from_records(
        [rec(oid=i, jobid=b"acme.x", index=i + 1) for i in range(4)])
    batch.origin = "fs0"
    out = R.RecordBatch.from_wire(batch.to_wire2())
    assert out.origin == "fs0"
    assert out.indices() == [1, 2, 3, 4]
    # v1 frames have nowhere to carry the tag
    assert R.RecordBatch.from_wire(batch.to_wire()).origin is None
    # a tagless v2 frame decodes with no origin (old sender)
    plain = R.RecordBatch.from_records([rec(index=1)])
    assert R.RecordBatch.from_wire(plain.to_wire2()).origin is None
    # derived batches keep the stamp
    assert batch[1:3].origin == "fs0"
    assert batch.select([0, 2]).origin == "fs0"
    joined = R.RecordBatch.concat([batch[:2], batch[2:]])
    assert joined.origin == "fs0"
    other = R.RecordBatch.from_records([rec(index=9)])
    other.origin = "fs1"
    assert R.RecordBatch.concat([batch, other]).origin is None


def test_global_cursor():
    c = GlobalCursor()
    c.advance("fs0", "p0", 5)
    c.advance("fs0", "p0", 3)             # regressions ignored
    c.advance("fs1", "p0", 2)             # same pid, other origin
    assert c.position("fs0", "p0") == 5
    assert c.position("fs1", "p0") == 2
    assert c.position("fs9", "zz") == 0
    snap = c.snapshot()
    snap["fs0"]["p0"] = 99                # deep copy: no aliasing
    assert c.position("fs0", "p0") == 5
    d = GlobalCursor(c.snapshot())
    assert d == c
    d.advance("fs0", "p0", 7)
    c.merge(d)
    assert c.position("fs0", "p0") == 7


# -------------------------------------------------------------- federation
def mk_fed(n_each=0):
    logs_a = {"fs0-p0": Llog("fs0-p0"), "fs0-p1": Llog("fs0-p1")}
    logs_b = {"fs1-p0": Llog("fs1-p0"), "fs1-p1": Llog("fs1-p1")}
    ca = LcapCluster(logs_a, n_shards=2)
    cb = LcapCluster(logs_b, n_shards=2)
    fed = Federation({"fs0": ca, "fs1": cb})
    return fed, ca, cb, logs_a, logs_b


def test_federation_fan_in_exactly_once():
    fed, ca, cb, logs_a, logs_b = mk_fed()
    stream = fed.subscribe(Subscription(group="g", auto_commit=False))
    for log in logs_a.values():
        feed(log, b"acme.f", 10)
    for log in logs_b.values():
        feed(log, b"acme.f", 7, base=500)
    seen = []
    for _ in range(100):
        fed.pump()
        got = stream.fetch(4096)
        for origin, pid, batch in got:
            assert batch.origin == origin
            assert pid.startswith(origin)   # producers never cross planes
            seen.extend((origin, pid, i) for i in batch.indices())
        stream.commit()
        if not got and len(seen) >= 34:
            break
    assert len(seen) == len(set(seen)) == 34
    # the cursor reached every producer's high watermark, per origin
    snap = stream.cursor.snapshot()
    assert snap["fs0"] == {"fs0-p0": 10, "fs0-p1": 10}
    assert snap["fs1"] == {"fs1-p0": 7, "fs1-p1": 7}
    stream.close()
    fed.close()
    ca.close(), cb.close()


def test_federation_per_origin_replay(tmp_path):
    logs_a = {"a": Llog("a", path=str(tmp_path / "a"), segment_records=8,
                        history=True)}
    logs_b = {"b": Llog("b", path=str(tmp_path / "b"), segment_records=8,
                        history=True)}
    ca, cb = LcapCluster(logs_a, n_shards=2), LcapCluster(logs_b, n_shards=2)
    fed = Federation({"fs0": ca, "fs1": cb})
    burn = fed.subscribe(Subscription(group="burn", auto_commit=False))
    feed(logs_a["a"], b"acme.h", 12)
    feed(logs_b["b"], b"acme.h", 12)
    drain_scoped(fed.pump, burn)          # ack everything -> journals trim
    assert logs_a["a"].first_index > 1 and logs_b["b"].first_index > 1
    # bootstrap fs0 from history, attach fs1 live-only
    stream = fed.subscribe(Subscription(group="boot", auto_commit=False),
                           replay={"fs0": True})
    feed(logs_b["b"], b"acme.h", 3, base=600)     # new live records on fs1
    per_origin = {}
    for _ in range(200):
        fed.pump()
        got = 0
        for origin, _pid, batch in stream.fetch(4096):
            per_origin.setdefault(origin, set()).update(batch.indices())
            got += len(batch)
        stream.commit()
        if not got and not stream.replaying \
                and len(per_origin.get("fs1", ())) >= 3:
            break
    assert len(per_origin["fs0"]) == 12   # full history of fs0
    assert stream.replayed == 12
    # fs1 attached live: only the post-subscribe records
    assert len(per_origin["fs1"]) == 3
    stream.close(), fed.close(), ca.close(), cb.close()


def test_federation_durable_resume():
    fed, ca, cb, logs_a, logs_b = mk_fed()
    with pytest.raises(UnknownConsumerError):
        fed.resume("g", "nobody")
    s = fed.subscribe(Subscription(group="g", name="aud", tenant=ACME,
                                   auto_commit=False))
    feed(logs_a["fs0-p0"], b"acme.r", 6)
    fed.pump()
    s.fetch(4096)
    s.commit()
    s.detach()
    # the other tenant cannot steal the parked federated cursor…
    with pytest.raises(TenantError):
        fed.subscribe(Subscription(group="g", name="aud", tenant=EVIL),
                      resume=True)
    # …and the failed steal left it resumable by its owner
    s2 = fed.resume("g", "aud", auto_commit=False)
    assert s2.resumed
    s2.close(), fed.close(), ca.close(), cb.close()


# --------------------------------------- the adversarial isolation invariant
def test_isolation_invariant_under_topology_churn(tmp_path):
    """The tentpole invariant: across history bootstrap, live slot
    migration, forced shard failover and federation fan-in, a scoped
    consumer sees (a) only in-scope jobids and (b) every in-scope
    record at least once."""
    logs_a = {"a0": Llog("a0", path=str(tmp_path / "a0"),
                         segment_records=8, history=True)}
    logs_b = {"b0": Llog("b0", path=str(tmp_path / "b0"),
                         segment_records=8, history=True)}
    ca = LcapCluster(logs_a, n_shards=2)
    cb = LcapCluster(logs_b, n_shards=3)
    fed = Federation({"fs0": ca, "fs1": cb})
    burn = fed.subscribe(Subscription(group="burn", auto_commit=False))

    # history era: mixed-tenant churn, fully acked and trimmed
    for i in range(20):
        feed(logs_a["a0"], b"acme.hist" if i % 2 else b"evil.hist", 1,
             base=i)
        feed(logs_b["b0"], b"acme.hist" if i % 3 else b"evil.hist", 1,
             base=i)
    drain_scoped(fed.pump, burn)
    assert logs_a["a0"].first_index > 1

    stream = fed.subscribe(Subscription(group="sec", tenant=ACME,
                                        auto_commit=False), replay=True)
    jobids, seen = set(), set()

    def poll(rounds=3):
        for _ in range(rounds):
            fed.pump()
            for origin, pid, batch in stream.fetch(4096):
                for i in range(len(batch)):
                    r = batch.record(i)
                    jobids.add(bytes(r.jobid or b""))
                    seen.add((origin, pid, r.index))
            stream.commit()
            # keep the unscoped group draining too, so its acks never
            # hold journal trim or migration handoff hostage
            burn.fetch(4096)
            burn.commit()

    poll(10)
    # topology churn with live traffic interleaved
    feed(logs_a["a0"], b"acme.live", 10, base=1000)
    feed(logs_b["b0"], b"evil.live", 10, base=1000)
    poll(2)
    ca.migrate_slots(range(0, ca.n_slots // 2), 1)     # live migration
    feed(logs_a["a0"], b"acme.live", 10, base=2000)
    poll(4)
    cb.kill_shard(0)                                   # forced failover
    feed(logs_b["b0"], b"acme.live", 10, base=2000)
    poll(30)

    assert jobids and jobids <= {b"acme.hist", b"acme.live"}
    # completeness: every in-scope live record of the post-bootstrap
    # era arrived (the burn group already consumed the history era;
    # replay re-delivered acme's share of it)
    a_live = {x for x in seen if x[0] == "fs0" and x[2] > 20}
    b_live = {x for x in seen if x[0] == "fs1" and x[2] > 20}
    assert len(a_live) == 20
    assert len(b_live) == 10
    assert stream.replayed > 0
    stream.close(), fed.close(), ca.close(), cb.close()


# ----------------------------------------------------------- observability
def test_tenant_metrics_and_federation_merge():
    logs = {"m": Llog("m")}
    proxy = LcapProxy({"m": logs["m"]})
    reg = MetricsRegistry()
    proxy.attach_registry(reg)
    proxy.set_tenant_quota("acme", records_per_s=1000)
    sess = connect(proxy)
    sess.subscribe(Subscription(group="g", tenant=ACME, auto_commit=False))
    feed(logs["m"], b"acme.m", 5)
    feed(logs["m"], b"evil.m", 2, base=50)
    proxy.pump()
    snap = reg.snapshot()
    by_name = {}
    for name, entry in snap.items():
        by_name[name] = entry
    assert "lcap_tenant_delivered_records_total" in by_name
    samples = by_name["lcap_tenant_delivered_records_total"]["samples"]
    assert any(lbl.get("tenant") == "acme" and v == 5
               for lbl, v in samples)
    assert "lcap_tenant_quota_level_records" in by_name
    filt = by_name["lcap_proxy_tenant_filtered_total"]["samples"]
    assert any(v == 2 for _lbl, v in filt)

    # federation merge: gauges gain the origin label
    fed, ca, cb, logs_a, logs_b = mk_fed()
    for i, shard in enumerate(ca.shards):
        shard.proxy.attach_registry(MetricsRegistry(), {"shard": str(i)})
    for i, shard in enumerate(cb.shards):
        shard.proxy.attach_registry(MetricsRegistry(), {"shard": str(i)})
    fed.set_tenant_quota("acme", records_per_s=1e9)
    s = fed.subscribe(Subscription(group="g", tenant=ACME,
                                   auto_commit=False))
    feed(logs_a["fs0-p0"], b"acme.z", 4)
    fed.pump()
    s.fetch(4096)
    s.commit()
    merged = fed.metrics()
    gauges = merged.get("lcap_buffered_records")
    assert gauges is not None
    assert {lbl.get("origin") for lbl, _v in gauges["samples"]} \
        >= {"fs0", "fs1"}
    deliv = merged.get("lcap_tenant_delivered_records_total")
    assert deliv and sum(v for _lbl, v in deliv["samples"]) == 4
    s.close(), fed.close(), ca.close(), cb.close()


def test_federation_stats_and_audit_report():
    fed, ca, cb, logs_a, logs_b = mk_fed()
    audit = AuditTrail(fed, group="audit", tenant=ACME)
    feed(logs_a["fs0-p0"], b"acme.1000", 6)
    feed(logs_b["fs1-p0"], b"acme.1000", 2)
    feed(logs_b["fs1-p1"], b"evil.666", 5, base=300)
    for _ in range(30):
        fed.pump()
        audit.poll()
    rep = audit.report()
    assert rep["tenant"] == "acme"
    assert set(rep["jobs"]) == {"acme.1000"}
    assert rep["jobs"]["acme.1000"]["by_origin"] == {"fs0": 6, "fs1": 2}
    assert rep["users"] == {"1000": 8}
    assert rep["unattributed"] == 0
    st = fed.stats()
    assert set(st["per_origin"]) == {"fs0", "fs1"}
    assert st["tenant_filtered"] == 5
    assert set(fed.lag()) == {"fs0", "fs1"}
    audit.close(), fed.close(), ca.close(), cb.close()


# ------------------------------------------------------- satellite: probe
def test_jax_probe_memoized(monkeypatch):
    calls = []

    def fake_resolve():
        calls.append(1)
        return None

    monkeypatch.setattr(cluster_mod, "_resolve_jax_fid_slots", fake_resolve)
    cluster_mod._reset_jax_probe()
    assert cluster_mod._jax_fid_slots() is None
    assert cluster_mod._jax_fid_slots() is None
    assert len(calls) == 1                # memoized after first probe
    cluster_mod._reset_jax_probe()
    cluster_mod._jax_fid_slots()
    assert len(calls) == 2                # reset hook re-arms the probe
    cluster_mod._reset_jax_probe()        # leave pristine for other tests
