"""LCAP proxy behaviour (paper §III, §IV-B): aggregation from multiple
producers, consumer groups with load balancing, broadcast across groups,
collective upstream acknowledgement, at-least-once redelivery, ephemeral
readers, backpressure."""

import pytest

from repro.core import records as R
from repro.core.llog import Llog
from repro.core.proxy import EPHEMERAL, Group, LcapProxy
from repro.core.reader import LocalReader


def rec(t=R.CL_CREATE, oid=1, name=b"f", **kw):
    return R.ChangelogRecord(type=t, tfid=R.Fid(1, oid, 0),
                             pfid=R.Fid(1, 0, 0), name=name, **kw)


def mk_proxy(n_producers=2, **kw):
    logs = {f"mdt{i}": Llog(f"mdt{i}") for i in range(n_producers)}
    proxy = LcapProxy(logs, **kw)
    return proxy, logs


def feed(logs, n_each=10):
    for pid, log in logs.items():
        for i in range(n_each):
            log.log(rec(oid=i, name=f"{pid}-{i}".encode()))


def drain(reader, limit=10_000):
    got = []
    while True:
        batch = reader.fetch(256)
        if not batch:
            return got
        got.extend(batch)
        assert len(got) < limit


def test_aggregates_all_producers():
    proxy, logs = mk_proxy(3)
    feed(logs, 5)
    r = LocalReader(proxy, "g")
    proxy.pump()
    got = drain(r)
    assert len(got) == 15
    assert {pid for pid, _ in got} == {"mdt0", "mdt1", "mdt2"}


def test_group_load_balancing_spreads_records():
    """The stream is spread among instances of a single group (fig. 2)."""
    proxy, logs = mk_proxy(1)
    readers = [LocalReader(proxy, "g") for _ in range(4)]
    feed(logs, 100)
    proxy.pump()
    counts = [len(drain(r)) for r in readers]
    assert sum(counts) == 100
    assert all(c > 0 for c in counts)
    assert max(counts) - min(counts) <= 2   # least-loaded keeps it even


def test_each_group_sees_every_record():
    """If multiple groups co-exist, every record is delivered to each."""
    proxy, logs = mk_proxy(1)
    g1 = [LocalReader(proxy, "g1") for _ in range(2)]
    g2 = [LocalReader(proxy, "g2")]
    feed(logs, 20)
    proxy.pump()
    got1 = sum((drain(r) for r in g1), [])
    got2 = drain(g2[0])
    assert len(got1) == 20 and len(got2) == 20
    assert {r.index for _, r in got1} == {r.index for _, r in got2}


def test_upstream_ack_requires_every_group():
    """Records are acknowledged upstream only once acknowledged by every
    group (at-least-once)."""
    proxy, logs = mk_proxy(1)
    log = logs["mdt0"]
    r1 = LocalReader(proxy, "g1")
    r2 = LocalReader(proxy, "g2")
    feed(logs, 4)
    proxy.pump()
    for pid, r in drain(r1):
        r1.ack(pid, r.index)
    assert log.first_index == 1          # g2 has not acked
    for pid, r in drain(r2):
        r2.ack(pid, r.index)
    assert log.first_index == 5          # all groups acked -> trimmed


def test_out_of_order_batched_acks():
    proxy, logs = mk_proxy(1)
    log = logs["mdt0"]
    r = LocalReader(proxy, "g")
    feed(logs, 5)
    proxy.pump()
    got = drain(r)
    order = [2, 4, 1, 5, 3]              # delayed and batched (paper §II)
    for idx in order[:2]:
        r.ack("mdt0", idx)
    assert log.first_index == 1          # hole at 1
    r.ack("mdt0", 1)
    assert log.first_index == 3          # 1,2 contiguous
    r.ack("mdt0", 5)
    r.ack("mdt0", 3)
    assert log.first_index == 6


def test_at_least_once_redelivery_on_failure():
    """A dead consumer's unacked records are redelivered to the group."""
    proxy, logs = mk_proxy(1)
    a = LocalReader(proxy, "g")
    b = LocalReader(proxy, "g")
    feed(logs, 20)
    proxy.pump()
    got_a = drain(a)
    assert got_a                          # a holds in-flight records
    a.close(failed=True)                  # crash before acking
    got_b = drain(b)
    proxy.pump()
    got_b += drain(b)
    seen = {r.index for _, r in got_b}
    assert seen == set(range(1, 21))      # b eventually sees everything
    assert proxy.stats["redelivered"] >= len(got_a)
    for pid, r in got_b:
        b.ack(pid, r.index)
    assert logs["mdt0"].first_index == 21


def test_group_with_no_members_parks_records():
    proxy, logs = mk_proxy(1)
    proxy.groups.setdefault("g", Group("g"))
    feed(logs, 3)
    proxy.pump()
    # no member yet: records parked, nothing acked upstream
    assert logs["mdt0"].first_index == 1
    r = LocalReader(proxy, "g")
    got = drain(r)
    assert len(got) == 3                  # drained on subscribe


def test_ephemeral_reader_radio_semantics():
    """Ephemeral readers miss history, need no acks, and never block the
    upstream trim (paper §IV-B)."""
    proxy, logs = mk_proxy(1)
    log = logs["mdt0"]
    persistent = LocalReader(proxy, "g")
    feed(logs, 5)                         # history
    proxy.pump()
    eph = LocalReader(proxy, None, mode=EPHEMERAL)
    for i in range(5, 8):
        log.log(rec(oid=i))
    proxy.pump()
    got = drain(eph)
    assert [r.index for _, r in got] == [6, 7, 8]   # no history
    eph.ack("mdt0", 6)                    # a no-op, not an error
    for pid, r in drain(persistent):
        persistent.ack(pid, r.index)
    assert log.first_index == 9           # eph never blocks trimming
    eph.close()


def test_ephemeral_stops_receiving_after_close():
    proxy, logs = mk_proxy(1)
    LocalReader(proxy, "g")
    eph = LocalReader(proxy, None, mode=EPHEMERAL)
    feed(logs, 2)
    proxy.pump()
    assert len(drain(eph)) == 2
    eph.close()
    feed(logs, 2)
    proxy.pump()
    with pytest.raises(KeyError):
        proxy.fetch(eph.cid)


def test_remote_remap_strips_unrequested_fields():
    """The proxy strips fields the consumer did not express via flags."""
    proxy, logs = mk_proxy(1)
    narrow = LocalReader(proxy, "old", flags=0)
    wide = LocalReader(proxy, "new", flags=R.CLF_SUPPORTED)
    logs["mdt0"].log(rec(jobid=b"JOB", metrics=(3.5,)))
    proxy.pump()
    (_, r_old), = drain(narrow)
    (_, r_new), = drain(wide)
    assert r_old.jobid is None and r_old.metrics is None
    assert r_new.jobid == b"JOB" and r_new.metrics == (3.5,)


def test_local_remap_zero_fills_requested_fields():
    """A consumer requesting fields the producer never wrote sees them
    zero-filled (local remap)."""
    proxy, logs = mk_proxy(1)
    r = LocalReader(proxy, "g", flags=R.CLF_JOBID | R.CLF_SHARD)
    logs["mdt0"].log(rec())               # no extensions at all
    proxy.pump()
    (_, out), = drain(r)
    assert out.jobid == b"" and out.shard == (0, 0, 0, 0)


def test_backpressure_stops_dispatch_not_ingest_overflow():
    proxy, logs = mk_proxy(1, outbox_cap=8)
    r = LocalReader(proxy, "g")
    feed(logs, 64)
    proxy.pump()
    # dispatch halted at the cap; buffer holds the rest
    assert len(proxy.consumers[r.cid].outbox) <= 8
    drained = drain(r)
    proxy.pump()
    drained += drain(r)
    while True:
        proxy.pump()
        more = drain(r)
        if not more:
            break
        drained += more
    assert len(drained) == 64


def test_greedy_batched_ingest_counts():
    proxy, logs = mk_proxy(2, batch_size=16)
    feed(logs, 50)
    LocalReader(proxy, "g")
    proxy.pump()
    assert proxy.stats["ingested"] == 100
    assert proxy.cursors["mdt0"] == 51


def test_late_producer_registration():
    proxy, logs = mk_proxy(1)
    r = LocalReader(proxy, "g")
    extra = Llog("mdt9")
    proxy.add_producer("mdt9", extra)
    extra.log(rec(oid=1))
    feed(logs, 1)
    proxy.pump()
    got = drain(r)
    assert {pid for pid, _ in got} == {"mdt0", "mdt9"}


def test_fetch_and_ack_unknown_consumer_error_is_clear():
    """Satellite regression: unknown/unsubscribed consumer ids raise a
    KeyError that names the consumer, not an opaque dict lookup."""
    proxy, logs = mk_proxy(1)
    with pytest.raises(KeyError, match="unknown or unsubscribed.*nope"):
        proxy.fetch("nope")
    with pytest.raises(KeyError, match="unknown or unsubscribed.*nope"):
        proxy.ack("nope", "mdt0", 1)
    with pytest.raises(KeyError, match="unknown or unsubscribed.*nope"):
        proxy.fetch_batches("nope")
    with pytest.raises(KeyError, match="unknown or unsubscribed.*nope"):
        proxy.ack_batch("nope", "mdt0", [1])
    r = LocalReader(proxy, "g")
    r.close()
    with pytest.raises(KeyError, match="unknown or unsubscribed"):
        proxy.fetch(r.cid)


def test_batch_fetch_and_batch_ack_roundtrip():
    """fetch_batches returns per-producer RecordBatches; ack_batch
    acknowledges a whole batch and propagates the collective watermark."""
    proxy, logs = mk_proxy(2)
    r = LocalReader(proxy, "g")
    feed(logs, 10)
    proxy.pump()
    total = 0
    while True:
        batches = r.fetch_batches(64)
        if not batches:
            break
        for pid, batch in batches:
            assert isinstance(batch, R.RecordBatch)
            total += len(batch)
            r.ack_batch(pid, batch.indices())
    assert total == 20
    assert all(log.first_index == 11 for log in logs.values())


def test_proxy_restart_resumes_at_own_watermark_not_trim_point():
    """Bugfix regression: a restarted proxy must resume at the lcap
    reader's own acked watermark.  A slower co-registered reader holds
    the journal's trim point (first_index) back; resuming there
    re-ingests records the proxy already delivered and acked, and every
    group sees them twice."""
    log = Llog("mdt0")
    slow = log.register_reader("slow-audit")      # lags; holds the trim
    proxy1 = LcapProxy({"mdt0": log})
    r1 = LocalReader(proxy1, "g")
    for i in range(10):
        log.log(rec(oid=i))
    proxy1.pump()
    for pid, r in drain(r1):
        r1.ack(pid, r.index)
    assert log.first_index == 1                   # slow reader: no trim
    assert log.reader_position("lcap-mdt0") == 10

    # the proxy process dies and restarts against the same journal
    proxy2 = LcapProxy({"mdt0": log})
    assert proxy2.cursors["mdt0"] == 11           # resumed, not rewound
    r2 = LocalReader(proxy2, "g")
    proxy2.pump()
    assert drain(r2) == []                        # nothing re-ingested
    assert proxy2.stats["ingested"] == 0
    log.log(rec(oid=99))                          # new records still flow
    proxy2.pump()
    (_, nr), = drain(r2)
    assert nr.index == 11
    log.ack(slow, 11)                             # slow reader catches up
    r2.ack("mdt0", 11)
    assert log.first_index == 12


def test_restart_redelivers_backlog_the_first_incarnation_never_acked():
    """At-least-once across the *first* restart: a proxy that attached
    to a journal with existing records, delivered them, and died before
    any consumer ack must re-ingest them — its reader owes acks for the
    whole live backlog from the moment it attaches (Llog.attach_reader),
    not merely for records logged after registration."""
    log = Llog("mdt0")
    log.register_reader("holder")                 # arms logging
    for i in range(10):
        log.log(rec(oid=i))
    proxy1 = LcapProxy({"mdt0": log})             # fresh attach, backlog
    r1 = LocalReader(proxy1, "g")
    proxy1.pump()
    assert len(drain(r1)) == 10                   # delivered, NOT acked

    proxy2 = LcapProxy({"mdt0": log})             # proxy crashed
    assert proxy2.cursors["mdt0"] == 1            # owes the full backlog
    r2 = LocalReader(proxy2, "g")
    proxy2.pump()
    got = drain(r2)
    assert [r.index for _, r in got] == list(range(1, 11))
    for pid, r in got:
        r2.ack(pid, r.index)
    assert log.reader_position("lcap-mdt0") == 10


def test_ephemeral_gets_no_history_from_late_added_producer():
    """Bugfix regression (§IV-B): a producer added after an ephemeral
    consumer attached must not leak its journaled history — the
    connection point is stamped per producer at add_producer time."""
    proxy, logs = mk_proxy(1)
    LocalReader(proxy, "g")                       # arms dispatch
    eph = LocalReader(proxy, None, mode=EPHEMERAL)
    late = Llog("late")
    late.register_reader("hold")                  # arms logging pre-attach
    for i in range(5):
        late.log(rec(oid=i))                      # history before joining
    proxy.add_producer("late", late)
    proxy.pump()
    got = drain(eph)
    assert [pid for pid, _ in got] == []          # no leaked history
    late.log(rec(oid=9))
    feed(logs, 1)
    proxy.pump()
    got = drain(eph)
    assert {(pid, r.index) for pid, r in got} == {("late", 6), ("mdt0", 1)}


def test_backpressure_is_per_group_idle_group_keeps_draining():
    """Bugfix regression: one saturated persistent consumer must stall
    only its own group; the other groups keep draining."""
    proxy, logs = mk_proxy(1, outbox_cap=8)
    stuck = LocalReader(proxy, "stuck")           # never fetches
    live = LocalReader(proxy, "live")
    feed(logs, 100)
    for _ in range(30):
        proxy.pump()
    # the live group drained everything despite the saturated group
    got_live = drain(live)
    while True:
        proxy.pump()
        more = drain(live)
        if not more:
            break
        got_live += more
    assert len(got_live) == 100
    assert len(proxy.consumers[stuck.cid].outbox) >= 8   # stuck at cap
    # nothing was acked upstream yet: the stuck group still owes acks
    for pid, r in got_live:
        live.ack(pid, r.index)
    assert logs["mdt0"].first_index == 1
    # the stuck group recovers: parked records are redelivered in order
    got_stuck = []
    while True:
        more = drain(stuck)
        if not more:
            proxy.pump()
            more = drain(stuck)
            if not more:
                break
        got_stuck += more
        for pid, r in more:
            stuck.ack(pid, r.index)
    assert [r.index for _, r in got_stuck] == list(range(1, 101))
    assert logs["mdt0"].first_index == 101        # full collective trim


def test_ingest_rotates_producers_under_full_buffer():
    """Bugfix regression: with a buffer smaller than one producer's
    backlog, dict-order draining starved every later producer.  The
    rotation must interleave producers across pumps."""
    proxy, logs = mk_proxy(2, batch_size=8, max_buffer=8)
    r = LocalReader(proxy, "g")
    feed(logs, 64)
    seen_producers = set()
    for _ in range(4):                            # a few constrained pumps
        proxy.pump()
        for pid, rec_ in drain(r):
            seen_producers.add(pid)
            r.ack(pid, rec_.index)
    assert seen_producers == {"mdt0", "mdt1"}     # both flow early
    # and nothing is lost overall
    got = []
    for _ in range(100):
        proxy.pump()
        more = drain(r)
        for pid, rec_ in more:
            r.ack(pid, rec_.index)
        got += more
        if all(log.first_index == log.last_index + 1
               for log in logs.values()):
            break
    assert all(log.first_index == 65 for log in logs.values())


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False


if not HAVE_HYPOTHESIS:                   # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_exactly_once_per_group_and_full_trim():
        ...

else:
    @settings(max_examples=30, deadline=None)
    @given(
        n_producers=st.integers(1, 3),
        n_groups=st.integers(1, 3),
        members_per_group=st.integers(1, 3),
        n_records=st.integers(0, 40),
        fail_one=st.booleans(),
    )
    def test_property_exactly_once_per_group_and_full_trim(
            n_producers, n_groups, members_per_group, n_records, fail_one):
        """System invariants under random topologies: (1) every group sees
        every record exactly once (at-least-once collapses to exactly-once
        when consumers ack everything they fetch); (2) after all acks every
        journal is fully trimmed; (3) a mid-stream consumer failure never
        loses records."""
        proxy, logs = mk_proxy(n_producers)
        groups = {f"g{gi}": [LocalReader(proxy, f"g{gi}")
                             for _ in range(members_per_group)]
                  for gi in range(n_groups)}
        feed(logs, n_records)
        proxy.pump()
        if fail_one and n_records and members_per_group > 1:
            groups["g0"][0].close(failed=True)
            groups["g0"] = groups["g0"][1:]
        seen = {g: [] for g in groups}
        for _ in range(200):
            moved = 0
            for g, readers in groups.items():
                for r in readers:
                    for pid, rec in r.fetch(64):
                        seen[g].append((pid, rec.index))
                        r.ack(pid, rec.index)
                        moved += 1
            proxy.pump()
            proxy.flush_upstream()
            if not moved and all(len(s) >= n_producers * n_records
                                 for s in seen.values()):
                break
        expect = {(f"mdt{p}", i) for p in range(n_producers)
                  for i in range(1, n_records + 1)}
        for g, s in seen.items():
            assert sorted(s) == sorted(expect), g  # exactly once per group
        for log in logs.values():
            assert log.first_index == log.last_index + 1   # fully trimmed
