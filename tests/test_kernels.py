"""Flash attention Pallas kernel vs pure-jnp oracle: shape/dtype sweep
in interpret mode (assignment requirement), plus feature coverage
(causal, sliding window, softcap, GQA, ragged lengths) and integration
with the model's attention_core."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention
from repro.kernels.ref import attention_reference

jax.config.update("jax_enable_x64", False)


def rand_qkv(key, B, Sq, Sk, H, KV, D, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Sq, H, D), dtype)
    k = jax.random.normal(kk, (B, Sk, KV, D), dtype)
    v = jax.random.normal(kv, (B, Sk, KV, D), dtype)
    return q, k, v


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


SHAPES = [
    # B, Sq, Sk, H, KV, D
    (1, 128, 128, 4, 4, 64),      # MHA, block-multiple
    (2, 256, 256, 8, 2, 64),      # GQA 4:1
    (1, 100, 100, 4, 2, 80),      # ragged seq + non-128 head_dim
    (2, 64, 192, 4, 1, 32),       # cross lengths, MQA
    (1, 512, 512, 2, 2, 128),     # exact MXU dims
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES)
def test_flash_matches_reference_causal(shape, dtype):
    B, Sq, Sk, H, KV, D = shape
    q, k, v = rand_qkv(jax.random.PRNGKey(0), B, Sq, Sk, H, KV, D, dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [8, 64])
def test_flash_sliding_window(window):
    q, k, v = rand_qkv(jax.random.PRNGKey(1), 2, 128, 128, 4, 2, 64,
                       jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32, interpret=True)
    ref = attention_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_softcap():
    q, k, v = rand_qkv(jax.random.PRNGKey(2), 1, 128, 128, 4, 4, 64,
                       jnp.float32)
    out = flash_attention(q, k, v, causal=True, cap=20.0,
                          block_q=64, block_k=64, interpret=True)
    ref = attention_reference(q, k, v, causal=True, cap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_non_causal():
    q, k, v = rand_qkv(jax.random.PRNGKey(3), 1, 64, 128, 4, 4, 64,
                       jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=64,
                          interpret=True)
    ref = attention_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_block_size_invariance():
    q, k, v = rand_qkv(jax.random.PRNGKey(4), 1, 256, 256, 2, 2, 64,
                       jnp.float32)
    a = flash_attention(q, k, v, block_q=32, block_k=128, interpret=True)
    b = flash_attention(q, k, v, block_q=256, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_flash_integrates_with_attention_core():
    """models.layers.attention_core(impl='pallas') == impl='naive'."""
    from repro.models.layers import attention_core
    B, S, H, KV, D = 2, 96, 4, 2, 64
    q, k, v = rand_qkv(jax.random.PRNGKey(5), B, S, S, H, KV, D,
                       jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    naive = attention_core(q, k, v, pos, pos, impl="naive", causal=True)
    pall = attention_core(q, k, v, pos, pos, impl="pallas", causal=True,
                          window=0, cap=0.0)
    np.testing.assert_allclose(np.asarray(pall), np.asarray(naive),
                               rtol=2e-5, atol=2e-5)


def test_fully_masked_rows_are_zero():
    """Rows with no visible kv (window smaller than gap) produce zeros,
    not NaNs."""
    q, k, v = rand_qkv(jax.random.PRNGKey(6), 1, 32, 32, 2, 2, 32,
                       jnp.float32)
    # window=1: each position sees only itself -> always >=1 visible; use
    # causal=False with an empty kv range via seq padding instead:
    out = flash_attention(q, k, v, causal=True, window=1,
                          block_q=16, block_k=16, interpret=True)
    assert bool(jnp.isfinite(out).all())
