"""Validate the differential-probe cost model: the 4-point linear solve
(probe depths PROBE_BODIES, both in the multi-layer regime) must
reproduce the cost_analysis of a FULLY UNROLLED compile of the
production-depth config (all numbers from compiled artifacts)."""

import os
import subprocess
import sys
import textwrap


def test_probe_extrapolation_matches_unrolled_compile():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import dataclasses, json
        import jax
        from repro import configs as C
        from repro.models import layers as ML, ssd as MS, transformer as T
        from repro.models.config import ShapeConfig
        from repro.runtime import specs as SP
        from repro.runtime.sharding import use_rules
        from repro.launch.dryrun import (PROBE_BODIES, _compile_and_measure,
                                         _reduced, predict_probe_model,
                                         solve_probe_model)

        cfg = C.get_smoke("granite-8b").replace(n_layers=5)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
        rules = SP.cell_rules(cfg, shape, mesh)
        dp = 2

        ML.UNROLL_BLOCKS = MS.UNROLL_CHUNKS = T.UNROLL_LAYERS = True
        pts = {}
        for k in PROBE_BODIES:
            for bl in (1, 2):
                ps = dataclasses.replace(shape, global_batch=dp * bl)
                with use_rules(rules):
                    pts[(k, bl, 1)] = _compile_and_measure(
                        _reduced(cfg, k), ps, rules, mesh, 1, "blockwise")
        # ground truth: production depth (5 bodies), local batch 4,
        # fully unrolled -> cost_analysis is exact
        truth_shape = dataclasses.replace(shape, global_batch=dp * 4)
        with use_rules(rules):
            truth = _compile_and_measure(cfg, truth_shape, rules, mesh, 1,
                                         "blockwise")
        T.UNROLL_LAYERS = ML.UNROLL_BLOCKS = MS.UNROLL_CHUNKS = False

        out = {}
        for m in ("flops", "bytes", "coll"):
            pred = predict_probe_model(solve_probe_model(pts, m), 5, 4)
            out[m] = (pred, truth[m])
        print(json.dumps(out))
        for m, (pred, tru) in out.items():
            if tru == 0:
                assert abs(pred) < 1e6, (m, pred)
            else:
                rel = abs(pred - tru) / abs(tru)
                # at smoke scale (d_model=64) constant-size ops are
                # proportionally large; production cells are dominated by
                # the linear terms the model fits.  bytes-accessed gets a
                # wider band (CPU fusion choices vary with shapes and the
                # metric is only reported as an upper bound).
                tol = {"bytes": 0.20, "coll": 0.15}.get(m, 0.10)
                assert rel < tol, (m, pred, tru, rel)
        print("VALIDATED")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "VALIDATED" in r.stdout, r.stdout
