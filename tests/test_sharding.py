"""Distribution: logical rules, sharded train step on a small host
mesh, SSD block vs sequential reference, head padding correctness."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.sharding import DEFAULT_RULES, LogicalRules


def test_rules_spec_no_duplicate_mesh_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = LogicalRules(mesh)
    spec = rules.spec(("vocab", "mlp"))     # both map to "model"
    assert list(spec) == ["model", None]    # second use dropped


def test_multipod_rules_batch_spans_pod_and_data():
    import numpy as _np
    devs = _np.array(jax.devices()[:1]).reshape(1, 1, 1)
    mesh = jax.sharding.Mesh(devs, ("pod", "data", "model"))
    rules = LogicalRules(mesh)
    assert rules.rules["batch"] == ("pod", "data")


def test_sharded_train_step_runs_on_host_mesh():
    """Lower + run one real train step on a 2x2 host-device mesh; the
    same code path the production mesh uses (pjit, rules, remat)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro import configs as C
        from repro.models.config import ShapeConfig
        from repro.runtime import specs as SP
        from repro.runtime.sharding import use_rules
        from repro.runtime.steps import TrainHParams, build_train_step
        from repro.models import transformer as T
        from repro.optim import adamw

        cfg = C.get_smoke("qwen2.5-14b")   # qkv-bias + non-div heads
        shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = SP.cell_rules(cfg, shape, mesh)
        with use_rules(rules):
            step = build_train_step(cfg, TrainHParams(n_micro=2,
                                                      attn_impl="blockwise"))
            args, in_sh, out_sh = SP.train_cell(cfg, shape, rules)
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            params = T.init_params(cfg, 0)
            opt = adamw.init(params)
            params = jax.tree.map(jax.device_put, params, in_sh[0])
            opt = jax.tree.map(jax.device_put, opt, in_sh[1])
            rng = np.random.RandomState(0)
            batch = {"tokens": jnp.asarray(
                         rng.randint(0, cfg.vocab_size, (4, 16)), jnp.int32),
                     "labels": jnp.asarray(
                         rng.randint(0, cfg.vocab_size, (4, 16)), jnp.int32)}
            with mesh:
                p2, o2, m = jitted(params, opt, batch)
        assert np.isfinite(float(m["loss"])), m
        # params stayed sharded per the rules
        leaf = jax.tree.leaves(p2)[0]
        assert leaf.sharding.mesh.shape == {"data": 2, "model": 2}
        print("LOSS", float(m["loss"]))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "LOSS" in r.stdout


def test_sharded_equals_unsharded_loss():
    """The sharded (2x2) loss equals the single-device loss — sharding
    must not change numerics."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs as C
        from repro.models.config import ShapeConfig
        from repro.runtime import specs as SP
        from repro.runtime.sharding import use_rules
        from repro.models import transformer as T

        cfg = C.get_smoke("granite-8b")
        params = T.init_params(cfg, 0)
        rng = np.random.RandomState(1)
        tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 16)),
                             jnp.int32)
        labels = jnp.roll(tokens, -1, 1)

        ref, _ = jax.jit(lambda p: T.loss_fn(p, cfg, tokens, labels))(params)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        shape = ShapeConfig("t", 16, 4, "train")
        rules = SP.cell_rules(cfg, shape, mesh)
        with use_rules(rules), mesh:
            shl, _ = jax.jit(lambda p: T.loss_fn(p, cfg, tokens, labels))(params)
        print("DIFF", abs(float(ref) - float(shl)))
        assert abs(float(ref) - float(shl)) < 5e-2
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]


def test_head_padding_preserves_gqa_semantics():
    """Padded-head attention == unpadded attention for awkward head
    counts (24, 40, 12 q-heads vs tp=16)."""
    from repro.models import layers as L
    from repro.runtime.sharding import use_rules

    class FakeRules:
        rules = {"heads": "model"}
        mesh = None

        def sharding(self, axes):
            raise AssertionError("lshard must not be called without mesh")

    key = jax.random.PRNGKey(0)
    for H, KV in ((24, 2), (40, 8), (12, 12)):
        q = jax.random.normal(key, (2, 8, H, 16))
        k = jax.random.normal(key, (2, 8, KV, 16))
        v = jax.random.normal(key, (2, 8, KV, 16))
        q2, k2, v2, H0 = L.pad_heads_for_tp(q, k, v)   # tp=1: no-op
        assert q2.shape[2] == H and H0 == H
    # simulate tp=16 via monkeypatched axis_size
    import repro.models.layers as ML
    import repro.runtime.sharding as SH
    orig = ML.axis_size
    ML.axis_size = lambda name: 16 if name == "heads" else 1
    try:
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
        for H, KV in ((24, 2), (40, 8), (12, 12)):
            q = jax.random.normal(key, (2, 8, H, 16))
            k = jax.random.normal(key, (2, 8, KV, 16))
            v = jax.random.normal(key, (2, 8, KV, 16))
            q2, k2, v2, H0 = ML.pad_heads_for_tp(q, k, v)
            assert q2.shape[2] % 16 == 0 and q2.shape[2] % k2.shape[2] == 0
            from repro.models.config import ModelConfig
            cfg = ModelConfig(arch_id="t", family="dense", n_layers=1,
                              d_model=H * 16, n_heads=H, n_kv_heads=KV,
                              d_ff=32, vocab_size=8)
            ref = ML.attention_core_naive(q, k, v, pos, pos, causal=True)
            out = ML.run_attention(q, k, v, pos, pos, cfg, causal=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)
    finally:
        ML.axis_size = orig


def test_ssd_scan_matches_sequential_reference():
    """Chunked SSD == naive per-token recurrence."""
    from repro.models.ssd import ssd_scan
    B, S, H, P, G, N = 2, 24, 4, 8, 2, 6
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))

    y, fin = ssd_scan(xh, dt, A, Bm, Cm, chunk=8)

    # sequential oracle
    hpg = H // G
    state = np.zeros((B, H, P, N))
    ys = np.zeros((B, S, H, P))
    for t in range(S):
        for h in range(H):
            g = h // hpg
            a = float(np.exp(np.asarray(dt[:, t, h] * A[h]))[0])
        for b in range(B):
            for h in range(H):
                g = h // hpg
                a = np.exp(float(dt[b, t, h]) * float(A[h]))
                state[b, h] = state[b, h] * a + float(dt[b, t, h]) * \
                    np.outer(np.asarray(xh[b, t, h]), np.asarray(Bm[b, t, g]))
                ys[b, t, h] = state[b, h] @ np.asarray(Cm[b, t, g])
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(fin), state, rtol=2e-3, atol=2e-3)
