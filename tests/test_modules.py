"""Stream-processing modules (paper §III-A) + ack interaction: records
dropped by modules must not block the upstream trim."""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import records as R
from repro.core.ack import AckTracker
from repro.core.llog import Llog
from repro.core.modules import (CancelCompensating, CoalesceHeartbeats,
                                ReorderByTarget, TypeFilter)
from repro.core.proxy import LcapProxy
from repro.core.reader import LocalReader


def rec(t=R.CL_CREATE, oid=1, ver=0, idx=0, name=b"f"):
    return R.ChangelogRecord(type=t, index=idx, tfid=R.Fid(1, oid, ver),
                             pfid=R.Fid(1, 0, 0), name=name)


def test_cancel_creat_unlink_pair():
    batch = [rec(R.CL_CREATE, oid=7, idx=1), rec(R.CL_SETATTR, oid=8, idx=2),
             rec(R.CL_UNLINK, oid=7, idx=3)]
    out = CancelCompensating()(batch)
    assert [r.index for r in out] == [2]


def test_cancel_only_matched_pairs():
    batch = [rec(R.CL_UNLINK, oid=7, idx=1),   # unmatched unlink stays
             rec(R.CL_CREATE, oid=7, idx=2)]   # later create stays
    out = CancelCompensating()(batch)
    assert [r.index for r in out] == [1, 2]


def test_ckpt_write_superseded():
    batch = [rec(R.CL_CKPT_WRITE, oid=3, ver=1, idx=1),
             rec(R.CL_CKPT_WRITE, oid=4, ver=1, idx=2),
             rec(R.CL_CKPT_WRITE, oid=3, ver=2, idx=3)]
    out = CancelCompensating()(batch)
    assert [r.index for r in out] == [2, 3]   # older write of shard 3 gone


def test_reorder_by_target_groups_objects():
    batch = [rec(oid=2, idx=1), rec(oid=1, idx=2), rec(oid=2, idx=3)]
    out = ReorderByTarget()(batch)
    assert [(r.tfid.oid, r.index) for r in out] == [(1, 2), (2, 1), (2, 3)]


def test_type_filter():
    batch = [rec(R.CL_CREATE, idx=1), rec(R.CL_HEARTBEAT, idx=2)]
    assert [r.index for r in TypeFilter({R.CL_HEARTBEAT})(batch)] == [2]


def test_coalesce_heartbeats_keeps_latest_per_host():
    batch = [rec(R.CL_HEARTBEAT, oid=1, idx=1), rec(R.CL_CREATE, oid=9, idx=2),
             rec(R.CL_HEARTBEAT, oid=1, idx=3), rec(R.CL_HEARTBEAT, oid=2, idx=4)]
    out = CoalesceHeartbeats()(batch)
    assert [r.index for r in out] == [2, 3, 4]


def test_dropped_records_do_not_block_upstream_ack():
    """Module-dropped records never reach consumers yet must still be
    trimmed upstream once surrounding records are acked."""
    log = Llog("mdt0")
    proxy = LcapProxy({"mdt0": log}, modules=[CancelCompensating()])
    r = LocalReader(proxy, "g")
    log.log(rec(R.CL_CREATE, oid=7))      # idx1 \ cancelled pair
    log.log(rec(R.CL_UNLINK, oid=7))      # idx2 /
    log.log(rec(R.CL_SETATTR, oid=8))     # idx3 delivered
    proxy.pump()
    got = r.fetch()
    assert [rr.index for _, rr in got] == [3]
    r.ack("mdt0", 3)
    assert log.first_index == 4           # 1,2 trimmed though never seen


def test_all_records_dropped_still_trims():
    log = Llog("mdt0")
    proxy = LcapProxy({"mdt0": log}, modules=[TypeFilter({R.CL_RENAME})])
    LocalReader(proxy, "g")
    for i in range(5):
        log.log(rec(R.CL_CREATE, oid=i))
    proxy.pump()
    proxy.flush_upstream()
    assert log.first_index == 6


def test_reorder_then_ack_out_of_order_watermark():
    log = Llog("mdt0")
    proxy = LcapProxy({"mdt0": log}, modules=[ReorderByTarget()])
    r = LocalReader(proxy, "g")
    log.log(rec(oid=9))                   # idx1 (sorts last)
    log.log(rec(oid=1))                   # idx2 (sorts first)
    proxy.pump()
    got = r.fetch()
    assert [rr.index for _, rr in got] == [2, 1]
    r.ack("mdt0", 2)
    assert log.first_index == 1           # idx1 still outstanding
    r.ack("mdt0", 1)
    assert log.first_index == 3


# ------------------------------------------------------- batch-level modules
def batch_of(*recs):
    return R.RecordBatch.from_records(list(recs))


def test_modules_accept_record_batches_zero_copy():
    """Modules operate on RecordBatch views: same decisions as the
    record-level path, output shares the input payload buffer."""
    b = batch_of(rec(R.CL_CREATE, oid=7, idx=1), rec(R.CL_SETATTR, oid=8, idx=2),
                 rec(R.CL_UNLINK, oid=7, idx=3))
    out = CancelCompensating()(b)
    assert isinstance(out, R.RecordBatch)
    assert out.indices() == [2]
    assert out.buf is b.buf                    # no payload copy

    b2 = batch_of(rec(oid=2, idx=1), rec(oid=1, idx=2), rec(oid=2, idx=3))
    out2 = ReorderByTarget()(b2)
    assert [(k[1], i) for k, i in zip(out2.keys(), out2.indices())] == \
        [(1, 2), (2, 1), (2, 3)]

    b3 = batch_of(rec(R.CL_CREATE, idx=1), rec(R.CL_HEARTBEAT, idx=2))
    assert TypeFilter({R.CL_HEARTBEAT})(b3).indices() == [2]

    b4 = batch_of(rec(R.CL_HEARTBEAT, oid=1, idx=1), rec(R.CL_CREATE, oid=9, idx=2),
                  rec(R.CL_HEARTBEAT, oid=1, idx=3), rec(R.CL_HEARTBEAT, oid=2, idx=4))
    assert CoalesceHeartbeats()(b4).indices() == [2, 3, 4]


def test_modules_noop_returns_same_batch_object():
    b = batch_of(rec(R.CL_CREATE, idx=1), rec(R.CL_SETATTR, oid=2, idx=2))
    assert CancelCompensating()(b) is b
    assert TypeFilter({R.CL_CREATE, R.CL_SETATTR})(b) is b
    assert CoalesceHeartbeats()(b) is b


# --------------------------------------------------------------- AckTracker
if not HAVE_HYPOTHESIS:                   # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_acktracker_watermark_invariant():
        ...

else:
    @settings(max_examples=200, deadline=None)
    @given(st.permutations(list(range(1, 12))), st.sets(st.integers(1, 11)))
    def test_acktracker_watermark_invariant(ack_order, delivered):
        """Property: watermark == largest W with every delivered idx <= W
        acked, regardless of delivery/ack order."""
        tr = AckTracker()
        for i in sorted(delivered):
            tr.deliver(i)
        acked = set()
        for idx in ack_order:
            if idx not in delivered:
                continue
            tr.ack(idx)
            acked.add(idx)
            expect = 0
            for w in sorted(delivered):
                if w in acked:
                    expect = w
                else:
                    break
            assert tr.watermark == expect


def test_acktracker_ack_through():
    tr = AckTracker()
    for i in (1, 2, 3, 5, 8):
        tr.deliver(i)
    assert tr.ack_through(5) == 5
    assert tr.in_flight == 1
    assert tr.ack(8) == 8
