"""Policy subsystem: namespace mirror (ground truth), rule engine with
the HSM-style action lifecycle stream, and the reconciler invariant —
through replay bootstrap, concurrent ingest, proxy restart, and shard
failover."""

import os
import threading
import time

from repro.core import records as R
from repro.core.cluster import LcapCluster
from repro.core.llog import Llog
from repro.core.proxy import LcapProxy
from repro.core.server import LcapService
from repro.core.session import Subscription, connect
from repro.policy import (STARTED, SUCCEED, WAITING, NamespaceMirror,
                          PolicyEngine, PolicyRule, reconcile,
                          replay_action_state)

T0 = 1_000_000_000_000_000


def rec(t, oid, at_s=0.0, name=b"f", ver=0, **kw):
    return R.ChangelogRecord(type=t, tfid=R.Fid(1, oid, ver),
                             pfid=R.Fid(1, 0, 0), name=name,
                             time=T0 + int(at_s * 1e9), **kw)


def mk_proxy(tmp_path, sub="j"):
    log = Llog("mdt0", path=str(tmp_path / sub), segment_records=16,
               history=True)
    return LcapProxy({"mdt0": log}), log


def drive(proxy, mirror, engine=None, rounds=50):
    """pump -> mirror poll -> evaluate until quiescent."""
    for _ in range(rounds):
        moved = proxy.pump()
        moved += mirror.poll(4096)
        if engine is not None:
            engine.evaluate()
            moved += proxy.pump()
        if not moved and not mirror.bootstrapping:
            return
    raise AssertionError("did not quiesce")


# ------------------------------------------------------------------ mirror
def test_mirror_matches_compactor_semantics(tmp_path):
    """Rename chains, hardlinked lifetimes, annihilated lifetimes,
    last-writer attrs: a mirror bootstrapped from *compacted* history
    reconstructs exactly the state of a from-the-start live mirror."""
    proxy, log = mk_proxy(tmp_path)
    live = NamespaceMirror(proxy, group="live", replay=None)
    # rename chain: a -> b -> c
    log.log(rec(R.CL_CREATE, 1, 0, name=b"a"))
    log.log(rec(R.CL_RENAME, 1, 1, name=b"b", sname=b"a",
                sfid=R.Fid(1, 1, 0)))
    log.log(rec(R.CL_RENAME, 1, 2, name=b"c", sname=b"b",
                sfid=R.Fid(1, 1, 0)))
    # hardlinked lifetime: one UNLINK removes one name only
    log.log(rec(R.CL_CREATE, 2, 0, name=b"h"))
    log.log(rec(R.CL_HARDLINK, 2, 1, name=b"h2"))
    log.log(rec(R.CL_UNLINK, 2, 2, name=b"h"))
    # closed lifetime: annihilated in history, UNLINKed live — same end
    log.log(rec(R.CL_CREATE, 3, 0, name=b"tmp"))
    log.log(rec(R.CL_SETATTR, 3, 1))
    log.log(rec(R.CL_UNLINK, 3, 2, name=b"tmp"))
    # last-writer-wins attrs
    log.log(rec(R.CL_CREATE, 4, 0, name=b"w"))
    log.log(rec(R.CL_SETATTR, 4, 1, shard=(0, 7, 0, 0), metrics=(1.0,)))
    log.log(rec(R.CL_SETATTR, 4, 2, shard=(0, 9, 0, 0), metrics=(2.5,)))
    drive(proxy, live)
    proxy.flush_upstream()
    assert log.first_index > 1                # journal trimmed into history

    boot = NamespaceMirror(proxy, group="boot", replay=True)
    boot.bootstrap()
    assert boot.stream.replayed > 0
    assert boot.snapshot() == live.snapshot()
    e = live.entries[(1, 1, 0)]
    assert e.name == b"c"                     # chain folded to final name
    assert live.entries[(1, 2, 0)].nlink == 1
    assert (1, 3, 0) not in live.entries
    w = live.entries[(1, 4, 0)]
    assert w.attr_shard == (0, 9, 0, 0) and w.attr_metrics == (2.5,)


def test_mirror_handoff_no_gap_no_dup_under_concurrent_ingest(tmp_path):
    """A mirror bootstrapping while the producer keeps logging ends in
    exactly the live mirror's state — the replay->live handoff loses
    nothing and double-applies nothing."""
    proxy, log = mk_proxy(tmp_path)
    svc = LcapService(proxy, poll_interval=0.001).start()
    try:
        live = NamespaceMirror(svc.address, group="live", replay=None)
        for i in range(100):
            log.log(rec(R.CL_CREATE, i, i * 0.01, name=b"f%d" % i))
        deadline = time.time() + 5
        while len(live.entries) < 100 and time.time() < deadline:
            live.poll(4096)
        assert len(live.entries) == 100

        stop = threading.Event()

        def produce():
            i = 100
            while not stop.is_set():
                log.log(rec(R.CL_CREATE, i, i * 0.01, name=b"f%d" % i))
                if i % 3 == 0:
                    log.log(rec(R.CL_UNLINK, i - 50, i * 0.01))
                i += 1
                time.sleep(0.0003)

        t = threading.Thread(target=produce)
        t.start()
        time.sleep(0.02)
        boot = NamespaceMirror(svc.address, group="boot", replay=True)
        boot.bootstrap()                       # mid-ingest bootstrap
        stop.set()
        t.join()
        deadline = time.time() + 5
        while time.time() < deadline:
            moved = live.poll(4096) + boot.poll(4096)
            if not moved and live.snapshot() == boot.snapshot():
                break
        assert boot.snapshot() == live.snapshot()
        assert boot.stream.replayed > 0
        assert boot.stats["deduped"] == 0      # no redelivery happened
    finally:
        svc.stop()


# ------------------------------------------------------------------ engine
def test_rule_matching_and_lifecycle(tmp_path):
    proxy, log = mk_proxy(tmp_path)
    mirror = NamespaceMirror(proxy)
    old = PolicyRule("age-out", action="purge", min_age_s=60.0)
    hot = PolicyRule("hot-writer", action="archive",
                     types={R.CL_SETATTR}, metrics_min=2.0,
                     flags_all=R.CLF_SHARD)
    engine = PolicyEngine(mirror, [old, hot], target=proxy,
                          path=str(tmp_path / "act"))
    log.log(rec(R.CL_CREATE, 1, 0, name=b"cold"))
    log.log(rec(R.CL_CREATE, 2, 50, name=b"warm"))
    log.log(rec(R.CL_CREATE, 3, 55, name=b"writer"))
    log.log(rec(R.CL_SETATTR, 3, 58, shard=(0, 1, 0, 0), metrics=(3.0,)))
    log.log(rec(R.CL_SETATTR, 2, 61, metrics=(9.9,)))   # no CLF_SHARD
    drive(proxy, mirror)
    acts = engine.evaluate()
    by_rule = {(a.rule, a.key[1]) for a in acts}
    # clock is 61s: only oid=1 is >= 60s old; oid=2's setattr lacks the
    # shard flag; oid=3 matches the metrics threshold
    assert by_rule == {("age-out", 1), ("hot-writer", 3)}
    assert all(a.status == WAITING for a in acts)
    # evaluating again must not double-fire live (target, rule) pairs
    log.log(rec(R.CL_SETATTR, 3, 62, shard=(0, 1, 0, 0), metrics=(4.0,)))
    drive(proxy, mirror)
    assert engine.evaluate() == []
    cookie = next(a.cookie for a in acts if a.rule == "hot-writer")
    engine.start(cookie)
    assert engine.actions[cookie].status == STARTED
    engine.complete(cookie)
    assert engine.actions[cookie].status == SUCCEED
    assert engine.janitor_sweep() == 1
    assert cookie not in engine.actions
    proxy.pump()
    # the stream saw the full chain: NEW, UPDATE, COMPLETED, PURGED
    # (via the raw history store — the live journal may have trimmed)
    from repro.core.history import JournalReplayReader
    reader = JournalReplayReader(engine.log)
    chain, pos = [], 1
    while pos <= engine.log.last_index:
        batch, pos = reader.read(pos, 100)
        chain.extend(batch.to_records())
    types = [r.type for r in chain
             if r.xattr and r.xattr.get("cookie") == cookie]
    assert types == [R.CL_ACTION_NEW, R.CL_ACTION_UPDATE,
                     R.CL_ACTION_COMPLETED, R.CL_ACTION_PURGED]


def test_age_rule_fires_on_quiescent_entry(tmp_path):
    """The flagship Robinhood case: a file nobody touches again must
    still age out.  The engine queues the (target, rule) pair when the
    entry is too young and re-examines it once the stream clock passes
    the gate — no new activity on the target required."""
    proxy, log = mk_proxy(tmp_path)
    mirror = NamespaceMirror(proxy)
    engine = PolicyEngine(mirror, [PolicyRule("age-out", min_age_s=3600.0)],
                          target=proxy)
    log.log(rec(R.CL_CREATE, 1, 0, name=b"old"))
    drive(proxy, mirror)
    assert engine.evaluate() == []             # too young: queued, not lost
    # two hours pass on the stream clock, via an unrelated target
    log.log(rec(R.CL_CREATE, 99, 7200, name=b"unrelated"))
    drive(proxy, mirror)
    matched = engine.evaluate()
    assert {a.key[1] for a in matched} == {1}
    # the waiter fired once; a third pass emits nothing new for it
    assert all(a.key[1] != 1 for a in engine.evaluate())
    proxy.pump()
    assert reconcile(engine, proxy).ok


def test_engine_recovers_from_journal_on_restart(tmp_path):
    """A *new* engine instance over the same persistent action journal
    rebuilds its live-action table and continues the cookie sequence —
    no cookie reuse, no forgotten chains, reconciler stays clean."""
    proxy, log = mk_proxy(tmp_path)
    mirror = NamespaceMirror(proxy)
    rules = [PolicyRule("r", min_age_s=0)]
    e1 = PolicyEngine(mirror, rules, target=proxy,
                      path=str(tmp_path / "act"))
    for i in range(6):
        log.log(rec(R.CL_CREATE, i, i))
    drive(proxy, mirror)
    e1.evaluate()
    done = sorted(e1.actions)[:2]
    for c in done:
        e1.start(c)
        e1.complete(c)
    purged_key = e1.actions[done[0]].key
    e1.purge(done[0])                          # one purged, one completed
    proxy.pump()
    truth_before = e1.live_state()

    proxy2 = LcapProxy({"mdt0": log})          # restart, fresh engine too
    mirror2 = NamespaceMirror(proxy2, replay=True)
    e2 = PolicyEngine(mirror2, rules, target=proxy2,
                      path=str(tmp_path / "act"))
    assert e2.stats["recovered"] == len(truth_before)
    assert e2.live_state() == truth_before
    drive(proxy2, mirror2)
    # recovered live chains never re-fire; the *purged* target's slot
    # is free again, so its still-matching rule fires a fresh action
    refired = e2.evaluate()
    assert {a.key for a in refired} == {purged_key}
    # new emissions continue the cookie sequence past the recovered max
    log.log(rec(R.CL_CREATE, 50, 50))
    drive(proxy2, mirror2)
    (new,) = e2.evaluate()
    assert new.cookie > max(truth_before)
    proxy2.pump()
    assert reconcile(e2, proxy2).ok


def test_mirror_compact_applied_bounds_dedup_map(tmp_path):
    proxy, log = mk_proxy(tmp_path)
    mirror = NamespaceMirror(proxy)
    for i in range(30):
        log.log(rec(R.CL_CREATE, i, i))
        log.log(rec(R.CL_UNLINK, i, i + 0.5))
    drive(proxy, mirror)
    proxy.flush_upstream()
    assert log.first_index == log.last_index + 1   # fully trimmed
    assert len(mirror._applied) == 30              # tombstones retained
    snap = mirror.snapshot()
    dropped = mirror.compact_applied({"mdt0": log.first_index})
    assert dropped == 30 and not mirror._applied
    # the mirror still tracks new activity correctly afterwards
    log.log(rec(R.CL_CREATE, 100, 100))
    drive(proxy, mirror)
    assert (1, 100, 0) in mirror.entries
    assert snap == {}                              # everything was unlinked


def test_deferred_attach_loses_no_actions(tmp_path):
    """An engine built with target=None must retain actions emitted
    before attach(): the journal is armed at construction, and the
    first attach's reader owes acks for the whole backlog."""
    proxy, log = mk_proxy(tmp_path)
    mirror = NamespaceMirror(proxy)
    engine = PolicyEngine(mirror, [PolicyRule("r", min_age_s=0)],
                          target=None)
    log.log(rec(R.CL_CREATE, 1, 0))
    drive(proxy, mirror)
    (act,) = engine.evaluate()              # emitted while detached
    assert engine.log.last_index == 1       # journal armed: not dropped
    engine.attach(proxy)
    agent = connect(proxy).subscribe(Subscription(
        group="agent", types=R.CL_ACTION_TYPES, auto_commit=False))
    proxy.pump()
    got = [idx for _pid, b in agent.fetch(100) for idx in b.indices()]
    agent.commit()
    assert got == [1]                       # pre-attach backlog delivered
    assert reconcile(engine, proxy).ok


def test_zombie_actions_reaped_when_target_vanishes(tmp_path):
    proxy, log = mk_proxy(tmp_path)
    mirror = NamespaceMirror(proxy)
    engine = PolicyEngine(mirror, [PolicyRule("r", min_age_s=0)],
                          target=proxy)
    log.log(rec(R.CL_CREATE, 1, 0))
    drive(proxy, mirror)
    (act,) = engine.evaluate()
    log.log(rec(R.CL_UNLINK, 1, 1))
    drive(proxy, mirror)
    engine.evaluate()
    assert engine.stats["zombies_reaped"] == 1
    assert act.cookie not in engine.actions
    proxy.pump()
    assert reconcile(engine, proxy).ok


# -------------------------------------------------------------- reconciler
def test_reconciler_detects_injected_discrepancies(tmp_path):
    proxy, log = mk_proxy(tmp_path)
    mirror = NamespaceMirror(proxy)
    engine = PolicyEngine(mirror, [PolicyRule("r", min_age_s=0)],
                          target=proxy)
    for i in range(5):
        log.log(rec(R.CL_CREATE, i, i))
    drive(proxy, mirror)
    engine.evaluate()
    proxy.pump()
    assert reconcile(engine, proxy).ok

    from repro.policy.engine import Action
    # missing from stream: ground truth knows an action the stream never
    # carried (a lost NEW)
    engine.actions[999] = Action(999, (1, 77, 0), "r", "archive")
    # extra in stream: an action record whose PURGED the truth recorded
    # but the stream never got (simulated by emitting a chain the truth
    # does not track)
    ghost = Action(998, (1, 88, 0), "r", "archive")
    engine._emit(R.CL_ACTION_NEW, ghost, WAITING)
    # mismatched status: truth advanced, stream did not see the UPDATE
    victim = next(iter(engine.live_state()))
    engine.actions[victim].status = STARTED
    proxy.pump()

    report = reconcile(engine, proxy)
    assert not report.ok
    assert report.missing == [999]
    assert report.extra == [998]
    assert (victim, STARTED, WAITING) in report.mismatched
    assert "missing" in str(report)


# ------------------------------------------------------- restart / cluster
def test_action_lifecycle_exactly_once_through_proxy_restart(tmp_path):
    """Action records consumed and acknowledged before a proxy restart
    are never redelivered; records emitted around the restart all
    arrive — each action index exactly once end to end."""
    proxy, log = mk_proxy(tmp_path)
    mirror = NamespaceMirror(proxy)
    engine = PolicyEngine(mirror, [PolicyRule("r", min_age_s=0)],
                          target=proxy, path=str(tmp_path / "act"))
    agent = connect(proxy).subscribe(Subscription(
        group="agent", types=R.CL_ACTION_TYPES, auto_commit=False))
    seen = []

    def drain_agent(stream):
        for _pid, b in stream.fetch(4096):
            seen.extend(b.indices())
        stream.commit()

    for i in range(10):
        log.log(rec(R.CL_CREATE, i, i))
    drive(proxy, mirror)
    engine.evaluate()
    engine.run_pending()                       # NEW + UPDATE + COMPLETED
    proxy.pump()
    drain_agent(agent)
    proxy.flush_upstream()
    acked_before = len(seen)
    assert acked_before == 30

    # ---- restart: a new proxy over the same (persistent) journals ----
    proxy2 = LcapProxy({"mdt0": log})
    mirror2 = NamespaceMirror(proxy2, replay=True)
    engine.attach(proxy2)                      # journal watermark resumes
    engine.mirror = mirror2
    agent2 = connect(proxy2).subscribe(Subscription(
        group="agent", types=R.CL_ACTION_TYPES, auto_commit=False))
    drive(proxy2, mirror2)
    assert mirror2.snapshot() == mirror.snapshot()
    engine.evaluate()                          # live pairs: no re-emission
    assert engine.janitor_sweep() == 10        # PURGE the completed chains
    proxy2.pump()
    drain_agent(agent2)
    proxy2.flush_upstream()

    assert len(seen) == len(set(seen)), "duplicate action delivery"
    assert sorted(seen) == list(range(1, engine.log.last_index + 1)), \
        "gap in the action stream"
    assert reconcile(engine, proxy2).ok


def test_two_shard_cluster_chains_never_split(tmp_path):
    """Policy engine against an LcapCluster: actions route by target
    FID, so every record of one action chain lands on one shard; the
    mirror and reconciler hold across the fan-in."""
    logs = {f"mdt{m}": Llog(f"mdt{m}", path=str(tmp_path / f"j{m}"),
                            segment_records=16, history=True)
            for m in range(2)}
    cluster = LcapCluster(logs, n_shards=2)
    mirror = NamespaceMirror(cluster)
    engine = PolicyEngine(mirror, [PolicyRule("r", min_age_s=0)],
                          target=cluster)
    for i in range(40):
        logs[f"mdt{i % 2}"].log(rec(R.CL_CREATE, i, i, name=b"f%d" % i))
    for _ in range(30):
        moved = cluster.pump() + mirror.poll(4096)
        engine.evaluate()
        moved += cluster.pump()
        if not moved and not mirror.bootstrapping:
            break
    engine.run_pending()
    cluster.pump()
    assert len(engine.actions) == 40
    assert reconcile(engine, cluster).ok

    # per-shard audit: each cookie's chain is wholly on one shard
    placement = {}
    for i, shard in enumerate(cluster.shards):
        state = replay_action_state(shard.proxy)
        for cookie in state:
            assert cookie not in placement, "chain split across shards"
            placement[cookie] = i
    assert set(placement) == set(engine.actions)
    assert set(placement.values()) == {0, 1}   # both shards used


def churn_step(logs, i, keys):
    pid = f"mdt{i % len(logs)}"
    log = logs[pid]
    log.log(rec(R.CL_CREATE, i, i * 0.001, name=b"f%d" % i))
    keys.add(i)
    if i % 3 == 0:
        log.log(rec(R.CL_SETATTR, i, i * 0.001 + 0.0001,
                    shard=(0, i % 8, 0, 0), metrics=(float(i % 5),)))
    if i % 4 == 0 and i > 20:
        victim = i - 20
        log.log(rec(R.CL_UNLINK, victim, i * 0.001 + 0.0002))
        keys.discard(victim)


def test_churn_with_restart_reconciles_single_proxy(tmp_path):
    """The acceptance workload, single-proxy half: churn with a
    mid-run proxy restart; the reconciler reports zero
    discrepancies."""
    proxy, log = mk_proxy(tmp_path)
    logs = {"mdt0": log}
    mirror = NamespaceMirror(proxy)
    engine = PolicyEngine(mirror, [PolicyRule("attr", types={R.CL_SETATTR},
                                              min_age_s=0)],
                          target=proxy, path=str(tmp_path / "act"))
    keys = set()
    n, half = 2000, 1000
    for i in range(half):
        churn_step(logs, i, keys)
        if i % 100 == 0:
            drive(proxy, mirror, engine)
            engine.run_pending()
            if i % 200 == 0:
                engine.janitor_sweep()
    drive(proxy, mirror, engine)

    proxy2 = LcapProxy({"mdt0": log})          # mid-run restart
    mirror2 = NamespaceMirror(proxy2, replay=True)
    engine.attach(proxy2)
    engine.mirror = mirror2
    drive(proxy2, mirror2, engine)
    for i in range(half, n):
        churn_step(logs, i, keys)
        if i % 100 == 0:
            drive(proxy2, mirror2, engine)
            engine.run_pending()
    drive(proxy2, mirror2, engine)
    engine.run_pending()
    proxy2.pump()
    assert set(k[1] for k in mirror2.entries) == keys
    report = reconcile(engine, proxy2)
    assert report.ok, str(report)


def test_churn_with_shard_kill_reconciles_4shard_cluster(tmp_path):
    """The acceptance workload, cluster half: churn on a 4-shard
    cluster with one mid-run kill_shard; zero discrepancies."""
    logs = {f"mdt{m}": Llog(f"mdt{m}", path=str(tmp_path / f"j{m}"),
                            segment_records=64, history=True)
            for m in range(2)}
    cluster = LcapCluster(logs, n_shards=4)
    mirror = NamespaceMirror(cluster)
    engine = PolicyEngine(mirror, [PolicyRule("attr", types={R.CL_SETATTR},
                                              min_age_s=0)],
                          target=cluster)

    def settle():
        for _ in range(60):
            moved = cluster.pump() + mirror.poll(4096)
            engine.evaluate()
            moved += cluster.pump()
            if not moved and not mirror.bootstrapping:
                return
        raise AssertionError("cluster did not quiesce")

    keys = set()
    n, half = 2000, 1000
    for i in range(half):
        churn_step(logs, i, keys)
        if i % 100 == 0:
            settle()
            engine.run_pending()
            if i % 200 == 0:
                engine.janitor_sweep()
    settle()
    cluster.kill_shard(1)                      # mid-run failover
    for i in range(half, n):
        churn_step(logs, i, keys)
        if i % 100 == 0:
            settle()
            engine.run_pending()
    settle()
    engine.run_pending()
    cluster.pump()
    assert cluster.stats["shards_failed"] == 1
    assert set(k[1] for k in mirror.entries) == keys
    report = reconcile(engine, cluster)
    assert report.ok, str(report)
