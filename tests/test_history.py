"""Compacted history tier + replay-bootstrap subscriptions.

State-preserving compaction (CREATE+UNLINK annihilation, rename-chain
folding, last-writer-wins thinning), the Llog archive-at-trim hook,
HistoryStore persistence/crash recovery, and the replay handoff
contract: a replay-bootstrap consumer reconstructs the exact same
final state as a from-the-start live consumer, with zero gap and zero
duplicate at the handoff watermark — single proxy, wire, and sharded
cluster."""

import os
import threading
import time

import pytest

from repro.core import records as R
from repro.core.cluster import LcapCluster, LcapClusterService
from repro.core.errors import SubscriptionError
from repro.core.history import Compactor, HistoryStore, JournalReplayReader
from repro.core.llog import Llog
from repro.core.proxy import LcapProxy
from repro.core.server import LcapService
from repro.core.session import Subscription, connect


def rec(t=R.CL_CREATE, oid=1, ver=0, name=b"f", index=0, **kw):
    return R.ChangelogRecord(type=t, index=index, tfid=R.Fid(1, oid, ver),
                             pfid=R.Fid(1, 0, 0), name=name, **kw)


def batch_of(recs):
    for i, r in enumerate(recs):
        if not r.index:
            r.index = i + 1
    return R.RecordBatch.from_records(recs)


def apply_state(state, r):
    """The reference reducer both consumers run; compaction must be
    invisible to it."""
    t, k = r.type, r.key()
    if t in (R.CL_CREATE, R.CL_MKDIR, R.CL_MKNOD, R.CL_SOFTLINK):
        state[k] = {"name": r.name, "attr": None, "hb": None}
    elif t in (R.CL_UNLINK, R.CL_RMDIR):
        state.pop(k, None)
    elif t == R.CL_RENAME:
        if k in state:
            state[k]["name"] = r.name
    elif t == R.CL_SETATTR:
        if k in state:
            state[k]["attr"] = r.index
    elif t == R.CL_HEARTBEAT:
        state.setdefault(k, {})["hb"] = r.metrics


def drain_state(stream, state, rounds=400, done=None):
    """Fetch until the stream is dry (and any replay finished)."""
    for _ in range(rounds):
        pairs = stream.fetch(4096)
        for _pid, b in pairs:
            for i in range(len(b)):
                apply_state(state, b.record(i))
        stream.commit()
        if not pairs and not stream.replaying and (done is None or done()):
            return
    raise AssertionError("stream did not drain")


# ------------------------------------------------------------- compactor
def test_annihilates_closed_lifetimes():
    c = Compactor()
    out = c.compact(batch_of([
        rec(R.CL_CREATE, oid=1), rec(R.CL_SETATTR, oid=1),
        rec(R.CL_RENAME, oid=1, name=b"g"), rec(R.CL_UNLINK, oid=1),
        rec(R.CL_CREATE, oid=2),
    ]))
    assert [R.unpack(b).type for b in out] == [R.CL_CREATE]
    assert R.unpack(out[0]).tfid.oid == 2
    assert c.stats["annihilated"] == 4


def test_unlink_without_observed_create_is_kept():
    c = Compactor()
    out = c.compact(batch_of([rec(R.CL_SETATTR, oid=1),
                              rec(R.CL_UNLINK, oid=1)]))
    assert [R.unpack(b).type for b in out] == [R.CL_SETATTR, R.CL_UNLINK]


def test_hardlinked_lifetime_not_annihilated():
    c = Compactor()
    out = c.compact(batch_of([
        rec(R.CL_CREATE, oid=1), rec(R.CL_HARDLINK, oid=1),
        rec(R.CL_UNLINK, oid=1),
    ]))
    assert len(out) == 3                     # UNLINK removed one name only


def test_recreate_after_unlink_survives():
    c = Compactor()
    out = c.compact(batch_of([
        rec(R.CL_CREATE, oid=1), rec(R.CL_UNLINK, oid=1),
        rec(R.CL_CREATE, oid=1, name=b"again"),
    ]))
    parsed = [R.unpack(b) for b in out]
    assert [p.type for p in parsed] == [R.CL_CREATE]
    assert parsed[0].name == b"again"


def test_rename_chain_folds_to_original_source_final_target():
    c = Compactor()
    out = c.compact(batch_of([
        rec(R.CL_RENAME, oid=1, name=b"b", sfid=R.Fid(9, 9, 9),
            sname=b"a"),
        rec(R.CL_RENAME, oid=1, name=b"c", sfid=R.Fid(8, 8, 8),
            sname=b"b"),
        rec(R.CL_RENAME, oid=1, name=b"d", sfid=R.Fid(7, 7, 7),
            sname=b"c"),
    ]))
    assert len(out) == 1
    folded = R.unpack(out[0])
    assert folded.name == b"d" and folded.sname == b"a"
    assert folded.sfid == R.Fid(9, 9, 9)     # original source
    assert folded.index == 3                 # final rename's position
    assert c.stats["folded"] == 2


def test_idempotent_ops_thin_to_last_writer():
    c = Compactor()
    out = c.compact(batch_of([
        rec(R.CL_CREATE, oid=1),
        rec(R.CL_SETATTR, oid=1), rec(R.CL_SETATTR, oid=1),
        rec(R.CL_SETATTR, oid=1),
        rec(R.CL_HEARTBEAT, oid=7, metrics=(0.1,)),
        rec(R.CL_HEARTBEAT, oid=7, metrics=(0.9,)),
    ]))
    parsed = [R.unpack(b) for b in out]
    assert [p.type for p in parsed] == [R.CL_CREATE, R.CL_SETATTR,
                                        R.CL_HEARTBEAT]
    assert parsed[1].index == 4              # the last SETATTR
    assert parsed[2].metrics == (0.9,)       # the last heartbeat
    assert c.stats["thinned"] == 3


def test_output_stays_in_journal_index_order():
    c = Compactor()
    out = c.compact(batch_of([
        rec(R.CL_CREATE, oid=1), rec(R.CL_CREATE, oid=2),
        rec(R.CL_SETATTR, oid=1), rec(R.CL_SETATTR, oid=2),
        rec(R.CL_SETATTR, oid=1),
    ]))
    indices = [R.unpack(b).index for b in out]
    assert indices == sorted(indices) == [1, 2, 4, 5]


# ---------------------------------------------------------- history store
def feed_churn(log, n_files=20, setattrs=3, unlink_every=2):
    """Create/spam/rename/maybe-unlink — the churn workload."""
    for i in range(n_files):
        log.log(rec(R.CL_CREATE, oid=i, name=b"f%d" % i))
        for _ in range(setattrs):
            log.log(rec(R.CL_SETATTR, oid=i))
        log.log(rec(R.CL_RENAME, oid=i, name=b"g%d" % i, sname=b"f%d" % i,
                    sfid=R.Fid(1, i, 0)))
        if i % unlink_every == 0:
            log.log(rec(R.CL_UNLINK, oid=i, name=b"g%d" % i))


def test_trim_archives_instead_of_unlinking(tmp_path):
    log = Llog("mdt0", path=str(tmp_path / "j"), segment_records=8,
               history=True)
    rid = log.register_reader()
    feed_churn(log)
    total = log.last_index
    log.ack(rid, total)                       # trims everything
    assert log.first_index == total + 1
    hist = log.history
    assert (hist.covered_lo, hist.covered_hi) == (1, total)
    assert 0 < hist.record_count < total      # compacted on merge
    # archived files exist; dropped journal segments are gone
    assert not [p for p in os.listdir(tmp_path)
                if ".seg." in p and os.path.getsize(tmp_path / p)]


def test_archive_is_idempotent():
    hist = HistoryStore()
    b = batch_of([rec(oid=1), rec(oid=2)])
    assert hist.archive(b, 1, 2)
    assert not hist.archive(b, 1, 2)          # crash-window replay
    assert hist.stats["duplicate_skips"] == 1
    assert hist.record_count == 2


def test_read_skips_annihilated_gaps_and_advances():
    hist = HistoryStore(merge_factor=2)
    hist.archive(batch_of([rec(R.CL_CREATE, oid=1, index=1),
                           rec(R.CL_CREATE, oid=2, index=2)]), 1, 2)
    hist.archive(batch_of([rec(R.CL_SETATTR, oid=1, index=3),
                           rec(R.CL_UNLINK, oid=1, index=4)]), 3, 4)
    # merge compacted: oid=1's whole lifetime annihilated
    assert hist.record_count == 1
    batch, nxt = hist.read(1, 10)
    assert [R.unpack(b).index for b in batch] == [2]
    assert nxt == 5                           # gap 3..4 covered too
    empty, nxt = hist.read(3, 10)
    assert len(empty) == 0 and nxt == 5


def test_store_reload_and_crash_recovery(tmp_path):
    base = str(tmp_path / "hist")
    hist = HistoryStore(base, merge_factor=100)
    hist.archive(batch_of([rec(oid=1, index=1), rec(oid=2, index=2)]), 1, 2)
    hist.archive(batch_of([rec(oid=3, index=3), rec(oid=4, index=4)]), 3, 4)
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 2
    # crash mid-merge leaves a stray tmp; crash mid-write leaves a torn
    # tail record — both must be absorbed on reload (Llog parity)
    with open(base + ".0.8.tmp", "wb") as fh:
        fh.write(b"garbage")
    torn = str(tmp_path / files[-1])
    with open(torn, "r+b") as fh:
        fh.truncate(os.path.getsize(torn) - 3)
    hist2 = HistoryStore(base)
    assert not [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]
    assert hist2.stats["torn_dropped"] == 1
    batch, _ = hist2.read(1, 10)
    assert [R.unpack(b).index for b in batch] == [1, 2, 3]
    assert (hist2.covered_lo, hist2.covered_hi) == (1, 4)


def test_reload_drops_segments_covered_by_a_merge(tmp_path):
    base = str(tmp_path / "hist")
    hist = HistoryStore(base, merge_factor=100)
    hist.archive(batch_of([rec(oid=1, index=1)]), 1, 1)
    hist.archive(batch_of([rec(oid=2, index=2)]), 2, 2)
    saved = {p: (tmp_path / p).read_bytes() for p in os.listdir(tmp_path)}
    hist.compact_now()                        # writes merged, deletes parts
    for p, blob in saved.items():             # crash before the deletes
        (tmp_path / p).write_bytes(blob)
    assert len(os.listdir(tmp_path)) == 3
    hist2 = HistoryStore(base)
    assert hist2.segment_count == 1           # merged segment wins
    assert len(os.listdir(tmp_path)) == 1     # covered files deleted
    batch, _ = hist2.read(1, 10)
    assert [R.unpack(b).index for b in batch] == [1, 2]


def test_journal_replay_reader_spans_history_and_live(tmp_path):
    log = Llog("mdt0", path=str(tmp_path / "j"), segment_records=4,
               history=True)
    rid = log.register_reader()
    for i in range(10):
        log.log(rec(oid=100 + i))             # unique targets: no drops
    log.ack(rid, 6)                           # segments [1..4] archived
    assert log.history.covered_hi == 4
    reader = JournalReplayReader(log)
    assert reader.available_lo() == 1
    got, pos = [], 1
    while pos <= 10:
        batch, pos = reader.read(pos, 3)
        got.extend(batch.indices())
    assert got == list(range(1, 11))          # gapless across the seam


# ------------------------------------------------------- replay: 1 proxy
def mk_history_proxy(tmp_path, **llog_kw):
    log = Llog("mdt0", path=str(tmp_path / "j"), segment_records=16,
               history=True, **llog_kw)
    proxy = LcapProxy({"mdt0": log})
    return proxy, log


def run_churn_with_live(proxy, log, state_live, n_files=40):
    live = connect(proxy).subscribe("live")
    for i in range(n_files):
        feed_churn(log, n_files=1, setattrs=2)
        proxy.pump()
        for _pid, b in live:
            for x in range(len(b)):
                apply_state(state_live, b.record(x))
        live.commit()
        proxy.flush_upstream()
    return live


def test_replay_bootstrap_matches_live_state(tmp_path):
    proxy, log = mk_history_proxy(tmp_path)
    state_live = {}
    run_churn_with_live(proxy, log, state_live)
    assert log.first_index > 1                # journal really trimmed
    boot = connect(proxy).subscribe(Subscription(group="boot", replay=True))
    state_boot = {}
    drain_state(boot, state_boot)
    assert boot.replayed > 0
    assert state_boot == state_live
    # compaction made the bootstrap cheaper than the full journal
    assert boot.replayed < log.last_index


def test_replay_from_index(tmp_path):
    proxy, log = mk_history_proxy(tmp_path)
    log2_state = {}
    run_churn_with_live(proxy, log, log2_state, n_files=10)
    hi = log.last_index
    boot = connect(proxy).subscribe(Subscription(group="boot",
                                                 replay=hi - 4))
    got = []
    for _ in range(50):
        for _pid, b in boot.fetch(4096):
            got.extend(b.indices())
        if not boot.replaying:
            break
    assert got and min(got) >= hi - 4


def test_replay_requires_fresh_group_and_no_resume(tmp_path):
    proxy, log = mk_history_proxy(tmp_path)
    session = connect(proxy)
    session.subscribe("taken")
    with pytest.raises(SubscriptionError):
        session.subscribe(Subscription(group="taken", replay=True))
    with pytest.raises(SubscriptionError):
        proxy.attach("fresh", name="n", resume=True, replay=True)


def test_replay_beyond_available_history_is_refused(tmp_path):
    log = Llog("mdt0", path=str(tmp_path / "j"), segment_records=4)
    proxy = LcapProxy({"mdt0": log})          # no history store
    for i in range(10):
        log.log(rec(oid=i))
    proxy.pump()
    s = connect(proxy).subscribe("g")
    for _pid, b in s:
        pass
    s.commit()
    proxy.flush_upstream()                    # trims; history is gone
    assert log.first_index > 1
    with pytest.raises(SubscriptionError):
        connect(proxy).subscribe(Subscription(group="boot", replay=True))
    # the untrimmed suffix is still replayable
    stream = connect(proxy).subscribe(
        Subscription(group="ok", replay=log.first_index))
    assert stream is not None


def test_replay_handoff_exact_under_concurrent_ingest(tmp_path):
    """The acceptance-criterion exactness check: with compaction
    disabled, replayed ∪ live is every index exactly once, split at
    the handoff watermark, while the producer keeps logging."""
    log = Llog("mdt0", path=str(tmp_path / "j"), segment_records=16,
               history=HistoryStore(str(tmp_path / "j.hist"),
                                    compactor=None))
    proxy = LcapProxy({"mdt0": log})
    svc = LcapService(proxy, poll_interval=0.001).start()
    try:
        live = connect(svc.address).subscribe("live")
        for i in range(200):
            log.log(rec(oid=i))
        deadline, got = time.time() + 5, 0
        while got < 200 and time.time() < deadline:
            for _pid, b in live:
                got += len(b)
            live.commit()
        assert got == 200

        stop = threading.Event()

        def produce():
            i = 200
            while not stop.is_set():
                log.log(rec(oid=i))
                i += 1
                time.sleep(0.0003)

        t = threading.Thread(target=produce)
        t.start()
        time.sleep(0.02)
        boot = connect(svc.address).subscribe(
            Subscription(group="boot", replay=True))
        replay_idx, live_idx = set(), set()
        deadline = time.time() + 10
        while time.time() < deadline:
            before = boot.replayed
            pairs = boot.fetch(256)
            delta = boot.replayed - before    # replay batches come first
            seen = 0
            for _pid, b in pairs:
                for x in range(len(b)):
                    tgt = replay_idx if seen < delta else live_idx
                    tgt.add(b.packed_index(x))
                    seen += 1
            boot.commit()
            if not boot.replaying and len(replay_idx | live_idx) >= 260:
                break
        stop.set()
        t.join()
        for _ in range(80):                   # drain the tail
            for _pid, b in boot.fetch(4096):
                for x in range(len(b)):
                    live_idx.add(b.packed_index(x))
            boot.commit()
            for _pid, b in live:
                pass
            live.commit()
        total = log.last_index
        assert replay_idx and live_idx
        assert not (replay_idx & live_idx), "duplicate at handoff"
        assert max(replay_idx) < min(live_idx), "handoff not a watermark"
        assert (replay_idx | live_idx) == set(range(1, total + 1)), "gap"
    finally:
        svc.stop()


def test_ephemeral_replay_is_an_audit_scan(tmp_path):
    proxy, log = mk_history_proxy(tmp_path)
    state_live = {}
    run_churn_with_live(proxy, log, state_live, n_files=15)
    audit = connect(proxy).subscribe(Subscription(mode="ephemeral",
                                                  replay=True))
    state = {}
    drain_state(audit, state)
    assert state == state_live
    # ephemeral: the scan never blocked the journal trim
    assert proxy.upstream_acked["mdt0"] == log.last_index


def test_parked_replay_resumes_where_it_stopped(tmp_path):
    proxy, log = mk_history_proxy(tmp_path)
    state_live = {}
    run_churn_with_live(proxy, log, state_live)
    session = connect(proxy)
    boot = session.subscribe(Subscription(group="boot", name="b0",
                                          replay=True, max_records=8))
    state_boot = {}
    pairs = boot.fetch(8)                     # a *partial* bootstrap
    for _pid, b in pairs:
        for x in range(len(b)):
            apply_state(state_boot, b.record(x))
    assert boot.replaying
    boot.detach()                             # connection lost: parked
    resumed = session.resume("boot", "b0")
    assert resumed.replaying                  # bootstrap continues
    drain_state(resumed, state_boot)
    assert state_boot == state_live


# ------------------------------------------------------ replay: cluster
def mk_cluster(tmp_path, n_shards=2):
    logs = {f"mdt{m}": Llog(f"mdt{m}", path=str(tmp_path / f"j{m}"),
                            segment_records=16, history=True)
            for m in range(2)}
    return LcapCluster(logs, n_shards=n_shards), logs


def churn_cluster(cluster, logs, live, state_live, n=40):
    for i in range(n):
        for m, log in enumerate(logs.values()):
            log.log(rec(R.CL_CREATE, oid=i * 2 + m, name=b"f%d" % i))
            log.log(rec(R.CL_SETATTR, oid=i * 2 + m))
            if i % 3 == 0:
                log.log(rec(R.CL_UNLINK, oid=i * 2 + m))
        cluster.pump()
        for _pid, b in live:
            for x in range(len(b)):
                apply_state(state_live, b.record(x))
        live.commit()
        cluster.collect_watermarks()


def test_cluster_replay_bootstrap_matches_live(tmp_path):
    cluster, logs = mk_cluster(tmp_path)
    live = connect(cluster).subscribe("live")
    state_live = {}
    churn_cluster(cluster, logs, live, state_live)
    assert all(log.first_index > 1 for log in logs.values())
    boot = connect(cluster).subscribe(Subscription(group="boot",
                                                   replay=True))
    state_boot = {}
    drain_state(boot, state_boot)
    assert boot.replayed > 0
    assert state_boot == state_live


def test_cluster_replay_after_shard_kill_reroute(tmp_path):
    """Compaction + replay across a failover: the dead shard's slots
    re-route, and a consumer bootstrapping afterwards reads that
    history from the surviving owners."""
    cluster, logs = mk_cluster(tmp_path)
    live = connect(cluster).subscribe("live")
    state_live = {}
    churn_cluster(cluster, logs, live, state_live, n=25)
    cluster.kill_shard(0)
    churn_cluster(cluster, logs, live, state_live, n=10)
    boot = connect(cluster).subscribe(Subscription(group="boot",
                                                   replay=True))
    state_boot = {}
    drain_state(boot, state_boot)
    assert state_boot == state_live
    assert cluster.stats["shards_failed"] == 1


def test_cluster_service_replay_over_the_wire(tmp_path):
    cluster, logs = mk_cluster(tmp_path)
    service = LcapClusterService(cluster, poll_interval=0.001).start()
    try:
        live = connect(service).subscribe("live")
        state_live = {}
        for i in range(30):
            for m, log in enumerate(logs.values()):
                log.log(rec(R.CL_CREATE, oid=i * 2 + m))
                log.log(rec(R.CL_SETATTR, oid=i * 2 + m))
                if i % 2 == 0:
                    log.log(rec(R.CL_UNLINK, oid=i * 2 + m))
        total = sum(log.last_index for log in logs.values())
        deadline, seen = time.time() + 10, 0
        while seen < total and time.time() < deadline:
            for _pid, b in live:
                for x in range(len(b)):
                    apply_state(state_live, b.record(x))
                    seen += 1
            live.commit()
        assert seen == total
        # let collective acks trim the journals into history
        deadline = time.time() + 10
        while time.time() < deadline and \
                any(log.first_index <= log.last_index for log in
                    logs.values()):
            time.sleep(0.005)
        boot = connect(service).subscribe(Subscription(group="boot",
                                                       replay=True))
        state_boot = {}
        deadline = time.time() + 10
        while time.time() < deadline:
            pairs = boot.fetch(4096)
            for _pid, b in pairs:
                for x in range(len(b)):
                    apply_state(state_boot, b.record(x))
            boot.commit()
            if not pairs and not boot.replaying and state_boot == state_live:
                break
        assert state_boot == state_live
        assert boot.replayed > 0
    finally:
        service.stop()


def test_replay_runs_the_stream_modules(tmp_path):
    """A replay consumer must see the stream the proxy's modules
    produce, not the raw archive, or its state diverges from every
    live consumer's (modules run at ingest, before the journal view a
    live group gets — but *after* what the history tier archives)."""
    from repro.core.modules import TypeFilter
    log = Llog("mdt0", path=str(tmp_path / "j"), segment_records=8,
               history=True)
    proxy = LcapProxy({"mdt0": log},
                      modules=[TypeFilter({R.CL_CREATE, R.CL_UNLINK,
                                           R.CL_SETATTR, R.CL_RENAME})])
    live = connect(proxy).subscribe("live")
    state_live = {}
    for i in range(20):
        log.log(rec(R.CL_CREATE, oid=i))
        log.log(rec(R.CL_HEARTBEAT, oid=100 + i, metrics=(0.5,)))
        proxy.pump()
        for _pid, b in live:
            for x in range(len(b)):
                apply_state(state_live, b.record(x))
        live.commit()
        proxy.flush_upstream()
    assert not any(k[1] >= 100 for k in state_live)   # hb filtered live
    boot = connect(proxy).subscribe(Subscription(group="boot", replay=True))
    state_boot = {}
    drain_state(boot, state_boot)
    assert state_boot == state_live


def test_cluster_replay_interrupted_by_failover_rewinds(tmp_path):
    """A shard killed mid-bootstrap must not leave its re-routed
    slots' history unreplayed: the survivors' active bootstraps rewind
    and re-cover them (at-least-once through the failover)."""
    cluster, logs = mk_cluster(tmp_path)
    live = connect(cluster).subscribe("live")
    state_live = {}
    churn_cluster(cluster, logs, live, state_live, n=40)
    boot = connect(cluster).subscribe(Subscription(group="boot",
                                                   replay=True,
                                                   max_records=4))
    state_boot = {}
    pairs = boot.fetch(4)                 # partial bootstrap on shards
    for _pid, b in pairs:
        for x in range(len(b)):
            apply_state(state_boot, b.record(x))
    assert boot.replaying
    cluster.kill_shard(0)
    drain_state(boot, state_boot)
    assert boot.lost == [0]
    assert state_boot == state_live
