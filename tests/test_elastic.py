"""Elastic + straggler response policies."""

from repro.core import records as R
from repro.core.proxy import LcapProxy
from repro.core.reader import LocalReader
from repro.runtime.straggler import StragglerMitigator, rebalance_shards
from repro.track import ActivityTracker, StragglerDetector


def test_rebalance_even_without_ewma():
    out = rebalance_shards(8, [0, 1, 2, 3], {})
    assert sorted(sum(out.values(), [])) == list(range(8))
    assert all(len(v) == 2 for v in out.values())


def test_rebalance_shifts_away_from_straggler():
    ewma = {0: 0.1, 1: 0.1, 2: 0.4, 3: 0.1}     # host 2 is 4x slower
    out = rebalance_shards(16, [0, 1, 2, 3], ewma)
    assert sorted(sum(out.values(), [])) == list(range(16))
    assert len(out[2]) < len(out[0])
    assert len(out[2]) >= 1                      # not starved entirely


def test_mitigator_emits_straggler_records():
    trackers = [ActivityTracker(run_id=1, host_id=h) for h in range(3)]
    proxy = LcapProxy({t.llog.producer_id: t.llog for t in trackers})
    det = StragglerDetector(proxy)
    audit = LocalReader(proxy, "audit")
    mit = StragglerMitigator(det, n_shards=6, tracker=trackers[0])

    for step in range(8):
        for h, t in enumerate(trackers):
            t.heartbeat(step, step_time_s=0.5 if h == 1 else 0.1)
    proxy.pump()
    det.poll()
    assert det.flagged == {1}
    new = mit.maybe_rebalance([0, 1, 2], step=8)
    assert new is not None and len(new[1]) < len(new[0])
    # decision visible on the changelog stream
    proxy.pump()
    types = [rec.type for _, rec in audit.fetch(100)]
    assert R.CL_STRAGGLER in types
    # unchanged verdict -> no repeated rebalance
    assert mit.maybe_rebalance([0, 1, 2], step=9) is None
