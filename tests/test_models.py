"""Per-architecture smoke tests: reduced same-family configs, one
forward + one train-grad step + prefill/decode consistency on CPU.
Asserts output shapes and absence of NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, prefill, count_params)

ARCHS = C.list_archs()
B, S = 2, 16


def inputs_for(cfg, batch=B, seq=S):
    rng = np.random.RandomState(0)
    kw = {}
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (batch, seq)),
                         jnp.int32)
    if cfg.is_encoder_decoder:
        kw["frames"] = jnp.asarray(
            rng.randn(batch, cfg.n_frames, cfg.d_model), jnp.float32)
    if cfg.n_image_patches:
        kw["image_embeds"] = jnp.asarray(
            rng.randn(batch, cfg.n_image_patches, cfg.d_model), jnp.float32)
    return tokens, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = C.get_smoke(arch)
    params = init_params(cfg, seed=0)
    tokens, kw = inputs_for(cfg)
    logits, aux = forward(params, cfg, tokens, **kw)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step(arch):
    cfg = C.get_smoke(arch)
    params = init_params(cfg, seed=0)
    tokens, kw = inputs_for(cfg)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss(p):
        l, _ = loss_fn(p, cfg, tokens, labels, **kw)
        return l

    val, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(val))
    # a sensible init: loss near ln(vocab)
    assert float(val) < 2 * np.log(cfg.vocab_size) + 1
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), arch
    # gradients actually flow to the embedding and deep layers
    gnorm = sum(float(jnp.abs(g).sum()) for g in leaves)
    assert gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """decode(t) after prefill(0..t-1) must reproduce the full-sequence
    forward logits at position t."""
    cfg = C.get_smoke(arch)
    params = init_params(cfg, seed=0)
    tokens, kw = inputs_for(cfg, seq=S)
    full_logits, _ = forward(params, cfg, tokens, **kw)

    cut = S - 1
    last_logits, cache = prefill(params, cfg, tokens[:, :cut],
                                 max_seq=S, **kw)
    np.testing.assert_allclose(
        np.asarray(last_logits), np.asarray(full_logits[:, cut - 1]),
        rtol=0.12, atol=0.12)

    pos = jnp.full((B,), cut, jnp.int32)
    step_logits, cache = decode_step(params, cfg, tokens[:, cut:cut + 1],
                                     cache, pos)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, cut]),
        rtol=0.12, atol=0.12)


@pytest.mark.parametrize("arch", ["granite-8b", "gemma2-9b", "mamba2-780m",
                                  "jamba-v0.1-52b"])
def test_blockwise_attention_matches_naive(arch):
    cfg = C.get_smoke(arch)
    params = init_params(cfg, seed=0)
    tokens, kw = inputs_for(cfg)
    naive, _ = forward(params, cfg, tokens, impl="naive", **kw)
    block, _ = forward(params, cfg, tokens, impl="blockwise", **kw)
    np.testing.assert_allclose(np.asarray(naive), np.asarray(block),
                               rtol=0.05, atol=0.05)


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }
    for arch, (L_, D, H, KV, F, V) in expect.items():
        cfg = C.get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads) == \
            (L_, D, H, KV), arch
        ff = cfg.moe_d_ff if cfg.family == "moe" else cfg.d_ff
        assert ff == F and cfg.vocab_size == V, arch
    m = C.get_config("mamba2-780m")
    assert (m.n_layers, m.d_model, m.vocab_size, m.ssm_state) == \
        (48, 1536, 50280, 128)
    q = C.get_config("qwen3-moe-30b-a3b")
    assert (q.n_experts, q.top_k) == (128, 8)
    g = C.get_config("granite-moe-1b-a400m")
    assert (g.n_experts, g.top_k) == (32, 8)
    j = C.get_config("jamba-v0.1-52b")
    assert (j.n_experts, j.top_k, j.hybrid_period) == (16, 2, 8)


def test_param_counts_in_expected_range():
    """Sanity: derived param counts are in the ballpark the arch names
    claim (loose bounds; head_dim derives from the assigned table)."""
    expect_b = {"starcoder2-3b": (2.0, 4.5), "gemma2-9b": (7.5, 11.5),
                "granite-8b": (6.5, 9.5), "qwen2.5-14b": (11.0, 16.0),
                "mamba2-780m": (0.6, 1.0), "jamba-v0.1-52b": (38.0, 60.0)}
    for arch, (lo, hi) in expect_b.items():
        n = count_params(C.get_config(arch)) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"


def test_ring_buffer_window_cache_multi_step():
    """Sliding-window decode with a ring cache of exactly `window` slots
    must reproduce full-sequence forward logits across several
    wrap-arounds."""
    cfg = C.get_smoke("gemma2-9b")          # window=8, alternating local
    params = init_params(cfg, seed=0)
    S_total = 24
    tokens, kw = inputs_for(cfg, seq=S_total)
    full_logits, _ = forward(params, cfg, tokens, **kw)

    cut = 4                                  # prefill shorter than window
    _, cache = prefill(params, cfg, tokens[:, :cut], max_seq=S_total, **kw)
    # local slots use ring buffers of size window (8), not S_total
    assert cache["slot0"]["k"].shape[2] == 8
    assert cache["slot1"]["k"].shape[2] == S_total
    for t in range(cut, S_total):            # 20 steps, 2+ wraps
        pos = jnp.full((B,), t, jnp.int32)
        step_logits, cache = decode_step(params, cfg, tokens[:, t:t + 1],
                                         cache, pos)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=0.15, atol=0.15, err_msg=f"step {t}")
