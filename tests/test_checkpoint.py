"""Fault tolerance: checkpoint/restore round-trip, async overlap, crash
+ restart resume, elastic resharding onto a different mesh."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.models import transformer as T
from repro.optim import adamw


@pytest.fixture(scope="module")
def small_state():
    cfg = C.get_smoke("granite-8b")
    params = T.init_params(cfg, seed=1)
    opt = adamw.init(params)
    return cfg, {"params": params, "opt": opt}


def test_save_restore_roundtrip(tmp_path, small_state):
    cfg, tree = small_state
    save_checkpoint(tree, 7, str(tmp_path), n_shards=3)
    assert latest_step(str(tmp_path)) == 7
    out = restore_checkpoint(tree, 7, str(tmp_path))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpointer_overlap(tmp_path, small_state):
    _, tree = small_state
    ck = AsyncCheckpointer(str(tmp_path), n_shards=2)
    f1 = ck.submit(tree, 1)
    f2 = ck.submit(tree, 2)          # waits for f1 internally
    ck.close()
    assert f1.done() and f2.done()
    assert latest_step(str(tmp_path)) == 2


def test_restore_with_mesh_shardings(tmp_path, small_state):
    """Elastic path: checkpoint is mesh-agnostic; restore lands on the
    current (1x1) mesh with the logical rules applied."""
    from repro.runtime.elastic import reshard_state
    cfg, tree = small_state
    save_checkpoint(tree, 3, str(tmp_path), n_shards=2)
    out = restore_checkpoint(tree, 3, str(tmp_path))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params, opt, rules = reshard_state(cfg, out["params"], out["opt"], mesh)
    leaf = jax.tree.leaves(params)[0]
    assert leaf.sharding.mesh.shape == {"data": 1, "model": 1}


def test_plan_mesh_shapes():
    from repro.runtime.elastic import plan_mesh_shape
    assert plan_mesh_shape(256) == (16, 16)
    assert plan_mesh_shape(12) == (2, 4)      # degraded fleet -> 8 usable
    assert plan_mesh_shape(1) == (1, 1)


def test_crash_restart_resumes_exactly(tmp_path):
    """Train 6 steps with ckpt_every=3, 'crash', restart: the trainer
    resumes from step 3 with identical data (stateless pipeline) and the
    journals survive on disk."""
    script = textwrap.dedent("""
        import os, sys, json
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        from repro import configs as C
        from repro.runtime.train_loop import Trainer
        cfg = C.get_smoke("starcoder2-3b")
        phase = sys.argv[1]
        wd = sys.argv[2]
        t = Trainer(cfg, workdir=wd, global_batch=4, seq_len=16,
                    n_hosts=2, ckpt_every=3)
        if phase == "first":
            hist = t.run(4)          # crash after step 4 (ckpt at 3)
            t.ckpt.wait()
            print(json.dumps({"start": hist[0]["step"],
                              "end": hist[-1]["step"]}))
        else:
            assert t.step == 3, t.step
            hist = t.run(2)
            print(json.dumps({"start": hist[0]["step"],
                              "end": hist[-1]["step"],
                              "resumed_from": 3}))
        t.close()
    """)
    env = dict(os.environ, PYTHONPATH="src")
    wd = str(tmp_path / "run")
    r1 = subprocess.run([sys.executable, "-c", script, "first", wd],
                        capture_output=True, text=True, env=env, cwd="/root/repo")
    assert r1.returncode == 0, r1.stderr[-2000:]
    assert '"end": 4' in r1.stdout
    r2 = subprocess.run([sys.executable, "-c", script, "second", wd],
                        capture_output=True, text=True, env=env, cwd="/root/repo")
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert '"start": 4' in r2.stdout and '"end": 5' in r2.stdout
