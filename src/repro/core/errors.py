"""Typed errors of the changelog client/server API.

Server replies carry ``{"err": "ExcName: msg", "err_type": "ExcName"}``;
the client side (session.py) maps them back to these classes instead of
surfacing strings.  The hierarchy deliberately doubles as the built-in
types the pre-session API raised (``KeyError`` for unknown consumers,
``ValueError`` for bad subscriptions), so code written against the old
readers keeps catching what it always caught.
"""

from __future__ import annotations

from typing import Dict, Type


class SessionError(RuntimeError):
    """Base for all client-visible changelog API errors."""


class SubscriptionError(SessionError, ValueError):
    """A subscription spec the proxy cannot honor (missing group,
    unknown mode, duplicate durable name, unsupported protocol...)."""


class UnknownConsumerError(SessionError, KeyError):
    """The consumer id / durable name is not (or no longer) registered."""

    def __str__(self) -> str:  # KeyError quotes its arg; keep the message
        return RuntimeError.__str__(self)


class UnknownProducerError(SessionError, KeyError):
    """An acknowledgement names a producer the proxy does not track."""

    def __str__(self) -> str:
        return RuntimeError.__str__(self)


class ClusterError(SessionError):
    """A sharded-cluster operation failed (no live shards, a shard verb
    rejected, or a malformed cluster topology)."""


class TenantError(SessionError, PermissionError):
    """A tenant-scope violation: a subscription tried to widen (or take
    over) a scope it does not own — resuming another tenant's durable
    cursor, broadening a parked tenant scope, or a malformed
    ``TenantPrincipal``.  Scope *enforcement* never raises: out-of-scope
    records are silently acknowledged in place by the proxy (pushdown),
    exactly like op-type filtering."""


#: reply ``err_type`` -> exception class (legacy names map onto the
#: closest typed error so old servers still produce typed failures)
WIRE_ERRORS: Dict[str, Type[SessionError]] = {
    "SessionError": SessionError,
    "SubscriptionError": SubscriptionError,
    "UnknownConsumerError": UnknownConsumerError,
    "UnknownProducerError": UnknownProducerError,
    "ClusterError": ClusterError,
    "TenantError": TenantError,
    "KeyError": UnknownConsumerError,
    "ValueError": SubscriptionError,
}


def raise_reply_error(reply: dict) -> None:
    """Raise the typed exception a ``{"err": ...}`` reply encodes; no-op
    for successful replies."""
    err = reply.get("err")
    if not err:
        return
    name = reply.get("err_type")
    if name is None and ":" in err:        # legacy "ExcName: msg" replies
        name = err.split(":", 1)[0]
    cls = WIRE_ERRORS.get(name or "", SessionError)
    raise cls(err)
