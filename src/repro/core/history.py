"""Compacted history tier — replayable past for the changelog stream.

The live journal (``Llog``) keeps records only *until read and
acknowledged by all registered readers*; a consumer that arrives late
gets nothing and must fall back to the full-namespace scan that
Robinhood exists to avoid (PAPERS.md).  The history tier closes that
gap the way ``lustre-hsm-action-stream`` keeps a replayable stream
whose state can reconstruct ground truth: instead of unlinking a fully
acknowledged segment, the journal *archives* it here, and the store
coalesces the records per target FID into immutable compacted segments
that still carry the covered journal-index range.

Compaction is state-preserving, not record-preserving:

- **CREATE+UNLINK annihilation** — an object created and destroyed
  inside the covered range never existed as far as final state is
  concerned, so its whole lifetime (creation, setattrs, renames,
  destruction) is dropped.  Hardlinked lifetimes are kept whole (an
  UNLINK may remove only one name).
- **rename-chain folding** — successive renames of one object fold to
  a single rename from the original source to the final target.
- **last-writer-wins thinning** — idempotent full-state operations
  (SETATTR, HEARTBEAT, MARK) keep only the newest record per target.

A replay-bootstrap consumer therefore reconstructs the *same final
state* as a from-the-start live consumer, from far fewer records.

Storage: archiving a sealed on-disk journal segment is an
``os.replace`` (the framing is identical — u32 length + packed record),
so the journal's trim path stays O(1) per segment; compaction runs only
when ``merge_factor`` segments have accumulated (or on an explicit
``compact_now()``), rewriting the tail into one compacted segment via
write-to-tmp + atomic rename.  File names encode the covered range
(``<base>.<first016>.<last016>``); recovery parses segments with the
same torn-tail truncation as ``Llog``, deletes stray ``.tmp`` files
(a crash mid-merge), and drops segments whose range another segment
already covers (a crash between writing a merged segment and deleting
its sources).
"""

from __future__ import annotations

import bisect
import glob as _glob
import os
import struct
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import records as R

_LEN = struct.Struct("<I")

#: operations that begin an object lifetime
CREATES = frozenset({R.CL_CREATE, R.CL_MKDIR, R.CL_MKNOD, R.CL_SOFTLINK})
#: operations that end one
DESTROYS = frozenset({R.CL_UNLINK, R.CL_RMDIR})
#: idempotent full-state operations: only the last per target matters
IDEMPOTENT = frozenset({R.CL_SETATTR, R.CL_HEARTBEAT, R.CL_MARK})


class Compactor:
    """Pure per-FID coalescing of a contiguous run of records.

    ``compact(batch)`` returns a new batch containing the surviving
    records in journal-index order; indices are preserved (the output
    is *sparse* over the covered range).  ``cr_prev`` chains may dangle
    across dropped records — replay consumers rebuild state, they do
    not walk prev pointers.
    """

    def __init__(self):
        self.stats = {"records_in": 0, "records_out": 0, "annihilated": 0,
                      "folded": 0, "thinned": 0}

    def compact(self, batch: R.RecordBatch) -> R.RecordBatch:
        n = len(batch)
        self.stats["records_in"] += n
        if n == 0:
            return batch
        # group rows per target FID with one stable lexsort over the
        # decoded header columns; a change-point scan yields the
        # per-FID segments, and three reduceat sums decide which
        # segments can possibly drop anything — only those run the
        # per-record fold, everything else passes through untouched
        t = batch.types_np()
        seq, oid, ver = batch.tfid_cols()
        order = np.lexsort((np.arange(n), ver, oid, seq))
        sseq, soid, sver = seq[order], oid[order], ver[order]
        starts = np.flatnonzero(np.r_[True, (sseq[1:] != sseq[:-1])
                                      | (soid[1:] != soid[:-1])
                                      | (sver[1:] != sver[:-1])])
        st = t[order]
        destroy = np.isin(st, sorted(DESTROYS)).astype(np.int64)
        rename = (st == R.CL_RENAME).astype(np.int64)
        idem = np.isin(st, sorted(IDEMPOTENT)).astype(np.int64)
        interesting = ((np.add.reduceat(destroy, starts) > 0)
                       | (np.add.reduceat(rename, starts) > 1)
                       | (np.add.reduceat(idem, starts) > 1))
        drop = set()
        replace: Dict[int, bytes] = {}
        if bool(interesting.any()):
            types = t.tolist()
            bounds = np.r_[starts, n]
            for k in np.flatnonzero(interesting).tolist():
                rows = order[bounds[k]:bounds[k + 1]].tolist()
                self._compact_key(batch, types, rows, drop, replace)
        if not drop and not replace:
            self.stats["records_out"] += n
            return batch
        out = [replace.get(i, None) or batch.packed(i)
               for i in range(n) if i not in drop]
        self.stats["records_out"] += len(out)
        return R.RecordBatch.from_packed(out)

    def _compact_key(self, batch: R.RecordBatch, types: List[int],
                     rows: List[int], drop: set,
                     replace: Dict[int, bytes]) -> None:
        # 1) annihilate closed lifetimes: rows from an observed creation
        # to the matching destroy, unless a hardlink shared the object
        cur: List[int] = []
        created = linked = False
        for r in rows:
            t = types[r]
            if t == R.CL_HARDLINK:
                linked = True
            if t in DESTROYS and created and not linked:
                drop.update(cur)
                drop.add(r)
                self.stats["annihilated"] += len(cur) + 1
                cur, created, linked = [], False, False
                continue
            if t in CREATES and not cur:
                created = True
            cur.append(r)
        alive = [r for r in rows if r not in drop]
        # 2) fold rename chains: one rename, original source -> final
        # target, at the last rename's index
        renames = [r for r in alive if types[r] == R.CL_RENAME]
        if len(renames) > 1:
            first = batch.record(renames[0])
            last = batch.record(renames[-1])
            folded = R.ChangelogRecord(
                type=last.type, index=last.index, prev=first.prev,
                time=last.time, tfid=last.tfid, pfid=last.pfid,
                name=last.name, sfid=first.sfid or last.sfid,
                spfid=first.spfid or last.spfid,
                sname=first.sname or last.sname, jobid=last.jobid,
                shard=last.shard, metrics=last.metrics, xattr=last.xattr)
            replace[renames[-1]] = R.pack(folded)
            drop.update(renames[:-1])
            self.stats["folded"] += len(renames) - 1
            alive = [r for r in alive if r not in drop]
        # 3) last-writer-wins for idempotent full-state records
        for t in IDEMPOTENT:
            t_rows = [r for r in alive if types[r] == t]
            if len(t_rows) > 1:
                drop.update(t_rows[:-1])
                self.stats["thinned"] += len(t_rows) - 1


class _HistSegment:
    """Immutable compacted records covering journal range
    [first, last] (inclusive); record indices are sparse within it."""

    __slots__ = ("first", "last", "batch", "indices", "path")

    def __init__(self, first: int, last: int, batch: R.RecordBatch,
                 path: Optional[str] = None):
        self.first = first
        self.last = last
        self.batch = batch
        self.indices = batch.indices()       # ascending journal indices
        self.path = path


class HistoryStore:
    """Archive of trimmed journal segments, compacted per FID.

    ``compactor=None`` disables coalescing (a raw retained history —
    the full-journal-replay baseline the benchmark compares against);
    the default compacts.  Thread-safe: the journal archives under its
    own lock while replay readers fetch concurrently.
    """

    def __init__(self, base_path: Optional[str] = None,
                 compactor: Optional[Compactor] = ...,
                 merge_factor: int = 8):
        self.base_path = base_path
        self.compactor = Compactor() if compactor is ... else compactor
        self.merge_factor = max(2, merge_factor)
        self._segments: List[_HistSegment] = []
        self._lock = threading.Lock()
        self.stats = {"archived_segments": 0, "archived_records": 0,
                      "merges": 0, "torn_dropped": 0, "duplicate_skips": 0,
                      "retention_trims": 0, "retention_dropped": 0}
        if base_path:
            self._load()

    # -- coverage ------------------------------------------------------------
    @property
    def covered_lo(self) -> int:
        """First covered journal index (0 when empty)."""
        with self._lock:
            return self._segments[0].first if self._segments else 0

    @property
    def covered_hi(self) -> int:
        """Last covered journal index (0 when empty)."""
        with self._lock:
            return self._segments[-1].last if self._segments else 0

    @property
    def record_count(self) -> int:
        with self._lock:
            return sum(len(s.batch) for s in self._segments)

    @property
    def segment_count(self) -> int:
        with self._lock:
            return len(self._segments)

    # -- persistence ---------------------------------------------------------
    def _seg_path(self, first: int, last: int) -> str:
        return f"{self.base_path}.{first:016d}.{last:016d}"

    def _parse_file(self, path: str) -> List[bytes]:
        with open(path, "rb") as fh:
            data = fh.read()
        out, off = [], 0
        while off + 4 <= len(data):
            (ln,) = _LEN.unpack_from(data, off)
            if off + 4 + ln > len(data) or ln < R.HDR_SIZE:
                self.stats["torn_dropped"] += 1      # crash mid-write
                break
            out.append(data[off + 4:off + 4 + ln])
            off += 4 + ln
        if 0 < len(data) - off < 4:
            self.stats["torn_dropped"] += 1
        return out

    def _write_file(self, path: str, batch: R.RecordBatch) -> None:
        tmp = path + ".tmp"
        with open(tmp, "wb") as fh:
            for buf in batch:
                fh.write(_LEN.pack(len(buf)))
                fh.write(buf)
            fh.flush()
        os.replace(tmp, path)

    def _load(self) -> None:
        found: List[Tuple[int, int, str]] = []
        for path in _glob.glob(self.base_path + ".*"):
            if path.endswith(".tmp"):                # crash mid-merge
                os.remove(path)
                continue
            parts = path.rsplit(".", 2)
            try:
                first, last = int(parts[-2]), int(parts[-1])
            except (ValueError, IndexError):
                continue
            found.append((first, last, path))
        # widest-first: a merged segment swallows the sources a crash
        # left behind (delete the covered files, keep the cover)
        found.sort(key=lambda t: (t[0], -(t[1])))
        kept: List[Tuple[int, int, str]] = []
        for first, last, path in found:
            if kept and first >= kept[-1][0] and last <= kept[-1][1]:
                os.remove(path)                      # fully covered
                continue
            kept.append((first, last, path))
        for first, last, path in kept:
            batch = R.RecordBatch.from_packed(self._parse_file(path))
            self._segments.append(_HistSegment(first, last, batch, path))

    # -- archiving (the Llog trim hook) --------------------------------------
    def archive(self, batch: R.RecordBatch, first: int, last: int,
                move_from: Optional[str] = None) -> bool:
        """Take ownership of trimmed journal records covering
        ``[first, last]``.  ``move_from`` is the journal's on-disk
        segment file, adopted with one ``os.replace`` (identical
        framing) so the trim path never rewrites payload bytes.
        Idempotent: a range already covered (a crash between archive
        and the journal's unlink) is skipped.  Returns True when the
        records were adopted (the caller must then *not* unlink
        ``move_from``)."""
        with self._lock:
            hi = self._segments[-1].last if self._segments else 0
            if last <= hi:
                self.stats["duplicate_skips"] += 1
                return False
            # freeze a private copy: the caller's buffer may be the
            # journal's live bytearray
            batch = R.RecordBatch.from_packed(list(batch))
            path = None
            if self.base_path:
                path = self._seg_path(first, last)
                if move_from and os.path.exists(move_from):
                    os.replace(move_from, path)
                else:
                    self._write_file(path, batch)
            self._segments.append(_HistSegment(first, last, batch, path))
            self.stats["archived_segments"] += 1
            self.stats["archived_records"] += len(batch)
            if len(self._segments) >= self.merge_factor:
                self._merge_locked()
            return True

    # -- compaction ----------------------------------------------------------
    def _merge_locked(self) -> None:
        segs = self._segments
        if len(segs) < 2 and self.compactor is None:
            return
        union = R.RecordBatch.concat([s.batch for s in segs]) \
            if segs else R.RecordBatch.empty()
        merged = self.compactor.compact(union) if self.compactor else union
        first = segs[0].first if segs else 0
        last = segs[-1].last if segs else 0
        path = None
        if self.base_path:
            path = self._seg_path(first, last)
            self._write_file(path, merged)
            for s in segs:
                if s.path and s.path != path and os.path.exists(s.path):
                    os.remove(s.path)
        self._segments = [_HistSegment(first, last, merged, path)]
        self.stats["merges"] += 1

    def compact_now(self) -> None:
        """Force-compact the whole store into one segment (benchmarks,
        tests, and operators draining before a snapshot)."""
        with self._lock:
            if self._segments:
                self._merge_locked()

    # -- retention -----------------------------------------------------------
    def trim(self, horizon: int) -> int:
        """Retention trim: drop archived records with journal index
        strictly below ``horizon``.  Safe whenever no live cursor can
        replay below ``horizon`` (the stream-janitor's contract): a
        bootstrap from any index >= horizon reads only surviving
        records, so reconstructed state is unchanged.  Segments wholly
        below the horizon are unlinked; the boundary segment is
        rewritten per record (write-to-tmp + atomic rename under a new
        range filename, crash-safe like a merge).  Returns the number
        of records dropped."""
        with self._lock:
            if not self._segments or horizon <= self._segments[0].first:
                return 0
            dropped = 0
            kept: List[_HistSegment] = []
            for seg in self._segments:
                if seg.last < horizon:
                    dropped += len(seg.batch)
                    if seg.path and os.path.exists(seg.path):
                        os.remove(seg.path)
                    continue
                if seg.first >= horizon:
                    kept.append(seg)
                    continue
                lo = bisect.bisect_left(seg.indices, horizon)
                if lo == 0:
                    # the range label dips below the horizon but every
                    # record survives (annihilated gap): keep as is
                    kept.append(seg)
                    continue
                batch = R.RecordBatch.from_packed(list(seg.batch[lo:]))
                path = None
                if self.base_path:
                    path = self._seg_path(horizon, seg.last)
                    self._write_file(path, batch)
                    if seg.path and seg.path != path \
                            and os.path.exists(seg.path):
                        os.remove(seg.path)
                kept.append(_HistSegment(horizon, seg.last, batch, path))
                dropped += lo
            self._segments = kept
            if dropped:
                self.stats["retention_trims"] += 1
                self.stats["retention_dropped"] += dropped
            return dropped

    # -- reading -------------------------------------------------------------
    def read(self, start: int, max_records: int = 1024,
             ) -> Tuple[R.RecordBatch, int]:
        """Records with journal index >= ``start``, at most
        ``max_records``; returns ``(batch, next_start)`` where
        ``next_start`` is the first index this read did *not* cover —
        annihilated gaps advance it without producing records."""
        with self._lock:
            views: List[R.RecordBatch] = []
            next_start = start
            want = max_records
            for seg in self._segments:
                if seg.last < start:
                    continue
                if want <= 0:
                    break
                lo = bisect.bisect_left(seg.indices, start)
                take = min(want, len(seg.indices) - lo)
                if take > 0:
                    views.append(seg.batch[lo:lo + take])
                    want -= take
                    next_start = seg.indices[lo + take - 1] + 1
                if lo + take == len(seg.indices) and want > 0:
                    # whole tail consumed: the trailing annihilated gap
                    # (if any) is covered too
                    next_start = max(next_start, seg.last + 1)
            if not views:
                return R.RecordBatch.empty(), max(next_start, start)
            if len(views) == 1:
                return views[0], next_start
            return R.RecordBatch.concat(views), next_start

    def close(self) -> None:
        pass                                   # all writes are atomic


class JournalReplayReader:
    """Replay source over one journal: compacted history first, then
    the journal's physically retained records (``read_raw`` — records
    logically trimmed but not yet archived stay readable, so the union
    is gapless).  ``read`` returns ``(batch, next_start)``."""

    def __init__(self, log):
        self.log = log

    @property
    def floor_is_retention(self) -> bool:
        """True when a raised ``available_lo`` reflects a retention
        trim of an attached history tier (``StreamJanitor``) — a
        policy decision ``replay=True`` should clamp to — rather than
        a journal with no history at all, where a trimmed head means
        the records are simply gone and replay must be refused."""
        return getattr(self.log, "history", None) is not None

    def available_lo(self) -> int:
        hist = getattr(self.log, "history", None)
        if hist is not None and hist.segment_count:
            return hist.covered_lo
        return self.log.first_index

    def read(self, start: int, max_records: int = 1024,
             ) -> Tuple[R.RecordBatch, int]:
        hist = getattr(self.log, "history", None)
        if hist is not None and start <= hist.covered_hi:
            return hist.read(start, max_records)
        batch = self.log.read_raw(start, max_records)
        # a concurrent trim may have archived past ``start`` between
        # the coverage check and the raw read; archive-before-drop
        # makes the store authoritative the moment coverage reaches it
        if hist is not None and start <= hist.covered_hi:
            return hist.read(start, max_records)
        if not batch:
            return batch, max(start, self.log.last_index + 1)
        return batch, batch.packed_index(len(batch) - 1) + 1


class StreamJanitor:
    """Retention-SLO sweeper: bound how much history the tier keeps.

    Archiving is append-only — without a janitor the history store
    grows forever.  Each :meth:`sweep` asks its target (an
    ``LcapProxy`` or ``LcapCluster`` — anything with
    ``retention_horizons()``) for the **oldest still-live cursor** per
    journal: the collective ack frontier across consumer groups, the
    rewind point of any unfinished replay bootstrap (active consumers
    *and* parked durables), and an in-flight migration's handoff
    watermark.  Nothing below that cursor can ever be read again, so
    the janitor trims each journal's ``HistoryStore`` behind it —
    except for the last ``floor`` journal indices, the configurable
    retention SLO that keeps a bootstrap window available for
    late-arriving replay subscribers (``replay=True`` clamps to the
    trimmed ``available_lo``).
    """

    def __init__(self, target, floor: int = 4096):
        self.target = target
        self.floor = max(0, int(floor))
        self.stats = {"sweeps": 0, "records_dropped": 0}

    def _journals(self) -> Dict[str, object]:
        journals = getattr(self.target, "journals", None)
        if journals is not None:
            return dict(journals)
        return {pid: src
                for pid, src in getattr(self.target, "producers", {}).items()
                if getattr(src, "history", None) is not None}

    def sweep(self) -> Dict[str, Dict[str, int]]:
        """One retention pass; returns per journal the horizon applied
        and the records dropped."""
        horizons = self.target.retention_horizons()
        report: Dict[str, Dict[str, int]] = {}
        for pid, log in self._journals().items():
            hist = getattr(log, "history", None)
            if hist is None:
                continue
            horizon = min(horizons.get(pid, 0),
                          log.last_index - self.floor + 1)
            dropped = hist.trim(horizon) if horizon > 0 else 0
            self.stats["records_dropped"] += dropped
            report[pid] = {"horizon": horizon, "dropped": dropped}
        self.stats["sweeps"] += 1
        return report
