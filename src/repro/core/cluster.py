"""Sharded LCAP cluster — horizontal fan-out of the changelog proxy.

The paper's headline claim is *distributed* changelog processing; a
single ``LcapProxy`` serializes every producer through one dispatch
loop and one ingest buffer.  ``LcapCluster`` puts N independent proxy
shards behind one coordinator:

- **FID-hash routing**: every record is routed to a shard by a stable
  hash of its target FID (``fid_slot``), so the ``cr_prev`` chain of
  one target always lands on the same shard and per-target ordering is
  preserved.  The hash maps FIDs onto a fixed ring of *slots*; slots
  map onto shards, which is what makes failover re-routing a slot
  reassignment instead of a re-hash.
- **producers registered once**: the coordinator is the only registered
  changelog reader per journal (resume-aware, like the proxy itself);
  shards see push-fed ``PushSource`` producers and receive their record
  subsets via ``LcapProxy.offer``.  A shard that owns none of a read
  range still receives the watermark advance, so it never holds the
  collective ack back.
- **collective upstream ack**: each shard's per-journal watermark (the
  ``PushSource.acked`` its own collective ack writes) is collected by
  the coordinator; the minimum across live shards acknowledges the real
  journal, which trims exactly as with a single proxy.
- **epoch-versioned routing**: slot ownership lives in an immutable
  ``RoutingTable`` snapshot (routing.py).  Every topology change —
  migration drain/commit/cancel, shard add, failover — derives a new
  table at ``epoch + 1``; within one epoch the owner of a slot never
  changes, and the bump is published (piggybacked on offer/fetch/caps
  replies) before any record is offered under the new assignment, so
  consumers re-resolve their shard fan-in instead of assuming a fixed
  shard set.
- **one migration invariant, two speeds**: planned rebalancing
  (``migrate_slots`` / ``add_shard`` / ``split_shard``) and failover
  (``kill_shard``) share the same contract — *records above a
  per-producer handoff watermark whose slots moved are (re)offered to
  the new owners at the next epoch*.  A **graceful** migration marks
  slots draining, parks newly read records for them in a bounded
  buffer, waits until every source shard's watermark reaches the
  handoff (its in-flight share fully consumed and acknowledged), then
  commits and hands the parked journal tail to the new owner — zero
  loss *and* zero duplication.  A **forced** migration (shard death)
  cannot wait: the handoff collapses to the dead shard's own last
  watermark and the unacknowledged backlog ``(acked, cursor]`` is
  re-read from the journals for the new owners — zero loss,
  at-least-once (the journal never trimmed past the dead shard's own
  watermark).  (Records re-offered to survivors are covered by shard
  memory, not the journal, until consumed: a *second* failure inside
  that window can lose them — the documented cascading-failure caveat.)

Shards are either in-process (``LocalShard`` over ``LcapProxy``) or
independent daemons (``RemoteShard`` over the wire verbs ``add_source``
/ ``offer`` / ``watermarks``; see ``run_shard_daemon``).  Consumers
never talk to the coordinator: ``session.connect(cluster)`` (or a list
of shard addresses) fans a ``Subscription`` in from every shard — one
logical stream, per-(shard, producer) cursors, commits routed back to
the owning shard (session.py, ``FanInStream``).
"""

from __future__ import annotations

import itertools
import os
import threading
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import records as R
from .errors import ClusterError
from .history import JournalReplayReader
from .llog import Llog
from .proxy import LcapProxy, PushSource
from .routing import RoutingTable
from .transport import RpcClient

DEFAULT_SLOTS = 64

_MIX = 0x9E3779B97F4A7C15          # splitmix64 increment (golden ratio)
_MASK = (1 << 64) - 1


def fid_slot(key: Tuple[int, int, int], n_slots: int = DEFAULT_SLOTS) -> int:
    """Stable slot of a target FID ``(seq, oid, ver)``.

    A splitmix64-style integer mix — deterministic across processes and
    runs (unlike ``hash()``), cheap, and uniform even for the dense
    small integers FIDs are made of.
    """
    z = (key[0] * 0xBF58476D1CE4E5B9 ^ key[1] * 0x94D049BB133111EB
         ^ key[2] * _MIX) & _MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    return (z ^ (z >> 31)) % n_slots


def fid_slots(seq: np.ndarray, oid: np.ndarray, ver: np.ndarray,
              n_slots: int = DEFAULT_SLOTS) -> np.ndarray:
    """Vectorized ``fid_slot`` over FID columns (``batch.tfid_cols``):
    the identical splitmix64 mix, computed with wrapping uint64
    arithmetic across a whole batch at once."""
    with np.errstate(over="ignore"):
        z = (seq.astype(np.uint64) * np.uint64(0xBF58476D1CE4E5B9)
             ^ oid.astype(np.uint64) * np.uint64(0x94D049BB133111EB)
             ^ ver.astype(np.uint64) * np.uint64(_MIX))
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return ((z ^ (z >> np.uint64(31)))
                % np.uint64(n_slots)).astype(np.int64)


#: memoized result of the REPRO_JAX_ROUTING probe — resolved once at
#: first ``batch_slots`` call (the probe re-read os.environ and
#: re-attempted the stream_ops import per call, on the routing hot
#: path); ``_reset_jax_probe`` re-arms it for tests
_JAX_UNRESOLVED = object()
_jax_kernel = _JAX_UNRESOLVED


def _resolve_jax_fid_slots():
    """The accelerator twin of ``fid_slots`` when the deployment opts
    in (``REPRO_JAX_ROUTING=1``) and jax imports; None otherwise.  The
    numpy path stays the default: on a CPU-only coordinator the jit
    round-trip costs more than the mix."""
    if os.environ.get("REPRO_JAX_ROUTING") != "1":
        return None
    try:
        from ..kernels import stream_ops
    except Exception:
        return None
    return stream_ops.fid_slots


def _jax_fid_slots():
    global _jax_kernel
    if _jax_kernel is _JAX_UNRESOLVED:
        _jax_kernel = _resolve_jax_fid_slots()
    return _jax_kernel


def _reset_jax_probe() -> None:
    """Forget the memoized probe result (test hook: lets a test flip
    ``REPRO_JAX_ROUTING`` and have the next ``batch_slots`` re-probe)."""
    global _jax_kernel
    _jax_kernel = _JAX_UNRESOLVED


def batch_slots(batch: "R.RecordBatch",
                n_slots: int = DEFAULT_SLOTS) -> np.ndarray:
    """Slot of every record's target FID, straight off the batch's
    decoded header columns."""
    seq, oid, ver = batch.tfid_cols()
    kernel = _jax_fid_slots()
    if kernel is not None and n_slots < (1 << 16):
        return kernel(seq, oid, ver, n_slots)
    return fid_slots(seq, oid, ver, n_slots)


class ClusterReplayReader:
    """Shard-filtered replay source over a cluster journal's history
    tier: reads the journal's compacted history + retained records
    (``JournalReplayReader``) and keeps only the rows whose target FID
    currently routes to this shard, so a replay-bootstrap subscription
    fanned in from every shard covers the stream exactly once.  Slot
    ownership is read at call time: a consumer bootstrapping *after* a
    failover sees the dead shard's history from the slots' new owners,
    and a bootstrap *interrupted* by a failover is rewound to its start
    on the survivors (``kill_shard`` → ``rewind_active_replays``) so
    re-routed slots are not skipped — redelivery, not loss.  The
    residual window mirrors the live path's cascading-failure caveat:
    a shard whose bootstrap already finished cannot be rewound (the
    client stopped polling ``fetch_replay``), so a failover in that
    window loses the dead shard's *unreplayed* share for that consumer.
    """

    def __init__(self, cluster: "LcapCluster", pid: str, shard_index: int):
        self.cluster = cluster
        self.pid = pid
        self.shard_index = shard_index
        self._reader = JournalReplayReader(cluster.journals[pid])

    def available_lo(self) -> int:
        return self._reader.available_lo()

    @property
    def floor_is_retention(self) -> bool:
        return self._reader.floor_is_retention

    def read(self, start: int, max_records: int = 1024):
        batch, nxt = self._reader.read(start, max_records)
        if len(batch):
            owner = self.cluster.routing.owner_array()
            mine = owner[batch_slots(batch, self.cluster.n_slots)] \
                == self.shard_index
            if not bool(mine.all()):
                batch = batch.select(np.flatnonzero(mine))
        return batch, nxt


# ---------------------------------------------------------------------------
# Shard handles: one protocol, two deployments.
# ---------------------------------------------------------------------------
class LocalShard:
    """An in-process shard: direct method calls into an ``LcapProxy``."""

    #: in-process watermarks are a dict copy — never worth skipping
    remote = False

    def __init__(self, proxy: LcapProxy, index: int = 0):
        self.proxy = proxy
        self.index = index

    def add_source(self, pid: str, first: int = 1) -> None:
        self.proxy.add_source(pid, first)

    def set_replay_reader(self, pid: str, reader) -> None:
        src = self.proxy.producers.get(pid)
        if isinstance(src, PushSource):
            src.history_reader = reader

    def rewind_replays(self) -> None:
        self.proxy.rewind_active_replays()

    def offer_many(self, offers: Sequence[Tuple[str, R.RecordBatch, int]],
                   ) -> Dict[str, int]:
        self.proxy.offer_many(offers)
        return self.watermarks()

    # in-process: "send" applies immediately, "recv" reports the result
    def offer_send(self, offers: Sequence[Tuple[str, R.RecordBatch, int]],
                   ) -> None:
        self._pending = self.offer_many(offers)

    def offer_recv(self) -> Dict[str, int]:
        pending, self._pending = getattr(self, "_pending", {}), {}
        return pending

    def watermarks(self) -> Dict[str, int]:
        return dict(self.proxy.upstream_acked)

    def metrics(self) -> Dict[str, dict]:
        return self.proxy.metrics_snapshot()

    def lag(self) -> Dict[str, Dict[str, Dict[str, int]]]:
        return self.proxy.lag()

    def pump(self) -> int:
        moved = self.proxy.pump()
        self.proxy.flush_upstream()
        return moved

    def backend(self):
        from .session import _LocalBackend
        return _LocalBackend(self.proxy)

    def close(self) -> None:
        pass


class RemoteShard:
    """A shard running as its own daemon, driven over the wire verbs.

    Offers are *deep-batched*: a whole routing round travels as one
    ``offer_many`` call carrying v2 (column-bearing) frames, and the
    reply piggybacks the shard's per-journal watermarks — no separate
    watermark round-trip while traffic flows.  An old daemon (no
    ``caps`` verb) falls back to the legacy pipelined per-batch offers
    with v1 frames.
    """

    #: offer replies piggyback watermarks — skip the separate poll
    remote = True

    def __init__(self, address, index: int = 0):
        self.address = address
        self.index = index
        self.rpc = RpcClient(tuple(address))
        self._watermarks: Dict[str, int] = {}
        self._caps: Optional[Dict] = None

    def caps(self) -> Dict:
        """Peer capabilities, probed once per connection: record-frame
        generation (``"wire"``) and deep-batched offer support
        (``"deep"``).  An old daemon answers the ``caps`` verb with an
        unknown-op error reply — treated as a v1, shallow peer."""
        c = self._caps
        if c is None:
            reply = self.rpc.call({"op": "caps"})
            if reply.get("err"):
                c = {"wire": R.WIRE_V1, "deep": False}
            else:
                c = {"wire": min(int(reply.get("wire", R.WIRE_V1)),
                                 R.WIRE_V2),
                     "deep": bool(reply.get("deep"))}
            self._caps = c
        return c

    def add_source(self, pid: str, first: int = 1) -> None:
        self._call({"op": "add_source", "pid": pid, "first": first})

    def set_replay_reader(self, pid: str, reader) -> None:
        # a detached daemon cannot call back into the coordinator's
        # journals; replay-bootstrap subscriptions are served by
        # in-process shards (LcapCluster / LcapClusterService)
        pass

    def rewind_replays(self) -> None:
        pass                              # no replay support (see above)

    def offer_many(self, offers: Sequence[Tuple[str, R.RecordBatch, int]],
                   ) -> Dict[str, int]:
        self.offer_send(offers)
        return self.offer_recv()

    def offer_send(self, offers: Sequence[Tuple[str, R.RecordBatch, int]],
                   ) -> None:
        """Fire this shard's burst without waiting, so every shard of
        the cluster ingests its share of a routing round concurrently;
        ``offer_recv`` drains the replies.  A deep-capable peer gets
        the whole round as one ``offer_many`` call (header columns ride
        the v2 frames); an old peer gets pipelined per-batch offers."""
        caps = self.caps()
        if caps["deep"]:
            wire = caps["wire"]
            self.rpc.send_request(
                {"op": "offer_many",
                 "offers": [(pid, batch.to_wire(wire), hi)
                            for pid, batch, hi in offers]})
            self._inflight = 1
            return
        self._inflight = 0
        for pid, batch, hi in offers:
            self.rpc.send_request({"op": "offer", "pid": pid,
                                   "blob": batch.to_wire(), "hi": hi})
            self._inflight += 1

    def offer_recv(self) -> Dict[str, int]:
        n, self._inflight = getattr(self, "_inflight", 0), 0
        for _ in range(n):
            reply = self.rpc.recv_reply()
            if reply.get("err"):
                raise ClusterError(reply["err"])
            self._watermarks.update(reply.get("watermarks") or {})
        return dict(self._watermarks)

    def watermarks(self) -> Dict[str, int]:
        reply = self._call({"op": "watermarks"})
        self._watermarks.update(reply.get("watermarks") or {})
        return dict(self._watermarks)

    def metrics(self) -> Dict[str, dict]:
        return self._call({"op": "metrics"}).get("metrics") or {}

    def lag(self) -> Dict[str, Dict[str, Dict[str, int]]]:
        return self._call({"op": "lag"}).get("lag") or {}

    def pump(self) -> int:
        return 0                          # the daemon's poller dispatches

    def _call(self, msg):
        reply = self.rpc.call(msg)
        if reply.get("err"):
            raise ClusterError(reply["err"])
        return reply

    def backend(self):
        from .session import _WireBackend
        return _WireBackend(tuple(self.address))

    def close(self) -> None:
        self.rpc.close()


class _Migration:
    """The one in-flight graceful migration: which slots are draining,
    where they are going, which shards must drain, and the per-producer
    handoff watermark recorded when the drain began (the highest
    journal index routed so far — exactly the replay-bootstrap handoff
    convention of ``LcapProxy._arm_replay_locked``)."""

    __slots__ = ("slots", "target", "sources", "handoff")

    def __init__(self, slots, target, sources, handoff):
        self.slots = frozenset(slots)
        self.target = int(target)
        self.sources = frozenset(sources)
        self.handoff: Dict[str, int] = dict(handoff)


class LcapCluster:
    """N proxy shards behind one coordinator; see the module docstring.

    ``producers`` are registered once, with the coordinator.  Shards
    are built in-process (``n_shards``) unless explicit handles are
    passed (``shards=[RemoteShard(addr), ...]`` for daemons).
    """

    def __init__(self, producers: Dict[str, Llog], n_shards: int = 2,
                 shards: Optional[Sequence] = None,
                 n_slots: int = DEFAULT_SLOTS, batch_size: int = 1024,
                 modules=None, park_cap: int = 1 << 16, **proxy_kwargs):
        self._modules = list(modules or [])
        self._proxy_defaults = dict(proxy_kwargs)
        if shards is None:
            shards = [LocalShard(LcapProxy({}, modules=list(self._modules),
                                           batch_size=batch_size,
                                           **proxy_kwargs), index=i)
                      for i in range(n_shards)]
        if not shards:
            raise ClusterError("a cluster needs at least one shard")
        self.shards = list(shards)
        for i, shard in enumerate(self.shards):
            shard.index = i
        self.n_slots = n_slots
        self.batch_size = batch_size
        #: the current ownership snapshot; replaced (never mutated) on
        #: every topology change — see routing.RoutingTable
        self.routing = RoutingTable.initial(n_slots, len(self.shards))
        self.alive: List[bool] = [True] * len(self.shards)
        self.journals: Dict[str, Llog] = {}
        self.reader_ids: Dict[str, str] = {}
        self.cursors: Dict[str, int] = {}       # next journal index to route
        self.journal_acked: Dict[str, int] = {}
        #: shard index -> (pid -> last known shard watermark)
        self.shard_acked: List[Dict[str, int]] = [dict() for _ in self.shards]
        self._lock = threading.RLock()
        #: the one in-flight graceful migration (None when settled)
        self._migration: Optional[_Migration] = None
        #: records read for draining slots, held until the commit hands
        #: them to the new owner: (pid, batch, hi) in journal order
        self._parked: List[Tuple[str, R.RecordBatch, int]] = []
        self._parked_count = 0
        #: parking-buffer bound: when reached, the routing loop stops
        #: reading journals (backpressure) until the migration settles
        self.park_cap = park_cap
        self.stats = {"routed": 0, "routing_rounds": 0, "shards_failed": 0,
                      "failover_redelivered": 0, "journal_acks": 0,
                      "epoch_bumps": 0, "migrations_started": 0,
                      "migrations_completed": 0, "migrations_cancelled": 0,
                      "slots_migrated": 0, "parked_records": 0,
                      "shards_added": 0}
        for pid, log in producers.items():
            self.add_producer(pid, log)

    # ------------------------------------------------------------ topology
    @property
    def slot_owner(self) -> List[int]:
        """Read-only view of the current table's ownership; topology
        changes go through the routing operations (migrate/add/kill)."""
        return list(self.routing.slot_owner)

    @property
    def epoch(self) -> int:
        """The routing table's current epoch."""
        return self.routing.epoch

    def shard_of(self, key: Tuple[int, int, int]) -> int:
        """The shard currently owning target FID ``key``."""
        return self.routing.slot_owner[fid_slot(key, self.n_slots)]

    @property
    def live_shards(self) -> List:
        return [s for i, s in enumerate(self.shards) if self.alive[i]]

    # ------------------------------------------------------------ producers
    def add_producer(self, pid: str, log: Llog) -> None:
        """Register journal ``pid`` once, with the coordinator; every
        shard gains a push-fed source for it.  Like the single proxy
        (``Llog.attach_reader``), a fresh coordinator owes acks for the
        journal's whole live backlog, and a restarted one resumes at
        its own acked watermark, not at a trim point another reader may
        be holding back."""
        with self._lock:
            rid, start = log.attach_reader(f"lcap-{pid}")
            self.journals[pid] = log
            self.reader_ids[pid] = rid
            self.cursors[pid] = start
            self.journal_acked[pid] = start - 1
            for i, shard in enumerate(self.shards):
                if self.alive[i]:
                    self._shard_call(i, shard.add_source, pid, start)
                    self._shard_call(i, shard.set_replay_reader, pid,
                                     ClusterReplayReader(self, pid, i))
                self.shard_acked[i].setdefault(pid, start - 1)
            if self._migration is not None:
                # nothing of this journal was routed before the drain
                self._migration.handoff.setdefault(pid, start - 1)

    # -------------------------------------------------------------- routing
    def _partition(self, batch: R.RecordBatch) -> List[np.ndarray]:
        """Row indices per shard, in batch (= journal) order."""
        owner = self.routing.owner_array()[batch_slots(batch, self.n_slots)]
        return [np.flatnonzero(owner == i) for i in range(len(self.shards))]

    def _route(self) -> Tuple[int, List[int]]:
        """One routing round: read every journal forward, partition by
        FID slot, push one deep-batched offer burst per shard —
        including empty ones, which carry the watermark advance.
        Rows whose slot is draining (mid-migration) are parked instead
        of offered; when the parking buffer is full the round stops
        reading (backpressure) until the migration settles.
        Returns ``(records routed, remote shards whose offer replies
        already piggybacked their watermarks this round)``."""
        n = 0
        offers: List[List[Tuple[str, R.RecordBatch, int]]] = \
            [[] for _ in self.shards]
        owner_arr = self.routing.owner_array()
        drain = (self.routing.draining_mask()
                 if self._migration is not None else None)
        for pid, log in self.journals.items():
            while True:
                if drain is not None and self._parked_count >= self.park_cap:
                    break
                batch = log.read(self.cursors[pid], self.batch_size)
                if not batch:
                    break
                got = len(batch)
                hi = batch.packed_index(got - 1)
                self.cursors[pid] = hi + 1
                slots = batch_slots(batch, self.n_slots)
                if drain is not None and bool(drain[slots].any()):
                    dmask = drain[slots]
                    parked_rows = np.flatnonzero(dmask)
                    self._parked.append((pid, batch.select(parked_rows), hi))
                    self._parked_count += int(parked_rows.size)
                    self.stats["parked_records"] += int(parked_rows.size)
                    keep = np.flatnonzero(~dmask)
                    owner = owner_arr[slots[keep]]
                    rows = [keep[owner == i]
                            for i in range(len(self.shards))]
                else:
                    owner = owner_arr[slots]
                    rows = [np.flatnonzero(owner == i)
                            for i in range(len(self.shards))]
                for i, shard_rows in enumerate(rows):
                    if self.alive[i]:
                        offers[i].append((pid, batch.select(shard_rows), hi))
                n += got
                if got < self.batch_size:
                    break
        # two-phase: fire every shard's burst first, then drain the
        # replies — the shards ingest their shares concurrently instead
        # of the coordinator serializing on one shard at a time
        sent = []
        for i, shard_offers in enumerate(offers):
            if shard_offers and self.alive[i]:
                self._shard_call(i, self.shards[i].offer_send, shard_offers)
                if self.alive[i]:          # send did not fail the shard
                    sent.append(i)
        covered = []
        for i in sent:
            if self.alive[i]:
                wm = self._shard_call(i, self.shards[i].offer_recv)
                if wm is not None:
                    self.shard_acked[i].update(wm)
                    if getattr(self.shards[i], "remote", False):
                        covered.append(i)
        self.stats["routed"] += n
        self.stats["routing_rounds"] += 1
        return n, covered

    def _shard_call(self, i: int, fn, *args):
        """Invoke a shard operation; a dead connection — or a shard
        that rejects the verb (``ClusterError`` from an error reply) —
        fails the shard over (slots re-routed, backlog redelivered)
        instead of killing the coordinator's routing loop."""
        try:
            return fn(*args)
        except (ConnectionError, OSError, ClusterError) as exc:
            self.kill_shard(i, reason=str(exc))
            return None

    def pump(self, pump_shards: bool = True) -> int:
        """One routing round; with ``pump_shards`` (in-process shards)
        also one dispatch cycle per shard, then collective-ack
        propagation."""
        with self._lock:
            moved, covered = self._route()
            if pump_shards:
                for i, shard in enumerate(self.shards):
                    if self.alive[i]:
                        got = self._shard_call(i, shard.pump)
                        moved += got or 0
                self._collect_watermarks(skip=covered)
            self._advance_migration_locked()
            self._ack_journals()
            return moved

    # ------------------------------------------------ elastic operations
    def migrate_slots(self, slots: Sequence[int], target: int) -> int:
        """Begin a live migration of ``slots`` to shard ``target``.

        The slots are marked draining at ``epoch + 1``: their current
        owners keep dispatching what they already ingested, while the
        routing loop parks newly read records for them.  The migration
        commits (on a later ``pump``/``collect_watermarks``) once every
        source shard's per-journal watermark reaches the handoff
        recorded here — i.e. its in-flight share of the drained slots
        is fully consumed and acknowledged — at which point ownership
        flips at ``epoch + 2`` and the parked journal tail is offered
        to the new owner.  No record is lost or delivered twice.

        Returns the number of slots actually draining (slots already
        owned by ``target`` are skipped).  One migration may be in
        flight at a time."""
        with self._lock:
            if self._migration is not None:
                raise ClusterError("a migration is already in flight")
            if not (0 <= target < len(self.shards)) or not self.alive[target]:
                raise ClusterError(f"migration target {target} is not a "
                                   "live shard")
            owner = self.routing.slot_owner
            move = sorted({int(s) for s in slots})
            if any(s < 0 or s >= self.n_slots for s in move):
                raise ClusterError("slot out of range")
            move = [s for s in move if owner[s] != target]
            if not move:
                return 0
            sources = {owner[s] for s in move}
            self.routing = self.routing.drain(move, target)
            self.stats["epoch_bumps"] += 1
            self.stats["migrations_started"] += 1
            self._migration = _Migration(
                slots=move, target=target, sources=sources,
                handoff={pid: self.cursors[pid] - 1
                         for pid in self.journals})
            # nothing in flight on the sources → commits immediately
            self._advance_migration_locked()
            return len(move)

    def _advance_migration_locked(self) -> None:
        """Commit the in-flight migration once every source shard's
        watermark shows its share of the drained slots consumed and
        acknowledged up to the handoff."""
        m = self._migration
        if m is None:
            return
        for src in m.sources:
            if not self.alive[src]:
                return                    # kill_shard cancels/absorbs it
            acked = self.shard_acked[src]
            for pid, h in m.handoff.items():
                if acked.get(pid, -1) < h:
                    return
        self._migration = None
        self.routing = self.routing.commit_drain()
        self.stats["epoch_bumps"] += 1
        self.stats["migrations_completed"] += 1
        self.stats["slots_migrated"] += len(m.slots)
        parked, self._parked, self._parked_count = self._parked, [], 0
        if self.alive[m.target]:
            if parked:
                wm = self._shard_call(m.target,
                                      self.shards[m.target].offer_many,
                                      parked)
                if wm is not None:
                    self.shard_acked[m.target].update(wm)
            # an interrupted replay bootstrap on the target has already
            # scanned (and filtered out) indices whose slots just moved
            # here; rewind it so they are revisited at the new epoch
            self._shard_call(m.target, self.shards[m.target].rewind_replays)

    def add_shard(self, shard=None, **proxy_kwargs) -> int:
        """Spin up shard N+1 while traffic flows: a fresh in-process
        shard (or an explicit handle) joins with zero slots and owes
        nothing routed before it joined — its push sources start at the
        current cursors, so it never holds the collective ack back.
        The epoch bumps so live consumers discover the wider shard set;
        records land on it once slots are migrated over
        (``migrate_slots`` / ``split_shard``)."""
        with self._lock:
            i = len(self.shards)
            if shard is None:
                kw = dict(self._proxy_defaults)
                kw.update(proxy_kwargs)
                shard = LocalShard(LcapProxy({}, modules=list(self._modules),
                                             batch_size=self.batch_size,
                                             **kw), index=i)
            shard.index = i
            self.shards.append(shard)
            self.alive.append(True)
            self.shard_acked.append({})
            self.stats["shards_added"] += 1
            for pid in self.journals:
                first = self.cursors[pid]
                self._shard_call(i, shard.add_source, pid, first)
                self._shard_call(i, shard.set_replay_reader, pid,
                                 ClusterReplayReader(self, pid, i))
                self.shard_acked[i][pid] = first - 1
            obs = getattr(self, "_obs", None)
            proxy = getattr(shard, "proxy", None)
            if obs is not None and proxy is not None:
                proxy.attach_registry(obs, {"shard": str(i)})
            if proxy is not None:
                # replicate group registrations: records routed to the
                # new shard park in each group's pending backlog until
                # that group's fan-in stream discovers the shard (epoch
                # bump) and subscribes — no window where the new shard
                # consumes-and-acks what a group never saw
                for other in self.shards[:i]:
                    peer = getattr(other, "proxy", None)
                    if peer is None:
                        continue
                    for gname in list(peer.groups):
                        proxy.ensure_group(gname)
            self.routing = self.routing.bumped()
            self.stats["epoch_bumps"] += 1
            return i

    def split_shard(self, source: Optional[int] = None,
                    **proxy_kwargs) -> int:
        """Shard split under load: add shard N+1 and migrate half of
        ``source``'s slot range (the most-loaded live shard when
        unspecified) to it while producers keep offering.  Returns the
        new shard's index; the migration commits asynchronously."""
        with self._lock:
            if self._migration is not None:
                raise ClusterError("a migration is already in flight")
            if source is None:
                counts = self.routing.counts(len(self.shards))
                live = [i for i in range(len(self.shards)) if self.alive[i]]
                source = max(live, key=lambda i: counts[i])
            elif not (0 <= source < len(self.shards)
                      and self.alive[source]):
                raise ClusterError(f"split source {source} is not a "
                                   "live shard")
            new = self.add_shard(**proxy_kwargs)
            mine = self.routing.slots_of(source)
            if mine:
                self.migrate_slots(mine[:(len(mine) + 1) // 2], new)
            return new

    def _redeliver_locked(self, moved: Sequence[int],
                          handoff: Dict[str, int]) -> int:
        """The shared migration invariant, forced flavor: re-read every
        journal above the per-producer handoff watermark and re-offer
        the rows whose slots are in ``moved`` to their current owners.
        Returns the number of records redelivered."""
        redelivered = 0
        owner_arr = self.routing.owner_array()
        moved_mask = np.zeros(self.n_slots, dtype=bool)
        moved_mask[list(moved)] = True
        for pid, log in self.journals.items():
            lo = max(log.first_index, handoff.get(pid, 0) + 1)
            end = self.cursors[pid]          # routed so far
            offers: List[List[Tuple[str, R.RecordBatch, int]]] = \
                [[] for _ in self.shards]
            while lo < end:
                batch = log.read(lo, self.batch_size)
                if not batch:
                    break
                slots = batch_slots(batch, self.n_slots)
                idx = batch.indices_np().astype(np.int64)
                keep = np.flatnonzero((idx < end) & moved_mask[slots])
                hi = int(idx[-1])
                if keep.size:
                    owner = owner_arr[slots[keep]]
                    for o in np.unique(owner).tolist():
                        rows = keep[owner == o]
                        offers[o].append((pid, batch.select(rows),
                                          int(idx[rows[-1]])))
                    redelivered += int(keep.size)
                lo = hi + 1
            for i, shard_offers in enumerate(offers):
                if shard_offers and self.alive[i]:
                    self._shard_call(i, self.shards[i].offer_many,
                                     shard_offers)
        return redelivered

    def _reoffer_parked_locked(self, parked, moved_mask: np.ndarray,
                               drop_above: Dict[str, int]) -> None:
        """Hand a cancelled migration's parked records back to their
        current owners.  Rows in ``moved_mask`` slots above the dead
        shard's watermark (``drop_above``) are dropped — the forced
        journal re-read already redelivers them — so a cancel does not
        double-offer what both paths cover."""
        owner_arr = self.routing.owner_array()
        offers: List[List[Tuple[str, R.RecordBatch, int]]] = \
            [[] for _ in self.shards]
        for pid, batch, hi in parked:
            slots = batch_slots(batch, self.n_slots)
            idx = batch.indices_np().astype(np.int64)
            cut = drop_above.get(pid, -1)
            keep = np.flatnonzero(~(moved_mask[slots] & (idx > cut)))
            if not keep.size:
                continue
            owner = owner_arr[slots[keep]]
            for o in np.unique(owner).tolist():
                rows = keep[owner == o]
                offers[o].append((pid, batch.select(rows), hi))
        for i, shard_offers in enumerate(offers):
            if shard_offers and self.alive[i]:
                wm = self._shard_call(i, self.shards[i].offer_many,
                                      shard_offers)
                if wm is not None:
                    self.shard_acked[i].update(wm)

    # ------------------------------------------------------------- acks
    def _collect_watermarks(self, skip: Sequence[int] = ()) -> None:
        """Poll live shards for their per-journal watermarks; remote
        shards whose offer replies already piggybacked them this round
        (``skip``) are not re-polled — the offer path replaced the
        separate watermark round-trip."""
        for i, shard in enumerate(self.shards):
            if self.alive[i] and i not in skip:
                wm = self._shard_call(i, shard.watermarks)
                if wm is not None:
                    self.shard_acked[i].update(wm)

    def collect_watermarks(self) -> None:
        """Refresh every live shard's per-journal watermark (the push
        sources' ``acked``) and propagate the collective minimum."""
        with self._lock:
            self._collect_watermarks()
            self._advance_migration_locked()
            self._ack_journals()

    def _ack_journals(self) -> None:
        live = [i for i in range(len(self.shards)) if self.alive[i]]
        if not live:
            return
        for pid, log in self.journals.items():
            horizon = min(self.shard_acked[i].get(pid,
                                                  self.journal_acked[pid])
                          for i in live)
            if horizon > self.journal_acked[pid]:
                log.ack(self.reader_ids[pid], horizon)
                self.journal_acked[pid] = horizon
                self.stats["journal_acks"] += 1

    # ------------------------------------------------------- observability
    def attach_registry(self, registry) -> None:
        """Publish coordinator metrics into ``registry`` and attach it
        to every in-process shard proxy (labeled by shard index).
        Remote shards keep their own registries, read via the
        ``metrics`` wire verb and merged by :meth:`metrics`."""
        self._obs = registry
        registry.register_collector(self._collect_samples)
        for i, shard in enumerate(self.shards):
            proxy = getattr(shard, "proxy", None)
            if proxy is not None:
                proxy.attach_registry(registry, {"shard": str(i)})

    def _collect_samples(self):
        with self._lock:
            stats = dict(self.stats)
            alive = list(self.alive)
            routing = self.routing
            owned = routing.counts(len(self.shards))
            acked = dict(self.journal_acked)
            cursors = dict(self.cursors)
            migrating = self._migration is not None
            parked = self._parked_count
            shard_lag = [sum(max(0, cursors[pid] - 1
                                 - self.shard_acked[i].get(
                                     pid, cursors[pid] - 1))
                             for pid in cursors)
                         for i in range(len(self.shards))]
        out = []
        for key, v in stats.items():
            out.append((f"lcap_cluster_{key}_total", "counter",
                        f"cluster stats[{key}]", {}, v))
        out.append(("lcap_routing_epoch", "gauge",
                    "routing table epoch (bumps on every topology "
                    "change)", {}, routing.epoch))
        out.append(("lcap_migration_in_flight", "gauge",
                    "1 while a slot migration is draining", {},
                    int(migrating)))
        out.append(("lcap_migration_parked_records", "gauge",
                    "records parked for draining slots", {}, parked))
        for i in range(len(alive)):
            lb = {"shard": str(i)}
            out.append(("lcap_shard_alive", "gauge",
                        "1 while the shard serves traffic", lb,
                        int(alive[i])))
            out.append(("lcap_shard_slots_owned", "gauge",
                        "routing slots currently owned", lb, owned[i]))
            out.append(("lcap_shard_dispatch_lag", "gauge",
                        "records routed but not yet acknowledged by "
                        "the shard (autoscaling signal)", lb,
                        shard_lag[i]))
        for pid in acked:
            lb = {"producer": pid}
            out.append(("lcap_journal_acked", "gauge",
                        "collective journal ack watermark", lb, acked[pid]))
            out.append(("lcap_journal_routed", "gauge",
                        "highest journal index routed to shards", lb,
                        cursors.get(pid, 1) - 1))
        return out

    def autoscale_signals(self) -> Dict[str, Dict[str, int]]:
        """Backpressure signals an external operator loop feeds into
        add/migrate decisions, per live shard: ``offer_queue_depth``
        (records admitted but not yet dispatched; ``-1`` for remote
        shards, whose depth is read from their own registry),
        ``dispatch_lag`` (records routed to the shard but not yet
        acknowledged by it) and ``slots_owned``.  The same numbers are
        exported through the registry as ``lcap_buffered_records`` and
        ``lcap_shard_dispatch_lag``."""
        with self._lock:
            counts = self.routing.counts(len(self.shards))
            out: Dict[str, Dict[str, int]] = {}
            for i, shard in enumerate(self.shards):
                if not self.alive[i]:
                    continue
                proxy = getattr(shard, "proxy", None)
                depth = proxy.buffered if proxy is not None else -1
                lag = sum(max(0, self.cursors[pid] - 1
                              - self.shard_acked[i].get(
                                  pid, self.cursors[pid] - 1))
                          for pid in self.journals)
                out[str(i)] = {"offer_queue_depth": depth,
                               "dispatch_lag": lag,
                               "slots_owned": counts[i]}
            return out

    def retention_horizons(self) -> Dict[str, int]:
        """Per producer, the oldest still-live cursor: the smallest
        journal index any current reader may still (re)read — the
        collective ack frontier (no group ever revisits below it), any
        unfinished replay bootstrap's rewind point on a live shard
        (active or parked durable), and the in-flight migration's
        handoff.  The stream-janitor (history.StreamJanitor) trims
        ``HistoryStore`` strictly below this, minus its floor."""
        with self._lock:
            out: Dict[str, int] = {}
            for pid in self.journals:
                h = self.journal_acked[pid] + 1
                if self._migration is not None:
                    h = min(h, self._migration.handoff.get(pid, h) + 1)
                for i, shard in enumerate(self.shards):
                    if not self.alive[i]:
                        continue
                    proxy = getattr(shard, "proxy", None)
                    if proxy is not None:
                        floor = proxy.replay_floor(pid)
                        if floor is not None:
                            h = min(h, floor)
                out[pid] = h
            return out

    def set_tenant_quota(self, tenant: str, **kw) -> None:
        """Install per-tenant delivery token buckets on every live
        in-process shard (see ``LcapProxy.set_tenant_quota``).  The
        rates apply *per shard* — a cluster-wide budget divides by the
        shard count at the caller."""
        with self._lock:
            for i, shard in enumerate(self.shards):
                proxy = getattr(shard, "proxy", None)
                if self.alive[i] and proxy is not None:
                    proxy.set_tenant_quota(tenant, **kw)

    def metrics(self) -> Dict[str, dict]:
        """One cluster snapshot: every live shard's registry snapshot
        merged (counters summed, gauges relabeled per shard), plus the
        coordinator's own registry when attached.

        In-process shards share the coordinator registry, so their
        samples are already shard-labeled and need no merge; remote
        shards are polled over the wire."""
        with self._lock:
            own = getattr(self, "_obs", None)
            per_shard = {}
            for i, shard in enumerate(self.shards):
                if not self.alive[i]:
                    continue
                proxy = getattr(shard, "proxy", None)
                if proxy is not None and proxy._obs is own:
                    continue     # shares the coordinator registry (or none)
                snap = self._shard_call(i, shard.metrics)
                if snap:
                    per_shard[str(i)] = snap
            from repro.obs.registry import merge_snapshots
            merged = merge_snapshots(per_shard) if per_shard else {}
            if own is not None:
                for name, ent in own.snapshot().items():
                    merged[name] = ent
            return merged

    def lag(self) -> Dict[str, Dict[str, Dict[str, int]]]:
        """Consumer lag per (group, producer), aggregated over live
        shards: lags sum (each shard's lag is its own re-routed share),
        ``dispatch_hw`` takes the furthest shard, ``ack`` the slowest.
        Dead shards are excluded — after a kill, lag is reported
        against the survivors' watermarks only."""
        with self._lock:
            out: Dict[str, Dict[str, Dict[str, int]]] = {}
            for i, shard in enumerate(self.shards):
                if not self.alive[i]:
                    continue
                shard_lag = self._shard_call(i, shard.lag)
                for gname, pids in (shard_lag or {}).items():
                    gout = out.setdefault(gname, {})
                    for pid, ent in pids.items():
                        cur = gout.get(pid)
                        if cur is None:
                            gout[pid] = dict(ent)
                        else:
                            cur["lag"] += ent["lag"]
                            cur["in_flight"] += ent["in_flight"]
                            cur["dispatch_hw"] = max(cur["dispatch_hw"],
                                                     ent["dispatch_hw"])
                            cur["ack"] = min(cur["ack"], ent["ack"])
            return out

    # ------------------------------------------------------------ failover
    def kill_shard(self, index: int, reason: str = "killed") -> None:
        """Fail shard ``index`` — a *forced zero-handoff migration*
        through the same invariant as ``migrate_slots``: records above
        the handoff watermark whose slots moved are re-offered to the
        new owners at the next epoch.  Forced means the handoff cannot
        be negotiated — it collapses to the dead shard's own last
        per-journal watermark — so the unacknowledged backlog
        ``(acked, cursor]`` is re-read from the journals and
        redelivered: zero loss, at-least-once (the journal never
        trimmed past the dead shard's own watermark).  The dead shard's
        slots are reassigned round-robin to the survivors; a graceful
        migration the dead shard participated in is cancelled first and
        its parked records folded into the redelivery."""
        with self._lock:
            if not self.alive[index]:
                return
            self.alive[index] = False
            self.stats["shards_failed"] += 1
            survivors = [i for i in range(len(self.shards))
                         if self.alive[i]]
            if not survivors:
                raise ClusterError(
                    f"shard {index} failed ({reason}); no shards left")
            carry = []
            m = self._migration
            if m is not None and (index == m.target or index in m.sources):
                # the graceful path lost a participant: cancel it and
                # let the forced path below absorb the parked records
                self._migration = None
                self.routing = self.routing.cancel_drain()
                self.stats["epoch_bumps"] += 1
                self.stats["migrations_cancelled"] += 1
                carry, self._parked, self._parked_count = self._parked, [], 0
            # forced migration: handoff = the dead shard's own watermark
            handoff = {pid: self.shard_acked[index].get(pid, 0)
                       for pid in self.journals}
            moved = set(self.routing.slots_of(index))
            rr = itertools.cycle(survivors)
            self.routing = self.routing.reassign({s: next(rr)
                                                  for s in sorted(moved)})
            self.stats["epoch_bumps"] += 1
            # a bootstrap in progress on a survivor has already scanned
            # indices whose slots just moved here and filtered them out;
            # restart those replays from their start (at-least-once
            # through failover — the reducers re-apply a prefix)
            for i in survivors:
                self._shard_call(i, self.shards[i].rewind_replays)
            redelivered = self._redeliver_locked(moved, handoff)
            self.stats["failover_redelivered"] += redelivered
            if carry:
                moved_mask = np.zeros(self.n_slots, dtype=bool)
                if moved:
                    moved_mask[list(moved)] = True
                self._reoffer_parked_locked(carry, moved_mask, handoff)
            # the dead shard no longer gates the collective ack
            self._ack_journals()

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        for i, shard in enumerate(self.shards):
            try:
                shard.close()
            except OSError:
                pass


# ---------------------------------------------------------------------------
# Daemon deployment.
# ---------------------------------------------------------------------------
def run_shard_daemon(conn, shard_index: int, shard_count: int,
                     host: str = "127.0.0.1", port: int = 0,
                     poll_interval: float = 0.002,
                     proxy_kwargs: Optional[dict] = None,
                     local_groups: Optional[Sequence[Tuple[str, int]]] = None,
                     local_flags: Optional[int] = None) -> None:
    """Entry point for a shard daemon process (multiprocessing target).

    Builds an empty push-fed ``LcapProxy`` wrapped in an ``LcapService``
    (so the shard serves subscribe/fetch/commit *and* the cluster verbs
    on its own port), reports ``(host, port)`` through ``conn``, then
    blocks until the parent sends anything (or the pipe closes).

    ``local_groups`` optionally co-locates consumers with the shard
    (the paper's policy-engine-per-host deployment, §III): for each
    ``(group, members)`` the daemon subscribes that many members
    through the in-process Session API and drains them in a local
    thread — records then never cross the wire on the consume side.
    On shutdown the daemon reports the drained record count back
    through ``conn``.
    """
    import sys
    from .server import LcapService
    from .session import Subscription, connect
    # a shard daemon interleaves three threads (poller dispatch, RPC
    # handlers, optional local drainer); the default 5 ms GIL switch
    # interval starves the short-lived offer/fetch handlers behind the
    # compute-bound poller
    sys.setswitchinterval(0.0005)
    proxy = LcapProxy({}, **(proxy_kwargs or {}))
    service = LcapService(proxy, host=host, port=port,
                          poll_interval=poll_interval,
                          shard_index=shard_index, shard_count=shard_count)
    service.start()
    stop = threading.Event()
    drained = [0]
    drainer = None
    if local_groups:
        session = connect(proxy)
        streams = [session.subscribe(Subscription(
            group=g, flags=local_flags, auto_commit=False))
            for g, members in local_groups for _ in range(members)]

        def _drain() -> None:
            import time
            while not stop.is_set():
                moved = 0
                for stream in streams:
                    for _pid, batch in stream.fetch():
                        moved += len(batch)
                    stream.commit()
                drained[0] += moved
                if not moved:
                    time.sleep(poll_interval)

        drainer = threading.Thread(target=_drain, daemon=True)
        drainer.start()
    try:
        conn.send(tuple(service.address))
        try:
            conn.recv()                   # parent says stop (or EOF)
        except EOFError:
            pass
    finally:
        stop.set()
        if drainer is not None:
            drainer.join(timeout=5)
            try:
                conn.send(drained[0])
            except (OSError, BrokenPipeError):
                pass
        service.stop()


class LcapClusterService:
    """The cluster as a set of daemons in one process: each in-process
    shard gets its own ``LcapService`` (own port, own poller — "each
    shard runs as its own daemon"), and a distributor thread runs the
    coordinator's routing/ack loop.  Consumers connect to
    ``addresses`` (``session.connect(service)`` fans in)."""

    def __init__(self, cluster: LcapCluster, host: str = "127.0.0.1",
                 poll_interval: float = 0.002):
        from .server import LcapService
        self.cluster = cluster
        self.host = host
        self.poll_interval = poll_interval
        self.services = []
        self._started = False
        for i, shard in enumerate(cluster.shards):
            if not isinstance(shard, LocalShard):
                raise ClusterError("LcapClusterService hosts in-process "
                                   "shards; remote shards already are "
                                   "daemons")
            self.services.append(LcapService(
                shard.proxy, host=host, port=0,
                poll_interval=poll_interval,
                shard_index=i, shard_count=len(cluster.shards),
                cluster_info=self.cluster_info))
        self._stop = threading.Event()
        self._distributor = threading.Thread(target=self._route_loop,
                                             daemon=True)

    @property
    def addresses(self) -> List[Tuple[str, int]]:
        return [svc.address for svc in self.services]

    def cluster_info(self) -> Dict:
        """The topology snapshot every shard service piggybacks on its
        replies and serves through the ``topology`` verb: the routing
        epoch, the shard count, and each shard's address — a consumer
        connected to *any* shard can re-resolve the whole fan-in."""
        return {"epoch": self.cluster.routing.epoch,
                "shards": len(self.cluster.shards),
                "addresses": [list(svc.address) for svc in self.services]}

    def add_shard(self, **proxy_kwargs) -> int:
        """Elastically grow the service: a fresh in-process shard joins
        the cluster (``LcapCluster.add_shard``) and immediately serves
        its own port.  Live consumers discover it through the epoch
        bump piggybacked on their next reply."""
        from .server import LcapService
        i = self.cluster.add_shard(**proxy_kwargs)
        svc = LcapService(self.cluster.shards[i].proxy, host=self.host,
                          port=0, poll_interval=self.poll_interval,
                          shard_index=i,
                          shard_count=len(self.cluster.shards),
                          cluster_info=self.cluster_info)
        self.services.append(svc)
        if self._started:
            svc.start()
        return i

    def _route_loop(self) -> None:
        import time
        while not self._stop.is_set():
            moved = self.cluster.pump(pump_shards=False)
            if not moved:
                # idle: no offer replies to piggyback watermarks on, so
                # poll them explicitly — the collective ack converges
                # once the consumers drain their backlog
                self.cluster.collect_watermarks()
                time.sleep(self.poll_interval)

    def start(self) -> "LcapClusterService":
        for svc in self.services:
            svc.start()
        self._started = True
        self._distributor.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._distributor.join(timeout=5)
        for svc in self.services:
            svc.stop()
