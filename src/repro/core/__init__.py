"""repro.core — the paper's contribution: distributed activity tracking.

Faithful implementation of "Distributed Lustre activity tracking"
(Doreau, CS.DC 2015): extensible changelog records (LU-1996 layout),
per-producer journals with collective acknowledgement, and the LCAP
aggregate-and-publish proxy with consumer groups, load balancing,
at-least-once delivery, ephemeral readers and stream modules.
"""

from . import records
from .ack import AckTracker
from .cluster import (LcapCluster, LcapClusterService, LocalShard,
                      RemoteShard, fid_slot)
from .errors import (ClusterError, SessionError, SubscriptionError,
                     TenantError, UnknownConsumerError, UnknownProducerError)
from .federation import Federation, FederatedStream, GlobalCursor
from .history import (Compactor, HistoryStore, JournalReplayReader,
                      StreamJanitor)
from .llog import Llog
from .modules import (CancelCompensating, CoalesceHeartbeats,
                      ReorderByTarget, TypeFilter)
from .proxy import EPHEMERAL, PERSISTENT, LcapProxy
from .reader import LocalReader, RemoteReader
from .records import RecordBatch
from .routing import RoutingTable
from .server import LcapService
from .session import (ClusterSession, FanInStream, Session, Stream,
                      Subscription, connect)
from .tenancy import TenantAccount, TenantPrincipal, TokenBucket

__all__ = [
    "records", "RecordBatch", "AckTracker", "Llog", "LcapProxy",
    "HistoryStore", "Compactor", "JournalReplayReader", "StreamJanitor",
    "LcapService", "PERSISTENT", "EPHEMERAL",
    "LcapCluster", "LcapClusterService", "LocalShard", "RemoteShard",
    "fid_slot", "RoutingTable",
    "connect", "Session", "Stream", "Subscription",
    "ClusterSession", "FanInStream",
    "Federation", "FederatedStream", "GlobalCursor",
    "TenantPrincipal", "TenantAccount", "TokenBucket",
    "SessionError", "SubscriptionError", "UnknownConsumerError",
    "UnknownProducerError", "ClusterError", "TenantError",
    "LocalReader", "RemoteReader",        # deprecated shims
    "CancelCompensating", "CoalesceHeartbeats", "ReorderByTarget",
    "TypeFilter",
]
