"""Acknowledgement watermark tracking for at-least-once delivery.

Per (group, producer): the upstream-ackable watermark is the highest
index W such that every *delivered* index <= W has been acknowledged.
Acks may arrive out of order (batched/delayed, paper §II) and — because
proxy modules may reorder or drop records (paper §III-A) — deliveries
may be out of index order and sparse.

Internals are a min-heap plus membership sets: ``deliver``/``ack`` are
O(log n) even when a consumer group runs tens of thousands of records
behind.  The columnar dispatch path hands in whole batches at once, so
``deliver_many``/``ack_many`` take index arrays and amortize the
filtering (one vectorized compare against the watermark) and the heap
maintenance (a sorted run *is* a valid min-heap, so an idle tracker
adopts it wholesale; a busy one extends and re-heapifies in O(n)).
The drain has a matching bulk exit: when every in-flight index is
acked — the steady state of a consumer that commits everything it
fetches — the whole heap collapses in one pass instead of a pop per
record.
"""

from __future__ import annotations

import bisect
import heapq
from typing import List, Set

import numpy as np


class AckTracker:
    def __init__(self, start: int = 0):
        self._heap: List[int] = []          # delivered & un-drained, min-first
        # _heap is always a valid min-heap; when _sorted it is fully
        # sorted (a stronger invariant bulk delivery maintains for free)
        # and the drain walks a prefix instead of popping per record
        self._sorted = True
        self._delivered: Set[int] = set()   # membership mirror of _heap
        self._acked: Set[int] = set()       # acked but blocked by a hole
        self._watermark = start
        self.delivered_total = 0            # cumulative, for metrics

    @property
    def watermark(self) -> int:
        return self._watermark

    @property
    def in_flight(self) -> int:
        return len(self._delivered)

    @property
    def acked_total(self) -> int:
        """Cumulative indices retired (every delivery eventually acks,
        so this is delivered_total minus what is still in flight)."""
        return self.delivered_total - len(self._delivered)

    def deliver(self, index: int) -> None:
        if index <= self._watermark or index in self._acked \
                or index in self._delivered:
            return
        self._delivered.add(index)
        self.delivered_total += 1
        heap = self._heap
        if self._sorted and (not heap or index >= heap[-1]):
            heap.append(index)              # common case: ascending arrival
        else:
            heapq.heappush(self._heap, index)
            self._sorted = False

    def deliver_many(self, indices) -> int:
        """Bulk ``deliver``: record a whole batch of indices (any order,
        duplicates tolerated) in one pass; returns how many were new."""
        arr = np.unique(np.asarray(indices, dtype=np.int64))
        arr = arr[arr > self._watermark]
        new = arr.tolist()
        if self._acked or self._delivered:
            acked, delivered = self._acked, self._delivered
            new = [i for i in new if i not in acked and i not in delivered]
        if not new:
            return 0
        self._delivered.update(new)
        self.delivered_total += len(new)
        heap = self._heap
        if heap:
            heap.extend(new)
            heap.sort()      # merge of (at most) two sorted runs: O(n)
        else:
            self._heap = new
        self._sorted = True
        return len(new)

    def _drain(self) -> int:
        heap = self._heap
        acked = self._acked
        if not heap or not acked:
            return self._watermark
        if self._delivered == acked:
            # steady state: everything in flight is acked — collapse in
            # one pass instead of visiting every entry below
            self._watermark = max(self._watermark, max(heap))
            heap.clear()
            self._delivered.clear()
            acked.clear()
            return self._watermark
        if not self._sorted and len(acked) > 64:
            heap.sort()                     # nearly sorted: cheap
            self._sorted = True
        if self._sorted:
            delivered = self._delivered
            # batched commits usually ack exactly the oldest run of the
            # heap: one superset test retires the whole prefix at C speed
            k = len(acked)
            if k <= len(heap):
                prefix = heap[:k]
                if acked.issuperset(prefix):
                    if prefix[-1] > self._watermark:
                        self._watermark = prefix[-1]
                    delivered.difference_update(prefix)
                    acked.difference_update(prefix)
                    del heap[:k]
                    return self._watermark
            pos, n = 0, len(heap)
            while pos < n and heap[pos] in acked:
                idx = heap[pos]
                acked.discard(idx)
                delivered.discard(idx)
                pos += 1
            if pos:
                if heap[pos - 1] > self._watermark:
                    self._watermark = heap[pos - 1]
                del heap[:pos]
            return self._watermark
        while heap and heap[0] in acked:
            idx = heapq.heappop(heap)
            acked.discard(idx)
            self._delivered.discard(idx)
            if idx > self._watermark:
                self._watermark = idx
        return self._watermark

    def ack(self, index: int) -> int:
        """Acknowledge one delivered index; returns the watermark."""
        if index > self._watermark:
            self._acked.add(index)
        return self._drain()

    def ack_many(self, indices) -> int:
        """Acknowledge a batch of delivered indices with one drain pass;
        returns the watermark."""
        wm = self._watermark
        if type(indices) is np.ndarray:
            self._acked.update(indices[indices > wm].tolist())
        else:
            acked = self._acked
            for index in indices:
                if index > wm:
                    acked.add(index)
        return self._drain()

    def ack_through(self, index: int) -> int:
        """Cumulative acknowledgement of every delivered index <= index."""
        heap = self._heap
        if self._sorted:
            pos = bisect.bisect_right(heap, index)
            if pos:
                if heap[pos - 1] > self._watermark:
                    self._watermark = heap[pos - 1]
                for idx in heap[:pos]:
                    self._acked.discard(idx)
                    self._delivered.discard(idx)
                del heap[:pos]
            return self._drain()
        while heap and heap[0] <= index:
            idx = heapq.heappop(heap)
            self._acked.discard(idx)
            self._delivered.discard(idx)
            if idx > self._watermark:
                self._watermark = idx
        return self._drain()
