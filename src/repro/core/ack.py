"""Acknowledgement watermark tracking for at-least-once delivery.

Per (group, producer): the upstream-ackable watermark is the highest
index W such that every *delivered* index <= W has been acknowledged.
Acks may arrive out of order (batched/delayed, paper §II) and — because
proxy modules may reorder or drop records (paper §III-A) — deliveries
may be out of index order and sparse.
"""

from __future__ import annotations

from bisect import bisect_right, insort
from typing import List, Set


class AckTracker:
    def __init__(self, start: int = 0):
        self._outstanding: List[int] = []   # sorted, delivered & un-acked
        self._acked: Set[int] = set()       # acked but blocked by a hole
        self._watermark = start

    @property
    def watermark(self) -> int:
        return self._watermark

    @property
    def in_flight(self) -> int:
        return len(self._outstanding)

    def deliver(self, index: int) -> None:
        if index <= self._watermark or index in self._acked:
            return
        pos = bisect_right(self._outstanding, index)
        if pos and self._outstanding[pos - 1] == index:
            return  # redelivery of an in-flight record
        insort(self._outstanding, index)

    def _drain(self) -> int:
        while self._outstanding and self._outstanding[0] in self._acked:
            self._acked.discard(self._outstanding[0])
            self._watermark = max(self._watermark, self._outstanding.pop(0))
        return self._watermark

    def ack(self, index: int) -> int:
        """Acknowledge one delivered index; returns the watermark."""
        if index > self._watermark:
            self._acked.add(index)
        return self._drain()

    def ack_through(self, index: int) -> int:
        """Cumulative acknowledgement of every delivered index <= index."""
        pos = bisect_right(self._outstanding, index)
        head, self._outstanding = self._outstanding[:pos], self._outstanding[pos:]
        for idx in head:
            self._acked.discard(idx)
            self._watermark = max(self._watermark, idx)
        return self._drain()
