"""Acknowledgement watermark tracking for at-least-once delivery.

Per (group, producer): the upstream-ackable watermark is the highest
index W such that every *delivered* index <= W has been acknowledged.
Acks may arrive out of order (batched/delayed, paper §II) and — because
proxy modules may reorder or drop records (paper §III-A) — deliveries
may be out of index order and sparse.

Internals are a min-heap plus membership sets, so ``deliver``/``ack``
are O(log n) even when a consumer group runs tens of thousands of
records behind (the sorted-list representation this replaced cost an
O(n) head pop per ack — quadratic under steady batch consumption).
"""

from __future__ import annotations

import heapq
from typing import List, Set


class AckTracker:
    def __init__(self, start: int = 0):
        self._heap: List[int] = []          # delivered & un-drained, min-first
        self._delivered: Set[int] = set()   # membership mirror of _heap
        self._acked: Set[int] = set()       # acked but blocked by a hole
        self._watermark = start

    @property
    def watermark(self) -> int:
        return self._watermark

    @property
    def in_flight(self) -> int:
        return len(self._delivered)

    def deliver(self, index: int) -> None:
        if index <= self._watermark or index in self._acked \
                or index in self._delivered:
            return
        self._delivered.add(index)
        heapq.heappush(self._heap, index)

    def _drain(self) -> int:
        heap = self._heap
        while heap and heap[0] in self._acked:
            idx = heapq.heappop(heap)
            self._acked.discard(idx)
            self._delivered.discard(idx)
            if idx > self._watermark:
                self._watermark = idx
        return self._watermark

    def ack(self, index: int) -> int:
        """Acknowledge one delivered index; returns the watermark."""
        if index > self._watermark:
            self._acked.add(index)
        return self._drain()

    def ack_many(self, indices) -> int:
        """Acknowledge a batch of delivered indices with one drain pass;
        returns the watermark."""
        wm = self._watermark
        acked = self._acked
        for index in indices:
            if index > wm:
                acked.add(index)
        return self._drain()

    def ack_through(self, index: int) -> int:
        """Cumulative acknowledgement of every delivered index <= index."""
        heap = self._heap
        while heap and heap[0] <= index:
            idx = heapq.heappop(heap)
            self._acked.discard(idx)
            self._delivered.discard(idx)
            if idx > self._watermark:
                self._watermark = idx
        return self._drain()
