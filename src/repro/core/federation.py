"""Federation — one global activity stream over many filesystems.

A site runs one LCAP plane (proxy or sharded cluster) per Lustre
filesystem; the audit/accounting layer wants a *single* stream across
all of them.  ``Federation`` joins named member planes — ``{"fs0":
cluster_a, "fs1": cluster_b}`` — into one consumer surface:

- ``subscribe`` opens the same declarative ``Subscription`` on every
  member and returns a ``FederatedStream`` of ``(origin, producer,
  batch)`` triples.  Each delivered ``RecordBatch`` is stamped with its
  member's origin tag (``batch.origin``, carried batch-level on the v2
  wire as a trailing frame — never per-record bytes), so downstream
  consumers can attribute activity to a filesystem without sniffing
  producer ids;
- per-member delivery positions live in a ``GlobalCursor``: one
  ``(origin, producer) -> index`` watermark map, advanced on delivery
  and snapshot-able for checkpointing.  Cursors never mix origins —
  producer ids are only unique *within* a member;
- members are consumed through their own sessions (``connect()`` per
  member), so a sharded member's epoch bumps, slot migrations and
  ``kill_shard`` failovers are absorbed by its ``FanInStream`` and
  stay invisible to the federated consumer;
- ``replay=`` bootstraps each member from *its own* history tier — a
  scalar applies to every origin, a ``{origin: value}`` dict gives
  per-origin start points (True = from the beginning, int = from that
  journal index, None/absent = live only);
- tenant scoping (``Subscription.tenant``) is pushed down to every
  member's proxies, so isolation holds per filesystem with no
  federation-level filtering;
- ``metrics()`` merges every member's registry snapshot with gauges
  relabeled by origin (``shard_label="origin"``), and ``stats()`` /
  ``lag()`` aggregate with per-origin breakdowns.

A member that dies mid-stream is dropped into ``FederatedStream.lost``
and the survivors keep flowing; unlike an intra-cluster shard death
there is no cross-member redelivery — filesystems are sovereign, their
records do not migrate between planes.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Dict, Iterator, List, Optional, Tuple, Union

from . import records as R
from .errors import SessionError, UnknownConsumerError
from .session import (ClusterSession, FanInStream, Session, Stream,
                      Subscription, _make_spec, connect)

#: per-member child stream kinds a federation fans in
MemberStream = Union[Stream, FanInStream]


class GlobalCursor:
    """Per-(origin, producer) delivery watermarks for a federated
    stream: the federation-level analogue of ``Stream.cursors``, keyed
    by origin first because producer ids are only unique within one
    member filesystem."""

    __slots__ = ("positions",)

    def __init__(self,
                 positions: Optional[Dict[str, Dict[str, int]]] = None):
        #: origin -> producer -> highest index delivered
        self.positions: Dict[str, Dict[str, int]] = {
            o: dict(p) for o, p in (positions or {}).items()}

    def advance(self, origin: str, pid: str, index: int) -> None:
        per = self.positions.setdefault(origin, {})
        if index > per.get(pid, 0):
            per[pid] = index

    def position(self, origin: str, pid: str) -> int:
        return self.positions.get(origin, {}).get(pid, 0)

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        """A deep copy safe to checkpoint."""
        return {o: dict(p) for o, p in self.positions.items()}

    def merge(self, other: "GlobalCursor") -> None:
        for origin, per in other.positions.items():
            for pid, idx in per.items():
                self.advance(origin, pid, idx)

    def __eq__(self, other) -> bool:
        return (isinstance(other, GlobalCursor)
                and self.positions == other.positions)

    def __repr__(self) -> str:
        return f"GlobalCursor({self.positions!r})"


class FederatedStream:
    """One logical subscription spanning every federation member.

    Owns one child stream per origin (a plain ``Stream`` for a proxy
    member, a ``FanInStream`` for a cluster member) and yields
    ``(origin, producer, batch)`` triples, round-robin across origins
    so one busy filesystem cannot starve the others.  Every delivered
    batch is stamped ``batch.origin = origin`` and advances the
    ``GlobalCursor``.

    ``commit()`` routes each member's acknowledgements back to exactly
    that member.  A member that dies mid-stream lands in ``lost`` and
    the rest keep flowing — records never migrate across filesystems,
    so there is nothing to redeliver elsewhere.
    """

    def __init__(self, federation: "Federation", spec: Subscription,
                 children: List[Tuple[str, MemberStream]]):
        self.federation = federation
        self.spec = spec
        self._children = list(children)      # [(origin, child stream)]
        self._rr = 0
        self.cursor = GlobalCursor()
        self.lost: List[str] = []

    # -- topology ------------------------------------------------------------
    @property
    def origins(self) -> List[str]:
        return [o for o, _ in self._children]

    @property
    def resumed(self) -> bool:
        return any(s.resumed for _, s in self._children)

    @property
    def replaying(self) -> bool:
        """True while any member's history bootstrap still streams."""
        return any(s.replaying for _, s in self._children)

    @property
    def replayed(self) -> int:
        return sum(s.replayed for _, s in self._children)

    @property
    def pending_commit(self) -> int:
        return sum(s.pending_commit for _, s in self._children)

    def _drop(self, pair: Tuple[str, MemberStream]) -> None:
        if pair in self._children:
            self._children.remove(pair)
            self.lost.append(pair[0])

    # -- delivery ------------------------------------------------------------
    def _stamp(self, origin: str, pid: str,
               batch: R.RecordBatch) -> R.RecordBatch:
        batch.origin = origin
        indices = batch.indices()
        if indices:
            self.cursor.advance(origin, pid, max(indices))
        return batch

    def fetch(self, max_records: Optional[int] = None,
              ) -> List[Tuple[str, str, R.RecordBatch]]:
        """Drain up to ``max_records`` across the members, round-robin.
        Every returned live batch is commit-pending on its own member."""
        cap = max_records or self.spec.max_records
        out: List[Tuple[str, str, R.RecordBatch]] = []
        children = list(self._children)
        taken = 0
        for k in range(len(children)):
            if taken >= cap:
                break
            pair = children[(self._rr + k) % len(children)]
            if pair not in self._children:
                continue
            origin, child = pair
            try:
                pairs = child.fetch(cap - taken)
            except (ConnectionError, OSError):
                self._drop(pair)
                continue
            for pid, batch in pairs:
                out.append((origin, pid, self._stamp(origin, pid, batch)))
                taken += len(batch)
        if self._children:
            self._rr = (self._rr + 1) % len(self._children)
        return out

    def __iter__(self) -> Iterator[Tuple[str, str, R.RecordBatch]]:
        return self

    def __next__(self) -> Tuple[str, str, R.RecordBatch]:
        """Round-robin the member iterators; each child keeps its own
        auto-commit contract.  Stops when every member is drained."""
        children = list(self._children)
        for k in range(len(children)):
            pair = children[(self._rr + k) % len(children)]
            if pair not in self._children:
                continue
            origin, child = pair
            try:
                pid, batch = next(child)
            except StopIteration:
                continue
            except (ConnectionError, OSError):
                self._drop(pair)
                continue
            self._rr = (self._rr + k + 1) % max(1, len(self._children))
            return origin, pid, self._stamp(origin, pid, batch)
        raise StopIteration

    def records(self) -> Iterator[Tuple[str, str, R.ChangelogRecord]]:
        """Record-level convenience: ``(origin, producer, record)``."""
        for origin, pid, batch in self:
            for i in range(len(batch)):
                yield origin, pid, batch.record(i)

    # -- acknowledgement -----------------------------------------------------
    def requeue(self,
                triples: List[Tuple[str, str, R.RecordBatch]]) -> None:
        """Hand unprocessed triples back to their owning member stream
        (withdrawn from commit-pending, redelivered first)."""
        by_origin: Dict[str, List[Tuple[str, R.RecordBatch]]] = {}
        for origin, pid, batch in triples:
            by_origin.setdefault(origin, []).append((pid, batch))
        children = dict(self._children)
        for origin, pairs in by_origin.items():
            child = children.get(origin)
            if child is None:
                raise SessionError(
                    f"requeue for unknown or lost origin {origin!r}")
            child.requeue(pairs)

    def commit(self) -> int:
        """One logical commit: each member receives exactly the acks
        for the records it delivered.  A dead member's pending acks are
        dropped (its plane redelivers on resume — at-least-once)."""
        total = 0
        for pair in list(self._children):
            try:
                total += pair[1].commit()
            except (ConnectionError, OSError):
                self._drop(pair)
        return total

    # -- lifecycle -----------------------------------------------------------
    def detach(self) -> None:
        for pair in list(self._children):
            try:
                pair[1].detach()
            except (ConnectionError, OSError):
                self._drop(pair)

    def close(self, failed: bool = False) -> None:
        for pair in list(self._children):
            try:
                pair[1].close(failed=failed)
            except (ConnectionError, OSError):
                self._drop(pair)


class Federation:
    """Named member activity planes joined into one global stream.

    ``members`` maps origin tags to anything ``connect()`` accepts —
    an in-process ``LcapProxy`` or ``LcapCluster``, a service address,
    or a list of shard addresses.  Member order is subscription
    round-robin order.

        fed = Federation({"fs0": cluster_a, "fs1": cluster_b})
        stream = fed.subscribe("audit", tenant=acme,
                               replay={"fs0": True})
        for origin, pid, batch in stream:
            ...
    """

    def __init__(self, members: Dict[str, object]):
        if not members:
            raise SessionError("a federation needs at least one member")
        self.members: Dict[str, object] = dict(members)
        self.sessions: Dict[str, Union[Session, ClusterSession]] = {}
        opened: List[str] = []
        try:
            for origin, target in self.members.items():
                self.sessions[origin] = connect(target)
                opened.append(origin)
        except Exception:
            for origin in opened:
                try:
                    self.sessions[origin].close()
                except (ConnectionError, OSError):
                    pass
            raise

    # -- subscriptions -------------------------------------------------------
    def _member_spec(self, spec: Subscription, origin: str,
                     replay) -> Subscription:
        """The spec one member attaches with: the ``replay=`` kwarg
        (scalar or per-origin dict) overrides the spec's own replay,
        which may itself be a per-origin dict."""
        per = replay if replay is not None else spec.replay
        if isinstance(per, dict):
            per = per.get(origin)
        return replace(spec, replay=per)

    def subscribe(self, subscription: Union[Subscription, str, None] = None,
                  *, resume: Optional[bool] = None,
                  replay=None, **spec_kwargs) -> FederatedStream:
        """Open the subscription on every member.  ``replay`` may be a
        scalar (every origin bootstraps the same way) or an ``{origin:
        value}`` dict (per-origin start points; absent origins attach
        live).  With ``resume=True``, members holding parked durable
        state resume at their cursor and the rest attach fresh; it is
        an error only when *no* member resumed."""
        spec = _make_spec(subscription, spec_kwargs)
        children: List[Tuple[str, MemberStream]] = []
        resumed_any = False
        try:
            for origin, sess in self.sessions.items():
                mspec = self._member_spec(spec, origin, replay)
                if resume:
                    try:
                        child = sess.subscribe(mspec, resume=True)
                        resumed_any = True
                    except UnknownConsumerError:
                        child = sess.subscribe(mspec, resume=None)
                else:
                    child = sess.subscribe(mspec, resume=resume)
                children.append((origin, child))
        except Exception:
            for _o, child in children:
                try:
                    child.close()
                except (ConnectionError, OSError):
                    pass
            raise
        if resume and not resumed_any:
            for _o, child in children:
                try:
                    child.close()
                except (ConnectionError, OSError):
                    pass
            raise UnknownConsumerError(
                f"no federation member holds parked state for durable "
                f"consumer {spec.group}/{spec.name!r}")
        return FederatedStream(self, spec, children)

    def resume(self, group: str, name: str, **spec_kwargs) -> FederatedStream:
        spec = Subscription(group=group, name=name, **spec_kwargs)
        return self.subscribe(spec, resume=True)

    # -- operations ----------------------------------------------------------
    def pump(self) -> int:
        """Advance every in-process member (proxy or cluster) one
        dispatch round; wire members pump themselves via their service
        pollers.  Returns the total records moved."""
        moved = 0
        for target in self.members.values():
            fn = getattr(target, "pump", None)
            if callable(fn):
                moved += int(fn() or 0)
        return moved

    def set_tenant_quota(self, tenant: str, **kw) -> None:
        """Install per-tenant delivery quotas on every member that
        exposes the knob (in-process proxies and clusters).  Rates
        apply per proxy — a federation-wide budget divides by the
        member/shard count at the caller."""
        for target in self.members.values():
            fn = getattr(target, "set_tenant_quota", None)
            if callable(fn):
                fn(tenant, **kw)

    # -- observability -------------------------------------------------------
    def stats(self) -> Dict:
        """Summed numeric proxy counters across members, with the raw
        per-origin views under ``"per_origin"``."""
        per_origin: Dict[str, Dict] = {}
        total: Dict[str, Union[int, float]] = {}
        for origin, sess in self.sessions.items():
            try:
                st = sess.stats()
            except (ConnectionError, OSError):
                continue
            per_origin[origin] = st
            for key, val in st.items():
                if isinstance(val, (int, float)):
                    total[key] = total.get(key, 0) + val
        total["per_origin"] = per_origin
        return total

    def metrics(self) -> Dict:
        """One federated registry snapshot: every member's metrics
        merged — counters and histograms summed, gauges relabeled with
        an ``origin`` label (the cluster tier already labeled its own
        gauges per shard)."""
        from repro.obs.registry import merge_snapshots
        per_origin = {}
        for origin, sess in self.sessions.items():
            try:
                snap = sess.metrics()
            except (ConnectionError, OSError):
                continue
            if snap:
                per_origin[origin] = snap
        return merge_snapshots(per_origin, shard_label="origin")

    def lag(self) -> Dict[str, Dict]:
        """Per-origin consumer lag views (origins are sovereign —
        there is no meaningful cross-filesystem lag sum)."""
        out: Dict[str, Dict] = {}
        for origin, sess in self.sessions.items():
            try:
                out[origin] = sess.lag()
            except (ConnectionError, OSError):
                continue
        return out

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        for sess in self.sessions.values():
            try:
                sess.close()
            except (ConnectionError, OSError):
                pass

    def __enter__(self) -> "Federation":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["Federation", "FederatedStream", "GlobalCursor"]
