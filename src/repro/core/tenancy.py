"""Tenant principals, scope matching, and per-tenant rate accounting.

The multi-tenant refactor's data model: a ``TenantPrincipal`` names who
a subscription acts for and which slice of the jobid namespace it may
observe.  Scope is enforced *server-side* in ``LcapProxy._dispatch`` as
a columnar pushdown predicate over ``RecordBatch.jobid_col`` — exactly
where op-type masks already live — so isolation is a property of the
proxy, not of polite clients: out-of-scope records are acknowledged in
place and never copied into a tenant's outbox (the ``filtered_out``
discipline, per-tenant under ``tenant_filtered``).

Scope semantics (audit-paper motivated: per-user/per-jobid trails with
isolation guarantees):

- ``jobids``     exact jobid match (a frozen set of bytes)
- ``prefixes``   jobid prefix match (``jobid.startswith(p)`` for any p)
- a record *without* a jobid matches no tenant scope — invisible to
  every scoped consumer, visible to unscoped (trusted) ones.  The
  isolation-safe default: unattributed activity leaks to nobody.
- empty scope entries are rejected (``TenantError``): an empty prefix
  would match everything and an empty jobid would match unattributed
  records, both silent scope widenings.

``TokenBucket`` is the proxy's per-tenant delivery throttle (records
and bytes); an over-quota tenant's groups park through the existing
per-group backpressure path and drain when the bucket refills.
"""

from __future__ import annotations

import sys
from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Union

import numpy as np

from .errors import TenantError

_JOBID_LEN = 32      # records._JOBID_LEN; kept literal to avoid a cycle


def _as_bytes(v: Union[str, bytes]) -> bytes:
    return v.encode("utf-8") if isinstance(v, str) else bytes(v)


@dataclass(frozen=True)
class TenantPrincipal:
    """Who a subscription acts for, and which jobids it may observe.

    ``name`` identifies the tenant for quota/audit accounting;
    ``jobids`` and ``prefixes`` define the visibility scope (either or
    both; at least one entry).  Principals are value objects: equality
    is by (name, scope), so a resumed durable consumer can prove it is
    the same tenant that parked.
    """

    name: str
    jobids: frozenset = frozenset()
    prefixes: Tuple[bytes, ...] = ()

    def __post_init__(self):
        if not self.name:
            raise TenantError("tenant principals need a name")
        jobids = frozenset(_as_bytes(j) for j in self.jobids)
        prefixes = tuple(sorted(_as_bytes(p) for p in self.prefixes))
        if not jobids and not prefixes:
            raise TenantError(
                f"tenant {self.name!r} has an empty scope; grant at "
                f"least one jobid or prefix")
        for v in (*jobids, *prefixes):
            if not v:
                raise TenantError(
                    f"tenant {self.name!r}: empty scope entries are "
                    f"forbidden (they would widen the scope)")
            if len(v) > _JOBID_LEN:
                raise TenantError(
                    f"tenant {self.name!r}: scope entry {v!r} exceeds "
                    f"the {_JOBID_LEN}-byte jobid field")
        object.__setattr__(self, "jobids", jobids)
        object.__setattr__(self, "prefixes", prefixes)
        need = max([len(j) + 1 for j in jobids] +
                   [len(p) for p in prefixes])
        if need <= 8 and sys.byteorder == "little":
            # every scope entry fits one machine word: round the mask
            # width up to 8 so ``scope_mask`` can test each entry with
            # a single masked-uint64 compare over the jobid column
            object.__setattr__(self, "_mask_width", 8)
            tests = []
            for j in jobids:          # entry + NUL (see scope_mask)
                v = j + b"\0"
                tests.append(
                    (np.uint64(int.from_bytes(b"\xff" * len(v), "little")),
                     np.uint64(int.from_bytes(v, "little"))))
            for p in prefixes:
                tests.append(
                    (np.uint64(int.from_bytes(b"\xff" * len(p), "little")),
                     np.uint64(int.from_bytes(p, "little"))))
            object.__setattr__(self, "_u64_tests", tuple(tests))
        else:
            object.__setattr__(self, "_mask_width", min(need, _JOBID_LEN))
            object.__setattr__(self, "_u64_tests", None)

    # ------------------------------------------------------------ matching
    def allows(self, jobid: bytes) -> bool:
        """Scalar scope check for the per-record dispatch path."""
        if jobid in self.jobids:
            return True
        return any(jobid.startswith(p) for p in self.prefixes)

    @property
    def mask_width(self) -> int:
        """The narrowest ``jobid_col`` width this scope can be checked
        against: jobids are NUL-padded, so an exact entry needs its own
        bytes plus the terminating NUL, a prefix only its own bytes."""
        return self._mask_width

    @property
    def word_scoped(self) -> bool:
        """True when every scope entry fits one little-endian machine
        word, so ``scope_mask`` accepts the cheap 1-D uint64 form
        (``RecordBatch.jobid_word``) instead of a byte matrix."""
        return self._u64_tests is not None

    def scope_mask(self, jobid_col: np.ndarray) -> np.ndarray:
        """Vectorized scope check over an ``(n, w)`` uint8 jobid matrix
        (``RecordBatch.jobid_col``, any ``w >= mask_width``): one
        boolean per row, computed with whole-column compares per scope
        entry — the columnar pushdown predicate
        ``LcapProxy._dispatch_batch`` evaluates."""
        if jobid_col.ndim == 1:
            # word-at-a-time form (``RecordBatch.jobid_word``): the
            # whole scope check is one masked compare per entry
            if self._u64_tests is None:
                raise TenantError(
                    f"tenant {self.name!r}: scope does not fit the "
                    f"word form; pass the byte matrix")
            mask = np.zeros(len(jobid_col), dtype=bool)
            for m64, t64 in self._u64_tests:
                mask |= (jobid_col & m64) == t64
            return mask
        n, w = jobid_col.shape
        mask = np.zeros(n, dtype=bool)
        if not n:
            return mask
        if self._u64_tests is not None and w >= 8:
            # word-at-a-time: the whole scope check is one masked
            # uint64 compare per entry over the leading 8 jobid bytes
            lead = jobid_col if w == 8 else jobid_col[:, :8]
            if not lead.flags.c_contiguous:
                lead = np.ascontiguousarray(lead)
            v = lead.view(np.uint64).ravel()
            for m64, t64 in self._u64_tests:
                mask |= (v & m64) == t64
            return mask
        for j in self.jobids:
            # compare the entry + one NUL: padding means the first zero
            # byte ends the jobid, so a longer jobid cannot alias
            row = np.frombuffer(j[:w].ljust(min(len(j) + 1, w), b"\0"),
                                dtype=np.uint8)
            mask |= (jobid_col[:, :len(row)] == row).all(axis=1)
        for p in self.prefixes:
            pre = np.frombuffer(p, dtype=np.uint8)
            mask |= (jobid_col[:, :len(p)] == pre).all(axis=1)
        return mask

    # ---------------------------------------------------------------- wire
    def to_wire(self) -> Dict:
        return {"name": self.name,
                "jobids": sorted(self.jobids),
                "prefixes": list(self.prefixes)}

    @staticmethod
    def from_wire(msg) -> Optional["TenantPrincipal"]:
        """Decode the ``tenant`` field of a subscribe/resume verb (or
        an ``attach`` kwarg): None passes through, a dict or an
        existing principal normalizes."""
        if msg is None:
            return None
        if isinstance(msg, TenantPrincipal):
            return msg
        if not isinstance(msg, dict) or "name" not in msg:
            raise TenantError(f"malformed tenant principal: {msg!r}")
        return TenantPrincipal(
            name=str(msg["name"]),
            jobids=frozenset(_as_bytes(j) for j in msg.get("jobids") or ()),
            prefixes=tuple(_as_bytes(p)
                           for p in msg.get("prefixes") or ()))


class TokenBucket:
    """A refill-on-read token bucket.  ``level`` may go negative when a
    whole batch is charged at once (bounded burst debt); the group then
    parks until refill brings it back above zero."""

    __slots__ = ("rate", "burst", "level", "_last")

    def __init__(self, rate: float, burst: Optional[float] = None):
        self.rate = float(rate)                  # tokens per second
        self.burst = float(burst if burst is not None else rate)
        self.level = self.burst
        self._last: Optional[float] = None

    def refill(self, now: float) -> None:
        if self._last is None:
            self._last = now
            return
        dt = now - self._last
        if dt > 0:
            self.level = min(self.burst, self.level + dt * self.rate)
            self._last = now

    def charge(self, n: float) -> None:
        self.level -= n

    @property
    def exhausted(self) -> bool:
        return self.level <= 0


@dataclass
class TenantAccount:
    """Per-tenant delivery accounting inside one proxy: counters the
    ``lcap_tenant_*`` collector exports, plus the optional quota
    buckets.  Created lazily the first time a tenant attaches (or a
    quota is set) and shared by every consumer of that tenant."""

    name: str
    delivered_records: int = 0
    delivered_bytes: int = 0
    filtered_records: int = 0        # scope-denied, acked in place
    replayed_records: int = 0        # history-tier deliveries
    quota_blocked_pumps: int = 0     # dispatch rounds parked on quota
    record_bucket: Optional[TokenBucket] = None
    byte_bucket: Optional[TokenBucket] = None
    consumers: int = 0               # live consumers under this tenant

    def set_quota(self, records_per_s: Optional[float] = None,
                  bytes_per_s: Optional[float] = None,
                  burst_records: Optional[float] = None,
                  burst_bytes: Optional[float] = None) -> None:
        self.record_bucket = (TokenBucket(records_per_s, burst_records)
                              if records_per_s else None)
        self.byte_bucket = (TokenBucket(bytes_per_s, burst_bytes)
                            if bytes_per_s else None)

    def refill(self, now: float) -> None:
        if self.record_bucket is not None:
            self.record_bucket.refill(now)
        if self.byte_bucket is not None:
            self.byte_bucket.refill(now)

    @property
    def exhausted(self) -> bool:
        return ((self.record_bucket is not None
                 and self.record_bucket.exhausted)
                or (self.byte_bucket is not None
                    and self.byte_bucket.exhausted))

    def charge(self, records: int, nbytes: int) -> None:
        self.delivered_records += records
        self.delivered_bytes += nbytes
        if self.record_bucket is not None:
            self.record_bucket.charge(records)
        if self.byte_bucket is not None:
            self.byte_bucket.charge(nbytes)


__all__ = ["TenantPrincipal", "TokenBucket", "TenantAccount"]
