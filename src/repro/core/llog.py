"""Per-producer persistent changelog journal (paper §II, Lustre LLOG).

One ``Llog`` per producer (an MDT in Lustre; a host/runtime-shard in the
training framework).  Semantics follow the paper:

- Logging is armed as soon as at least one reader is registered.
- The administrator selects which operation types are logged (``mask``).
- Records receive a monotonically increasing ``cr_index`` and a
  ``cr_prev`` pointing at the previous record touching the same target.
- Records are kept (on disk when a path is given) *until read and
  acknowledged by all registered readers*; the trim point is the minimum
  acknowledged index across readers.
- Readers poll with an explicit start index (the paper calls out that the
  start command addresses a changelog index on a given MDT, not a reader
  ID — we reproduce that, and LCAP papers over it).
"""

from __future__ import annotations

import json
import os
import struct
import threading
from typing import Dict, Iterable, List, Optional

from . import records as R

_LEN = struct.Struct("<I")


class Llog:
    def __init__(self, producer_id: str, path: Optional[str] = None,
                 mask: Optional[Iterable[int]] = None):
        self.producer_id = producer_id
        self.path = path
        self.mask = set(mask) if mask is not None else None  # None = all
        self._recs: List[bytes] = []      # packed records
        self._first = 1                   # index of _recs[0]
        self._next = 1
        self._prev_by_key: Dict[tuple, int] = {}
        self._readers: Dict[str, int] = {}   # reader_id -> acked-through index
        self._reader_seq = 0
        self._lock = threading.Lock()
        self._fh = None
        if path:
            self._load()

    # -- persistence --------------------------------------------------------
    def _sidecar(self) -> str:
        return self.path + ".readers"

    def _load(self) -> None:
        if os.path.exists(self.path):
            with open(self.path, "rb") as fh:
                data = fh.read()
            off = 0
            while off + 4 <= len(data):
                (ln,) = _LEN.unpack_from(data, off)
                buf = data[off + 4:off + 4 + ln]
                off += 4 + ln
                self._recs.append(buf)
            if self._recs:
                self._first = R.unpack(self._recs[0]).index
                self._next = R.unpack(self._recs[-1]).index + 1
        if os.path.exists(self._sidecar()):
            with open(self._sidecar()) as fh:
                meta = json.load(fh)
            self._readers = {k: int(v) for k, v in meta["readers"].items()}
            self._reader_seq = meta.get("seq", len(self._readers))
            self._first = meta.get("first", self._first)
            self._next = max(self._next, meta.get("next", self._next))

    def _persist_meta(self) -> None:
        if not self.path:
            return
        tmp = self._sidecar() + ".tmp"
        with open(tmp, "w") as fh:
            json.dump({"readers": self._readers, "seq": self._reader_seq,
                       "first": self._first, "next": self._next}, fh)
        os.replace(tmp, self._sidecar())

    def _append_disk(self, buf: bytes) -> None:
        if not self.path:
            return
        if self._fh is None:
            self._fh = open(self.path, "ab")
        self._fh.write(_LEN.pack(len(buf)) + buf)
        self._fh.flush()

    # -- reader registry -----------------------------------------------------
    @property
    def enabled(self) -> bool:
        return bool(self._readers)

    def register_reader(self, name: Optional[str] = None,
                        resume: bool = False) -> str:
        """Register (or, with ``resume``, re-attach to) a reader.
        Registrations are persistent — a restarted reader resumes at its
        acknowledged position and replays everything unacknowledged
        (at-least-once across restarts)."""
        with self._lock:
            self._reader_seq += 1
            rid = name or f"cl{self._reader_seq}"
            if rid in self._readers:
                if resume:
                    return rid
                raise ValueError(f"reader {rid} already registered")
            # a new reader only owes acks for records logged from now on
            self._readers[rid] = self._next - 1
            self._persist_meta()
            return rid

    def deregister_reader(self, rid: str) -> None:
        with self._lock:
            self._readers.pop(rid, None)
            self._trim_locked()
            self._persist_meta()

    # -- producing -----------------------------------------------------------
    def log(self, rec: R.ChangelogRecord) -> Optional[int]:
        """Append a record; returns its index, or None when not logged
        (no registered reader, or type masked out)."""
        with self._lock:
            if not self._readers:
                return None
            if self.mask is not None and rec.type not in self.mask:
                return None
            rec.index = self._next
            rec.prev = self._prev_by_key.get(rec.key(), 0)
            self._prev_by_key[rec.key()] = rec.index
            if not rec.time:
                rec.time = R.now_ns()
            buf = R.pack(rec)
            self._recs.append(buf)
            self._next += 1
            self._append_disk(buf)
            return rec.index

    # -- consuming -----------------------------------------------------------
    @property
    def first_index(self) -> int:
        return self._first

    @property
    def last_index(self) -> int:
        return self._next - 1

    def read(self, start: int, max_records: int = 1024) -> List[bytes]:
        """Return packed records with index >= start (at most
        ``max_records``).  ``start`` is a changelog index, per the paper."""
        with self._lock:
            if start < self._first:
                start = self._first
            lo = start - self._first
            if lo < 0 or lo >= len(self._recs):
                return []
            return self._recs[lo:lo + max_records]

    def ack(self, rid: str, index: int) -> None:
        """Acknowledge (clear) records up to ``index`` for reader ``rid``;
        trims storage up to the minimum acked index across readers."""
        with self._lock:
            if rid not in self._readers:
                raise KeyError(f"unknown reader {rid}")
            if index > self._readers[rid]:
                self._readers[rid] = index
            self._trim_locked()
            self._persist_meta()

    def _trim_locked(self) -> None:
        if not self._readers:
            return
        horizon = min(self._readers.values())
        drop = horizon - self._first + 1
        if drop > 0:
            drop = min(drop, len(self._recs))
            self._recs = self._recs[drop:]
            self._first += drop
            if self.path:
                self._rewrite_disk()

    def _rewrite_disk(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as fh:
            for buf in self._recs:
                fh.write(_LEN.pack(len(buf)) + buf)
        os.replace(tmp, self.path)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
