"""Per-producer persistent changelog journal (paper §II, Lustre LLOG).

One ``Llog`` per producer (an MDT in Lustre; a host/runtime-shard in the
training framework).  Semantics follow the paper:

- Logging is armed as soon as at least one reader is registered.
- The administrator selects which operation types are logged (``mask``).
- Records receive a monotonically increasing ``cr_index`` and a
  ``cr_prev`` pointing at the previous record touching the same target.
- Records are kept (on disk when a path is given) *until read and
  acknowledged by all registered readers*; the trim point is the minimum
  acknowledged index across readers.
- Readers poll with an explicit start index (the paper calls out that the
  start command addresses a changelog index on a given MDT, not a reader
  ID — we reproduce that, and LCAP papers over it).

Storage is *segmented* (Lustre's llog is a chain of fixed-size log
objects — same idea): records append to the active segment, a full
segment is sealed and a new one started, and trimming drops whole
sealed segments in O(1) instead of rewriting the journal.  Each segment
doubles as a ``RecordBatch``: ``read()`` returns a batch view over the
segment buffer, so the consume path never materializes per-record
objects.

On-disk layout (when ``path`` is given): one file per segment,
``<path>.seg.<first-index>``, each a sequence of ``u32 length + packed
record``; reader positions live in the ``<path>.readers`` sidecar.  A
truncated final record (crash mid-append) is dropped on load.

With a ``history`` store attached (history.py), trimming *archives*
fully acknowledged segments instead of destroying them: the segment
file is adopted by the store with one rename (same framing), so a late
consumer can still bootstrap from compacted history while the live
journal stays aggressively trimmed.
"""

from __future__ import annotations

import bisect
import glob as _glob
import json
import os
import struct
import threading
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from . import records as R

_LEN = struct.Struct("<I")

DEFAULT_SEGMENT_RECORDS = 1024


class _Segment:
    """A run of contiguous records [first, first+len) backed by one
    append-only buffer (and, when persistent, one file)."""

    __slots__ = ("first", "data", "offsets", "lengths", "path")

    def __init__(self, first: int, path: Optional[str] = None):
        self.first = first
        self.data = bytearray()
        self.offsets: List[int] = []
        self.lengths: List[int] = []
        self.path = path

    def __len__(self) -> int:
        return len(self.offsets)

    @property
    def last(self) -> int:
        return self.first + len(self.offsets) - 1

    def append(self, buf: bytes) -> None:
        self.offsets.append(len(self.data))
        self.lengths.append(len(buf))
        self.data += buf

    def seal(self) -> None:
        """Freeze the segment: immutable bytes (batch views then
        extract records with a single copy instead of locking a live
        bytearray) and int64 offset/length columns (batch views slice
        them zero-copy instead of re-materializing per read)."""
        self.data = bytes(self.data)
        self.offsets = np.asarray(self.offsets, dtype=np.int64)
        self.lengths = np.asarray(self.lengths, dtype=np.int64)

    def batch(self, lo: int, count: int) -> R.RecordBatch:
        """Batch view over records [lo, lo+count) (segment-relative)."""
        return R.RecordBatch(self.data, self.offsets[lo:lo + count],
                             self.lengths[lo:lo + count])


class Llog:
    def __init__(self, producer_id: str, path: Optional[str] = None,
                 mask: Optional[Iterable[int]] = None,
                 segment_records: int = DEFAULT_SEGMENT_RECORDS,
                 history=None):
        self.producer_id = producer_id
        self.path = path
        self.mask = set(mask) if mask is not None else None  # None = all
        self.segment_records = max(1, segment_records)
        if history is True:                 # convenience: co-located store
            from .history import HistoryStore
            history = HistoryStore(path + ".hist" if path else None)
        self.history = history
        self._segments: List[_Segment] = []
        self._firsts: List[int] = []      # seg.first per segment (for bisect)
        self._first = 1                   # logical trim point (first live)
        self._next = 1
        self._prev_by_key: Dict[tuple, int] = {}
        self._readers: Dict[str, int] = {}   # reader_id -> acked-through index
        self._reader_seq = 0
        self._lock = threading.Lock()
        self._fh = None                   # handle on the active segment file
        self.stats = {"segments_dropped": 0, "segments_rolled": 0,
                      "truncated_dropped": 0}
        if path:
            self._load()

    # -- persistence --------------------------------------------------------
    def _sidecar(self) -> str:
        return self.path + ".readers"

    def _seg_path(self, first: int) -> str:
        return f"{self.path}.seg.{first:016d}"

    def _parse_segment_file(self, path: str, first: int) -> _Segment:
        seg = _Segment(first, path)
        with open(path, "rb") as fh:
            data = fh.read()
        off = 0
        while True:
            if off + 4 > len(data):
                if off < len(data):
                    # torn mid-prefix: truncate the stray bytes too, or
                    # post-recovery appends land after garbage and are
                    # destroyed by the *next* recovery
                    self.stats["truncated_dropped"] += 1
                    with open(path, "r+b") as fh:
                        fh.truncate(off)
                break
            (ln,) = _LEN.unpack_from(data, off)
            if off + 4 + ln > len(data) or ln < R.HDR_SIZE:
                # crash mid-append: drop the truncated tail record
                self.stats["truncated_dropped"] += 1
                with open(path, "r+b") as fh:
                    fh.truncate(off)
                break
            seg.append(data[off + 4:off + 4 + ln])
            off += 4 + ln
        return seg

    def _load(self) -> None:
        seg_files = sorted(_glob.glob(self.path + ".seg.*"))
        if not seg_files and os.path.exists(self.path):
            # migrate a legacy single-file journal into segment 0
            legacy = self._parse_segment_file(self.path, 0)
            if len(legacy):
                first_idx = legacy.batch(0, 1).packed_index(0)
                legacy.first = first_idx
                legacy.path = self._seg_path(first_idx)
                with open(legacy.path, "wb") as fh:
                    off = 0
                    for o, ln in zip(legacy.offsets, legacy.lengths):
                        fh.write(_LEN.pack(ln))
                        fh.write(bytes(legacy.data[o:o + ln]))
                self._segments.append(legacy)
            os.remove(self.path)
        else:
            for path in seg_files:
                first = int(path.rsplit(".", 1)[1])
                seg = self._parse_segment_file(path, first)
                if len(seg):
                    self._segments.append(seg)
                else:
                    os.remove(path)
        if self._segments:
            for seg in self._segments[:-1]:      # only the last stays active
                seg.seal()
            self._first = self._segments[0].first
            self._next = self._segments[-1].last + 1
        self._firsts = [seg.first for seg in self._segments]
        if os.path.exists(self._sidecar()):
            with open(self._sidecar()) as fh:
                meta = json.load(fh)
            self._readers = {k: int(v) for k, v in meta["readers"].items()}
            self._reader_seq = meta.get("seq", len(self._readers))
            self._first = meta.get("first", self._first)
            self._next = max(self._next, meta.get("next", self._next))

    def _persist_meta(self) -> None:
        if not self.path:
            return
        tmp = self._sidecar() + ".tmp"
        with open(tmp, "w") as fh:
            json.dump({"readers": self._readers, "seq": self._reader_seq,
                       "first": self._first, "next": self._next}, fh)
        os.replace(tmp, self._sidecar())

    def _append_disk(self, seg: _Segment, buf: bytes) -> None:
        if not self.path:
            return
        if self._fh is None:
            self._fh = open(seg.path, "ab")
        self._fh.write(_LEN.pack(len(buf)) + buf)
        self._fh.flush()

    # -- segment management --------------------------------------------------
    def _active_segment(self) -> _Segment:
        if self._segments and len(self._segments[-1]) < self.segment_records:
            return self._segments[-1]
        # seal the active segment, roll a new one
        if self._segments:
            self._segments[-1].seal()
        if self._fh is not None:
            self._fh.close()
            self._fh = None
        seg = _Segment(self._next,
                       self._seg_path(self._next) if self.path else None)
        self._segments.append(seg)
        self._firsts.append(seg.first)
        if self._segments[:-1]:
            self.stats["segments_rolled"] += 1
        return seg

    @property
    def segment_count(self) -> int:
        return len(self._segments)

    # -- reader registry -----------------------------------------------------
    @property
    def enabled(self) -> bool:
        return bool(self._readers)

    def register_reader(self, name: Optional[str] = None,
                        resume: bool = False) -> str:
        """Register (or, with ``resume``, re-attach to) a reader.
        Registrations are persistent — a restarted reader resumes at its
        acknowledged position and replays everything unacknowledged
        (at-least-once across restarts)."""
        with self._lock:
            self._reader_seq += 1
            rid = name or f"cl{self._reader_seq}"
            if rid in self._readers:
                if resume:
                    return rid
                raise ValueError(f"reader {rid} already registered")
            # a new reader only owes acks for records logged from now on
            self._readers[rid] = self._next - 1
            self._persist_meta()
            return rid

    def deregister_reader(self, rid: str) -> None:
        with self._lock:
            self._readers.pop(rid, None)
            self._trim_locked()
            self._persist_meta()

    def attach_reader(self, name: str) -> Tuple[str, int]:
        """Register (or re-attach) a *consuming* reader under ``name``
        and return ``(rid, start index)``.

        A brand-new consuming reader starts at the journal's first live
        record and owes acknowledgements for all of it (position
        ``first_index - 1`` — unlike ``register_reader``, whose new
        readers only owe acks for records logged from then on).  An
        existing reader resumes right after its *own* acked watermark,
        never at a trim point a slower co-registered reader holds back,
        and never before ``first_index``.  Both halves are what
        at-least-once needs across restarts: backlog delivered but not
        yet acked is re-ingested; backlog already acked is not."""
        with self._lock:
            if name not in self._readers:
                self._readers[name] = self._first - 1
                self._persist_meta()
            return name, max(self._first, self._readers[name] + 1)

    def has_reader(self, rid: str) -> bool:
        with self._lock:
            return rid in self._readers

    def reader_position(self, rid: str) -> int:
        """The highest index reader ``rid`` has acknowledged.  A restarted
        reader resumes at ``max(first_index, reader_position + 1)`` —
        records before its own watermark were already consumed, even when
        a slower co-registered reader holds the trim point further back."""
        with self._lock:
            if rid not in self._readers:
                raise KeyError(f"unknown reader {rid}")
            return self._readers[rid]

    # -- producing -----------------------------------------------------------
    def _log_locked(self, rec: R.ChangelogRecord) -> Optional[int]:
        if self.mask is not None and rec.type not in self.mask:
            return None
        rec.index = self._next
        rec.prev = self._prev_by_key.get(rec.key(), 0)
        self._prev_by_key[rec.key()] = rec.index
        if not rec.time:
            rec.time = R.now_ns()
        buf = R.pack(rec)
        seg = self._active_segment()
        seg.append(buf)
        self._next += 1
        self._append_disk(seg, buf)
        return rec.index

    def log(self, rec: R.ChangelogRecord) -> Optional[int]:
        """Append a record; returns its index, or None when not logged
        (no registered reader, or type masked out)."""
        with self._lock:
            if not self._readers:
                return None
            return self._log_locked(rec)

    def log_batch(self, recs: Iterable[R.ChangelogRecord]) -> List[int]:
        """Append many records under one lock acquisition; returns the
        indices of the records actually logged."""
        out: List[int] = []
        with self._lock:
            if not self._readers:
                return out
            for rec in recs:
                idx = self._log_locked(rec)
                if idx is not None:
                    out.append(idx)
        return out

    # -- consuming -----------------------------------------------------------
    @property
    def first_index(self) -> int:
        return self._first

    @property
    def last_index(self) -> int:
        return self._next - 1

    def read(self, start: int, max_records: int = 1024) -> R.RecordBatch:
        """Return a ``RecordBatch`` view of packed records with index >=
        ``start`` (at most ``max_records``).  ``start`` is a changelog
        index, per the paper.  The batch shares the segment buffers —
        zero copy until a consumer extracts a record."""
        with self._lock:
            if start < self._first:
                start = self._first
            return self._read_locked(start, max_records)

    def read_raw(self, start: int, max_records: int = 1024) -> R.RecordBatch:
        """Like ``read`` but without clamping ``start`` to the logical
        trim point: records logically trimmed but still physically
        present (their segment not yet fully acknowledged and dropped)
        are served.  Replay-bootstrap readers use this for the span
        between compacted history and the live trim point, keeping the
        history+journal union gapless."""
        with self._lock:
            return self._read_locked(start, max_records)

    def _read_locked(self, start: int, max_records: int) -> R.RecordBatch:
        views: List[R.RecordBatch] = []
        want = max_records
        # first segment that may hold ``start``: the last one whose
        # first index is <= start — O(log n) with thousands of
        # sealed segments instead of a whole-list scan
        pos = bisect.bisect_right(self._firsts, start) - 1
        for seg in self._segments[max(0, pos):]:
            if want <= 0:
                break
            if seg.last < start or not len(seg):
                continue
            lo = max(0, start - seg.first)
            take = min(want, len(seg) - lo)
            if take > 0:
                views.append(seg.batch(lo, take))
                want -= take
        if not views:
            return R.RecordBatch.empty()
        if len(views) == 1:
            return views[0]
        return R.RecordBatch.concat(views)

    def ack(self, rid: str, index: int) -> None:
        """Acknowledge (clear) records up to ``index`` for reader ``rid``;
        trims storage up to the minimum acked index across readers."""
        with self._lock:
            if rid not in self._readers:
                raise KeyError(f"unknown reader {rid}")
            if index > self._readers[rid]:
                self._readers[rid] = index
            self._trim_locked()
            self._persist_meta()

    def _trim_locked(self) -> None:
        if not self._readers:
            return
        # an over-ack (index beyond anything logged) must not push the
        # trim point past the records that actually exist
        horizon = min(min(self._readers.values()), self._next - 1)
        if horizon < self._first:
            return
        self._first = horizon + 1
        # drop whole segments below the logical trim point — O(1) per
        # segment, never a journal rewrite.  With a history store the
        # drop is an *archive*: the store adopts the segment file by
        # rename (same framing) before the journal forgets it.
        while self._segments and self._segments[0].last < self._first:
            seg = self._segments.pop(0)
            self._firsts.pop(0)
            if len(self._segments) == 0 and self._fh is not None:
                self._fh.close()
                self._fh = None
            adopted = False
            if self.history is not None and len(seg):
                adopted = self.history.archive(seg.batch(0, len(seg)),
                                               seg.first, seg.last,
                                               move_from=seg.path)
            if not adopted and seg.path and os.path.exists(seg.path):
                os.remove(seg.path)
            self.stats["segments_dropped"] += 1

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
