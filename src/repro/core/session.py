"""Unified changelog client API: Subscription / Session / Stream.

One consumer-facing surface over both bindings (in-process proxy and
TCP), replacing the ``LocalReader``/``RemoteReader`` split:

- a ``Subscription`` declares *what* to consume: group, optional durable
  consumer name, delivery mode, §IV-A field projection (``flags``) and
  an op-type mask (``types``).  Both filters are pushed down to
  ``LcapProxy._dispatch`` — filtered records are never copied into the
  consumer's outbox, extending the paper's "remote remap" idea from
  fields to whole records;
- a ``Session`` is a connection: ``connect(proxy_or_address)`` returns
  one object with one implementation, backed by either the in-process
  proxy or the wire protocol (``subscribe``/``resume``/``commit``
  verbs, versioned messages);
- a ``Stream`` is a live subscription: iterate it for ``(producer,
  RecordBatch)`` pairs with per-producer cursor tracking and automatic
  batched acknowledgement (commit-on-iterate), or drive ``fetch()`` /
  ``commit()`` explicitly.

Durable consumers (``name=``) survive disconnects: the proxy parks
their unacked records and ack watermark under ``(group, name)``, and
``session.resume(group, name)`` (or a plain ``subscribe`` under the
same name) picks up exactly at the cursor — the stream's
``resume_token`` reports the per-producer watermark that was restored.

    session = lcap.connect(service.address)      # or connect(proxy)
    stream = session.subscribe(
        "ckpt", name="committer-0", types={R.CL_CKPT_WRITE})
    for pid, batch in stream:                    # auto-commits batches
        handle(pid, batch)

Failures surface as typed exceptions (``UnknownConsumerError``,
``SubscriptionError``) on both bindings, never as error strings.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, replace
from typing import Any, Deque, Dict, Iterator, List, Optional, Tuple, Union

from . import records as R
from .errors import (SessionError, SubscriptionError,  # noqa: F401 (re-export)
                     TenantError, UnknownConsumerError, raise_reply_error)
from .proxy import EPHEMERAL, PERSISTENT, LcapProxy
from .tenancy import TenantPrincipal
from .transport import PROTOCOL_VERSION, RpcClient

Address = Union[str, Tuple[str, int]]


@dataclass(frozen=True)
class Subscription:
    """Declarative consumer spec.

    group        consumer group (required for persistent mode)
    name         durable identity within the group; survives disconnects
    mode         PERSISTENT (default) or EPHEMERAL (§IV-B radio semantics)
    flags        CLF_* field projection; None = everything supported
    types        CL_* op-type mask; None = every operation
    auto_commit  iterate-commits-previous-batch (True) vs explicit commit()
    max_records  fetch granularity (records per fetch round)
    zero_fill    local remap fills requested-but-absent fields with
                 zeros (§IV-A, the default).  Columnar consumers whose
                 gathers already read absent extensions as zeros set
                 False: delivery becomes strip-only — identity, no
                 per-record work, when the proxy projection already
                 matched (the aggregation tier's hot path).
    replay       bootstrap from the compacted history tier: True = from
                 the beginning, an int = from that journal index.  The
                 stream yields history batches first, then hands off to
                 the live stream at a recorded watermark (no gap, no
                 duplicate).  Requires a fresh group for persistent mode.
    tenant       a ``TenantPrincipal`` (or its dict form) scoping the
                 subscription to the tenant's jobid namespace.  Scope is
                 enforced server-side at dispatch (pushdown): records
                 outside it are acknowledged in place and never leave
                 the proxy — isolation holds against impolite clients.
    """

    group: Optional[str] = None
    name: Optional[str] = None
    mode: str = PERSISTENT
    flags: Optional[int] = None
    types: Optional[frozenset] = None
    auto_commit: bool = True
    max_records: int = 1024
    replay: Optional[Union[bool, int]] = None
    zero_fill: bool = True
    tenant: Optional[TenantPrincipal] = None

    def __post_init__(self):
        if self.types is not None and not isinstance(self.types, frozenset):
            object.__setattr__(self, "types", frozenset(self.types))
        if self.tenant is not None and \
                not isinstance(self.tenant, TenantPrincipal):
            object.__setattr__(self, "tenant",
                               TenantPrincipal.from_wire(self.tenant))
        if self.mode == PERSISTENT and not self.group:
            raise SubscriptionError("persistent subscriptions need a group")
        if self.mode == EPHEMERAL and self.name:
            raise SubscriptionError("ephemeral subscriptions cannot be "
                                    "durable")


# ---------------------------------------------------------------------------
# One Session implementation, two backends.  A backend speaks attach /
# fetch / commit / unsubscribe / disconnect — the in-process one calls
# the proxy directly, the wire one frames the same verbs over TCP.
# ---------------------------------------------------------------------------
class _LocalBackend:
    def __init__(self, proxy: LcapProxy):
        self.proxy = proxy

    def attach(self, spec: Subscription,
               resume: Optional[bool] = None) -> Dict:
        return self.proxy.attach(spec.group, flags=spec.flags,
                                 mode=spec.mode, types=spec.types,
                                 name=spec.name, resume=resume,
                                 replay=spec.replay, tenant=spec.tenant)

    def fetch(self, cid: str, max_records: int,
              ) -> List[Tuple[str, R.RecordBatch]]:
        return self.proxy.fetch_batches(cid, max_records)

    def fetch_replay(self, cid: str, max_records: int,
                     ) -> Tuple[List[Tuple[str, R.RecordBatch]], bool]:
        return self.proxy.fetch_replay(cid, max_records)

    def commit(self, cid: str, acks: Dict[str, List[int]]) -> None:
        self.proxy.commit(cid, acks)

    def unsubscribe(self, cid: str) -> None:
        self.proxy.unsubscribe(cid)

    def disconnect(self, cid: str) -> None:
        self.proxy.disconnect(cid)

    crash = disconnect          # an in-process "connection" just vanishes

    def stats(self) -> Dict:
        return dict(self.proxy.stats)

    def metrics(self) -> Dict:
        return self.proxy.metrics_snapshot()

    def lag(self) -> Dict:
        return self.proxy.lag()

    def close(self) -> None:
        pass


class _WireBackend:
    def __init__(self, address: Tuple[str, int]):
        self.rpc = RpcClient(address)
        #: record-frame generation the server will emit, learned from
        #: the subscribe/resume reply (v1 until negotiated)
        self.wire = R.WIRE_V1
        #: highest routing epoch piggybacked on any reply from this
        #: shard (0 until a topology-aware peer stamps one); the fan-in
        #: layer watches it to detect topology changes mid-stream
        self.epoch = 0

    def _call(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        msg.setdefault("v", PROTOCOL_VERSION)
        reply = self.rpc.call(msg)
        raise_reply_error(reply)
        e = reply.get(R.CAP_EPOCH)
        if e is not None and int(e) > self.epoch:
            self.epoch = int(e)
        return reply

    def topology(self) -> Optional[Dict[str, Any]]:
        """The cluster topology snapshot (epoch, shard count, shard
        addresses) served by a topology-aware shard; None when the
        peer does not speak the verb."""
        try:
            return self._call({"op": "topology"})
        except SessionError:
            return None

    def attach(self, spec: Subscription,
               resume: Optional[bool] = None) -> Dict:
        reply = self._call({
            "op": "resume" if resume else "subscribe",
            "group": spec.group, "name": spec.name, "mode": spec.mode,
            "flags": spec.flags, "resume": resume, "replay": spec.replay,
            "types": sorted(spec.types) if spec.types is not None else None,
            "tenant": spec.tenant.to_wire() if spec.tenant is not None
            else None,
            # offer the column-bearing v2 record frame; an old server
            # ignores the key and keeps sending v1 (from_wire sniffs
            # the frame magic, so either way decodes transparently)
            "wire": R.WIRE_V2,
        })
        self.wire = int(reply.get("wire", R.WIRE_V1))
        return {"cid": reply["cid"], "resumed": reply.get("resumed", False),
                "flags": reply.get("flags"),
                "token": reply.get("token") or {},
                "replay": reply.get("replay", False)}

    def fetch(self, cid: str, max_records: int,
              ) -> List[Tuple[str, R.RecordBatch]]:
        reply = self._call({"op": "fetch", "cid": cid, "max": max_records})
        return [(pid, R.RecordBatch.from_wire(blob))
                for pid, blob in reply["batches"]]

    def fetch_replay(self, cid: str, max_records: int,
                     ) -> Tuple[List[Tuple[str, R.RecordBatch]], bool]:
        reply = self._call({"op": "fetch_replay", "cid": cid,
                            "max": max_records})
        return ([(pid, R.RecordBatch.from_wire(blob))
                 for pid, blob in reply["batches"]], reply["done"])

    def commit(self, cid: str, acks: Dict[str, List[int]]) -> None:
        self._call({"op": "commit", "cid": cid,
                    "acks": {pid: list(ix) for pid, ix in acks.items()}})

    def unsubscribe(self, cid: str) -> None:
        self._call({"op": "close", "cid": cid})

    def disconnect(self, cid: str) -> None:
        self._call({"op": "detach", "cid": cid})

    def crash(self, cid: str) -> None:
        # simulate a crash: drop the socket without deregistering; the
        # service's disconnect hook parks (durable) or fails (anonymous)
        self.rpc.close()

    def stats(self) -> Dict:
        return self._call({"op": "stats"})["stats"]

    def metrics(self) -> Dict:
        return self._call({"op": "metrics"})["metrics"]

    def lag(self) -> Dict:
        return self._call({"op": "lag"})["lag"]

    def close(self) -> None:
        self.rpc.close()


class Stream:
    """A live subscription: an iterator of ``(producer, RecordBatch)``
    pairs with cursor tracking and batched acknowledgement.

    Iterating auto-commits: each time the stream needs a new fetch
    round, every batch yielded so far is acknowledged in one ``commit``
    call (disable with ``auto_commit=False`` and call ``commit()``
    yourself — at-least-once either way).  Iteration stops when the
    proxy has nothing queued; poll again (or iterate again) later.
    """

    def __init__(self, session: "Session", spec: Subscription, info: Dict):
        self.session = session
        self.spec = spec
        self.cid: str = info["cid"]
        self.resumed: bool = info["resumed"]
        #: producer -> highest acked index (the durable cursor restored
        #: on resume, advanced by every commit)
        self.resume_token: Dict[str, int] = dict(info["token"])
        #: producer -> highest index delivered to the application
        self.cursors: Dict[str, int] = {}
        #: records delivered from the compacted history tier
        self.replayed = 0
        self._replaying: bool = bool(info.get("replay"))
        self._uncommitted: Dict[str, List[int]] = {}
        # (producer, batch, from_replay) — replayed batches are already
        # acknowledged upstream and are never commit-pending
        self._queue: Deque[Tuple[str, R.RecordBatch, bool]] = deque()
        # the proxy reports the *effective* projection (a resumed
        # consumer may have inherited a narrower parked mask); the
        # local remap must match it, not the spec's default
        flags = info.get("flags")
        self._flags = R.normalize_flags(spec.flags if flags is None
                                        else flags)
        self._closed = False

    # -- delivery ------------------------------------------------------------
    def _remap(self, batch: R.RecordBatch) -> R.RecordBatch:
        # local remap: zero-fill requested-but-absent fields (§IV-A).
        # With zero_fill=False only over-delivered fields are stripped
        # (columnar project; identity when the proxy already matched).
        if self.spec.zero_fill:
            return batch.remap(self._flags)
        return batch.project(self._flags)

    def _note(self, pid: str, batch: R.RecordBatch,
              track: bool = True) -> None:
        indices = batch.indices()
        if indices:
            # max, not last: a proxy module may reorder within a batch
            self.cursors[pid] = max(self.cursors.get(pid, 0), max(indices))
            if track and self.spec.mode != EPHEMERAL:
                self._uncommitted.setdefault(pid, []).extend(indices)

    @property
    def replaying(self) -> bool:
        """True while the history bootstrap is still streaming."""
        return self._replaying

    def _fetch_replay_round(self, cap: int,
                            ) -> List[Tuple[str, R.RecordBatch, bool]]:
        """One replay round: returns queued-entry triples; flips
        ``_replaying`` off when the proxy reports the bootstrap done."""
        out: List[Tuple[str, R.RecordBatch, bool]] = []
        while self._replaying and not out:
            batches, done = self.session._backend.fetch_replay(self.cid, cap)
            if done:
                self._replaying = False
            if not batches and not done:
                break                        # defensive: never spin
            for pid, batch in batches:
                out.append((pid, self._remap(batch), True))
        return out

    def fetch(self, max_records: Optional[int] = None,
              ) -> List[Tuple[str, R.RecordBatch]]:
        """Explicitly drain up to ``max_records`` queued records; every
        returned *live* batch becomes commit-pending (replayed history
        is already acknowledged upstream).  Locally requeued batches
        (see ``requeue``) are returned first."""
        cap = max_records or self.spec.max_records
        out, taken = [], 0
        while self._queue and taken < cap:
            pid, batch, from_replay = self._queue.popleft()
            self._note(pid, batch, track=not from_replay)
            if from_replay:
                self.replayed += len(batch)
            out.append((pid, batch))
            taken += len(batch)
        while self._replaying and taken < cap:
            round_ = self._fetch_replay_round(cap - taken)
            if not round_:
                break
            for pid, batch, _ in round_:
                self._note(pid, batch, track=False)
                self.replayed += len(batch)
                out.append((pid, batch))
                taken += len(batch)
        if taken < cap and not self._replaying:
            for pid, batch in self.session._backend.fetch(self.cid,
                                                          cap - taken):
                batch = self._remap(batch)
                self._note(pid, batch)
                out.append((pid, batch))
        return out

    def __iter__(self) -> Iterator[Tuple[str, R.RecordBatch]]:
        return self

    def __next__(self) -> Tuple[str, R.RecordBatch]:
        if not self._queue:
            if self.spec.auto_commit:
                self.commit()
            if self._replaying:
                self._queue.extend(
                    self._fetch_replay_round(self.spec.max_records))
            if not self._queue and not self._replaying:
                for pid, batch in self.session._backend.fetch(
                        self.cid, self.spec.max_records):
                    self._queue.append((pid, self._remap(batch), False))
            if not self._queue:
                raise StopIteration
        pid, batch, from_replay = self._queue.popleft()
        self._note(pid, batch, track=not from_replay)
        if from_replay:
            self.replayed += len(batch)
        return pid, batch

    def records(self) -> Iterator[Tuple[str, R.ChangelogRecord]]:
        """Record-level convenience over the batch iterator."""
        for pid, batch in self:
            for i in range(len(batch)):
                yield pid, batch.record(i)

    # -- acknowledgement -----------------------------------------------------
    @property
    def pending_commit(self) -> int:
        return sum(len(v) for v in self._uncommitted.values())

    def requeue(self, pairs: List[Tuple[str, R.RecordBatch]]) -> None:
        """Return delivered-but-unprocessed batches to the stream (a
        handler failed): they are withdrawn from the commit-pending set
        and handed out again at the front of the next fetch/iteration
        round, so a retrying consumer reprocesses them instead of
        wedging them in flight or acknowledging them unhandled."""
        for pid, batch in reversed(pairs):
            drop = set(batch.indices())
            left = [i for i in self._uncommitted.get(pid, ())
                    if i not in drop]
            if left:
                self._uncommitted[pid] = left
            else:
                self._uncommitted.pop(pid, None)
            # requeued batches re-enter as live; committing a replayed
            # index the group never delivered is a no-op upstream
            self._queue.appendleft((pid, batch, False))

    def commit(self) -> int:
        """Acknowledge every delivered-but-uncommitted record in one
        call; returns how many were acknowledged.  A failed commit
        keeps the records commit-pending, so a later retry still
        acknowledges them (at-least-once)."""
        if not self._uncommitted:
            return 0
        acks, self._uncommitted = self._uncommitted, {}
        try:
            self.session._backend.commit(self.cid, acks)
        except Exception:
            for pid, indices in acks.items():
                self._uncommitted.setdefault(pid, [])[:0] = indices
            raise
        for pid, indices in acks.items():
            self.resume_token[pid] = max(self.resume_token.get(pid, 0),
                                         max(indices))
        return sum(len(v) for v in acks.values())

    # -- lifecycle -----------------------------------------------------------
    def detach(self) -> None:
        """Let go of the connection but keep the durable identity: a
        later ``resume`` under the same (group, name) continues at the
        cursor.  For anonymous consumers this is a failure (backlog
        redelivered)."""
        if not self._closed:
            self._closed = True
            self.session._backend.disconnect(self.cid)
            self.session._forget(self)

    def close(self, failed: bool = False) -> None:
        """Deregister.  ``failed=True`` simulates a crash instead; on
        the wire binding that drops the Session's socket — taking every
        sibling stream of the same Session down with it, exactly like a
        real process death (use one Session per consumer when streams
        must fail independently)."""
        if self._closed:
            return
        self._closed = True
        if failed:
            self.session._backend.crash(self.cid)
        else:
            self.session._backend.unsubscribe(self.cid)
        self.session._forget(self)


def _make_spec(subscription: Union[Subscription, str, None],
               spec_kwargs: Dict) -> Subscription:
    """A ``Subscription``, or one built from kwargs (a plain string is
    shorthand for the group name) — shared by both session kinds."""
    if isinstance(subscription, Subscription):
        if spec_kwargs:
            raise SubscriptionError("pass either a Subscription or "
                                    "spec kwargs, not both")
        return subscription
    return Subscription(group=subscription, **spec_kwargs)


class FanInStream:
    """One logical stream over every shard of a cluster.

    A ``Subscription`` against a cluster attaches on each live shard;
    this facade owns one child ``Stream`` per shard and presents the
    single-stream surface: ``fetch``/iteration round-robin the shards,
    cursors stay per-(shard, producer) in the children, and ``commit``
    routes each batch's acknowledgement back to the shard that owns it
    (the child that delivered it) — never broadcast.

    A shard that dies mid-session is dropped (its index lands in
    ``lost``); its unacknowledged records are re-routed by the cluster
    coordinator to the surviving shards, so the group still sees them
    (at-least-once) through the remaining children.

    The stream also tracks the cluster's routing ``epoch``: every fetch
    round compares the session's current epoch against the one this
    stream last saw, and on a bump (slot migration, shard add/split,
    forced failover) re-resolves the shard set — shards that joined
    since subscribe get a fresh child ``Stream``, without restarting
    the consumer or disturbing the existing children's cursors.
    """

    def __init__(self, session: "ClusterSession", spec: Subscription,
                 children: List[Tuple[int, Stream]]):
        self.session = session
        self.spec = spec
        self._children = list(children)        # [(shard index, Stream)]
        self._rr = 0
        self._sources: Dict[int, Stream] = {}  # id(batch) -> owning child
        self.lost: List[int] = []
        #: routing epoch at which the shard set was last resolved
        self.epoch: int = session.current_epoch()

    def _maybe_refresh(self) -> None:
        """Re-resolve the shard set when the routing epoch moved past
        the one this stream subscribed under."""
        current = self.session.current_epoch()
        if current <= self.epoch:
            return
        self.epoch = current
        self.session._ensure_sessions()
        have = {i for i, _ in self._children} | set(self.lost)
        # a shard that joined after this stream subscribed: attach a
        # live child there.  No replay bootstrap — any history the new
        # shard's slots carry was already delivered by their previous
        # owners before the migration committed.
        child_spec = (replace(self.spec, replay=None)
                      if self.spec.replay else self.spec)
        for i, sess in self.session._sessions:
            if i in have or not self.session._shard_alive(i):
                continue
            try:
                self._children.append((i, sess._open(child_spec,
                                                     resume=None)))
            except (ConnectionError, OSError):
                continue

    # -- topology ------------------------------------------------------------
    @property
    def shards(self) -> List[int]:
        return [i for i, _ in self._children]

    @property
    def resumed(self) -> bool:
        return any(s.resumed for _, s in self._children)

    @property
    def resume_token(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for _, s in self._children:
            for pid, idx in s.resume_token.items():
                out[pid] = max(out.get(pid, 0), idx)
        return out

    @property
    def cursors(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for _, s in self._children:
            for pid, idx in s.cursors.items():
                out[pid] = max(out.get(pid, 0), idx)
        return out

    @property
    def shard_cursors(self) -> Dict[int, Dict[str, int]]:
        """Per-(shard, producer) delivery cursors."""
        return {i: dict(s.cursors) for i, s in self._children}

    @property
    def pending_commit(self) -> int:
        return sum(s.pending_commit for _, s in self._children)

    @property
    def replaying(self) -> bool:
        """True while any shard's history bootstrap is still
        streaming."""
        return any(s.replaying for _, s in self._children)

    @property
    def replayed(self) -> int:
        return sum(s.replayed for _, s in self._children)

    # -- failure handling ----------------------------------------------------
    def _drop(self, pair: Tuple[int, Stream]) -> None:
        if pair in self._children:
            self._children.remove(pair)
            self.lost.append(pair[0])

    def _live(self) -> List[Tuple[int, Stream]]:
        dead = [p for p in self._children
                if not self.session._shard_alive(p[0])]
        for p in dead:
            self._drop(p)
        return self._children

    # -- delivery ------------------------------------------------------------
    def fetch(self, max_records: Optional[int] = None,
              ) -> List[Tuple[str, R.RecordBatch]]:
        """Drain up to ``max_records`` across the shards, round-robin so
        one busy shard cannot starve the others.  Every returned batch
        becomes commit-pending on its owning shard."""
        cap = max_records or self.spec.max_records
        out: List[Tuple[str, R.RecordBatch]] = []
        self._maybe_refresh()
        children = self._live()
        taken = 0
        for k in range(len(children)):
            if taken >= cap:
                break
            pair = children[(self._rr + k) % len(children)]
            try:
                pairs = pair[1].fetch(cap - taken)
            except (ConnectionError, OSError):
                self._drop(pair)
                continue
            for pid, batch in pairs:
                self._sources[id(batch)] = pair[1]
                out.append((pid, batch))
                taken += len(batch)
        if children:
            self._rr = (self._rr + 1) % max(1, len(children))
        return out

    def __iter__(self) -> Iterator[Tuple[str, R.RecordBatch]]:
        return self

    def __next__(self) -> Tuple[str, R.RecordBatch]:
        """Round-robin the child iterators; each child keeps its own
        auto-commit contract (a batch is acknowledged one fetch round
        after it was yielded).  Stops when every shard is drained."""
        self._maybe_refresh()
        children = self._live()
        for k in range(len(children)):
            pair = children[(self._rr + k) % len(children)]
            try:
                item = next(pair[1])
            except StopIteration:
                continue
            except (ConnectionError, OSError):
                self._drop(pair)
                continue
            self._sources[id(item[1])] = pair[1]   # requeue routing
            self._rr = (self._rr + k + 1) % max(1, len(self._live()))
            return item
        raise StopIteration

    def records(self) -> Iterator[Tuple[str, R.ChangelogRecord]]:
        for pid, batch in self:
            for i in range(len(batch)):
                yield pid, batch.record(i)

    # -- acknowledgement -----------------------------------------------------
    def requeue(self, pairs: List[Tuple[str, R.RecordBatch]]) -> None:
        """Hand unprocessed batches back to their owning shard's stream
        (withdrawn from commit-pending, redelivered first).  Batches of
        one shard are requeued in one call so their relative order is
        preserved."""
        by_child: Dict[int, Tuple[Stream, List]] = {}
        for pid, batch in pairs:
            child = self._sources.get(id(batch))
            if child is None:
                raise SessionError("requeue of a batch this stream did "
                                   "not deliver")
            by_child.setdefault(id(child), (child, []))[1].append(
                (pid, batch))
        for child, child_pairs in by_child.values():
            child.requeue(child_pairs)

    def commit(self) -> int:
        """One logical commit: each shard receives exactly the
        acknowledgements for the records it delivered.  Returns the
        total acknowledged; a dead shard's pending acks are dropped
        (the cluster redelivers its records — at-least-once)."""
        total = 0
        for pair in list(self._children):
            try:
                total += pair[1].commit()
            except (ConnectionError, OSError):
                self._drop(pair)
        self._sources.clear()
        return total

    # -- lifecycle -----------------------------------------------------------
    def detach(self) -> None:
        for pair in list(self._children):
            try:
                pair[1].detach()
            except (ConnectionError, OSError):
                self._drop(pair)

    def close(self, failed: bool = False) -> None:
        for pair in list(self._children):
            try:
                pair[1].close(failed=failed)
            except (ConnectionError, OSError):
                self._drop(pair)


class ClusterSession:
    """A connection to a sharded cluster: one child ``Session`` per
    shard, one declarative surface.  ``subscribe``/``resume`` return a
    ``FanInStream`` that spans every live shard.

    The session is *topology-aware*: it can report the cluster's
    current routing epoch (``current_epoch``) and grow its shard set
    when the cluster does (``_ensure_sessions``).  Three discovery
    paths, in order of directness:

    - ``cluster=``   in-process ``LcapCluster`` — epoch and shard list
      read straight off the coordinator's routing table;
    - ``topology=``  a callable returning ``{"epoch", "shards",
      "addresses"}`` (``LcapClusterService.cluster_info``);
    - neither        the highest epoch piggybacked on any shard reply,
      with the ``topology`` wire verb probed for addresses when a bump
      is seen (falls back to a static shard set against pre-epoch
      daemons).
    """

    def __init__(self, sessions: List[Tuple[int, Session]],
                 alive=None, cluster=None, topology=None):
        self._sessions = list(sessions)
        self._alive = alive                  # callable: shard index -> bool
        self._cluster = cluster              # in-process LcapCluster
        self._topology = topology            # callable -> topology snapshot
        self._topology_unsupported = False

    def _shard_alive(self, index: int) -> bool:
        if self._alive is not None:
            return self._alive(index)
        if self._cluster is not None:
            alive = self._cluster.alive
            return index < len(alive) and alive[index]
        return True

    # -- topology ------------------------------------------------------------
    def current_epoch(self) -> int:
        """The cluster's routing epoch as this session can best see it
        (0 against a target with no epoch source at all)."""
        if self._cluster is not None:
            return self._cluster.routing.epoch
        if self._topology is not None:
            try:
                return int(self._topology()["epoch"])
            except (ConnectionError, OSError, KeyError, TypeError):
                pass
        # piggybacked epochs: the max any shard stamped on a reply
        return max((getattr(sess._backend, "epoch", 0)
                    for _i, sess in self._sessions), default=0)

    def _topology_snapshot(self) -> Optional[Dict]:
        """Current ``{"epoch", "shards", "addresses"}``, or None when
        no discovery path works (static wire shard set)."""
        if self._topology is not None:
            try:
                return self._topology()
            except (ConnectionError, OSError):
                return None
        if self._topology_unsupported:
            return None
        for i, sess in self._sessions:
            if not self._shard_alive(i):
                continue
            probe = getattr(sess._backend, "topology", None)
            if probe is None:                # in-process backend
                self._topology_unsupported = True
                return None
            try:
                reply = probe()
            except (ConnectionError, OSError):
                continue
            if reply is None:                # pre-epoch daemon
                self._topology_unsupported = True
                return None
            return reply
        return None

    def _ensure_sessions(self) -> None:
        """Open child sessions for shards that joined the cluster after
        this session connected (shard add / split)."""
        have = {i for i, _ in self._sessions}
        if self._cluster is not None:
            for i, shard in enumerate(self._cluster.shards):
                if i not in have and self._cluster.alive[i]:
                    self._sessions.append((i, Session(shard.backend())))
            return
        info = self._topology_snapshot()
        if not info:
            return
        for i, addr in enumerate(info.get("addresses") or []):
            if i not in have:
                try:
                    backend = _WireBackend(_parse_address(addr))
                except (ConnectionError, OSError):
                    continue
                self._sessions.append((i, Session(backend)))

    def subscribe(self, subscription: Union[Subscription, str, None] = None,
                  *, resume: Optional[bool] = None,
                  **spec_kwargs) -> FanInStream:
        spec = _make_spec(subscription, spec_kwargs)
        self._ensure_sessions()   # the shard set may have grown since connect
        children = []
        resumed_any = False
        for i, sess in self._sessions:
            if not self._shard_alive(i):
                continue
            if resume:
                # per-shard resume: a durable whose slots migrated (or
                # whose cluster grew) has parked state on *some* shards
                # only — resume where it exists, attach fresh elsewhere,
                # and fail only when no shard resumed at all
                try:
                    child = sess._open(spec, resume=True)
                    resumed_any = True
                except UnknownConsumerError:
                    child = sess._open(spec, resume=None)
            else:
                child = sess._open(spec, resume=resume)
            children.append((i, child))
        if not children:
            raise SessionError("no live shards to subscribe on")
        if resume and not resumed_any:
            for _i, child in children:
                try:
                    child.close()
                except (ConnectionError, OSError):
                    pass
            raise UnknownConsumerError(
                f"no shard holds parked state for durable consumer "
                f"{spec.group}/{spec.name!r}")
        return FanInStream(self, spec, children)

    def resume(self, group: str, name: str, **spec_kwargs) -> FanInStream:
        spec = Subscription(group=group, name=name, **spec_kwargs)
        return self.subscribe(spec, resume=True)

    def stats(self) -> Dict:
        """Summed proxy counters across live shards, plus the raw
        per-shard dicts under ``"per_shard"``."""
        per_shard: Dict[int, Dict] = {}
        total: Dict[str, int] = {}
        for i, sess in self._sessions:
            if not self._shard_alive(i):
                continue
            try:
                st = sess.stats()
            except (ConnectionError, OSError):
                continue
            per_shard[i] = st
            for key, val in st.items():
                if isinstance(val, (int, float)):
                    total[key] = total.get(key, 0) + val
        total["per_shard"] = per_shard
        return total

    def metrics(self) -> Dict:
        """Merged registry snapshots across live shards (counters and
        histograms summed, gauges labeled by shard)."""
        from repro.obs.registry import merge_snapshots
        per_shard = {}
        for i, sess in self._sessions:
            if not self._shard_alive(i):
                continue
            try:
                snap = sess.metrics()
            except (ConnectionError, OSError):
                continue
            if snap:
                per_shard[str(i)] = snap
        return merge_snapshots(per_shard)

    def lag(self) -> Dict:
        """Per-(group, producer) lag aggregated over live shards: lags
        and in-flight sum, ``dispatch_hw`` takes the furthest shard,
        ``ack`` the slowest; per-shard views under ``"per_shard"``."""
        per_shard: Dict[int, Dict] = {}
        merged: Dict[str, Dict] = {}
        for i, sess in self._sessions:
            if not self._shard_alive(i):
                continue
            try:
                shard_lag = sess.lag()
            except (ConnectionError, OSError):
                continue
            per_shard[i] = shard_lag
            for gname, pids in shard_lag.items():
                gout = merged.setdefault(gname, {})
                for pid, ent in pids.items():
                    cur = gout.get(pid)
                    if cur is None:
                        gout[pid] = dict(ent)
                    else:
                        cur["lag"] += ent["lag"]
                        cur["in_flight"] += ent["in_flight"]
                        cur["dispatch_hw"] = max(cur["dispatch_hw"],
                                                 ent["dispatch_hw"])
                        cur["ack"] = min(cur["ack"], ent["ack"])
        merged["per_shard"] = per_shard
        return merged

    def close(self) -> None:
        for _i, sess in self._sessions:
            try:
                sess.close()
            except (ConnectionError, OSError):
                pass

    def __enter__(self) -> "ClusterSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class Session:
    """A connection to one changelog proxy, local or remote.  Make one
    with ``connect``; open any number of subscriptions on it."""

    def __init__(self, backend):
        self._backend = backend
        self._streams: List[Stream] = []

    def subscribe(self, subscription: Union[Subscription, str, None] = None,
                  *, resume: Optional[bool] = None, **spec_kwargs) -> Stream:
        """Open a subscription.  Accepts a ``Subscription`` or builds one
        from kwargs (a plain string is shorthand for the group name).
        A durable name with parked state resumes transparently;
        ``resume=False`` refuses parked state instead (fresh identity or
        error), ``resume=True`` demands it (same as ``resume()``)."""
        return self._open(_make_spec(subscription, spec_kwargs),
                          resume=resume)

    def resume(self, group: str, name: str, **spec_kwargs) -> Stream:
        """Re-attach a durable consumer at its acknowledged cursor.
        Raises ``UnknownConsumerError`` when no parked state exists
        (never attached, expired, or already resumed)."""
        spec = Subscription(group=group, name=name, **spec_kwargs)
        return self._open(spec, resume=True)

    def _open(self, spec: Subscription, resume: Optional[bool]) -> Stream:
        info = self._backend.attach(spec, resume=resume)
        stream = Stream(self, spec, info)
        self._streams.append(stream)
        return stream

    def _forget(self, stream: Stream) -> None:
        if stream in self._streams:
            self._streams.remove(stream)

    def stats(self) -> Dict:
        return self._backend.stats()

    def metrics(self) -> Dict:
        """Typed metrics snapshot from the proxy's attached registry
        (``{}`` when no registry is attached); works over the wire."""
        return self._backend.metrics()

    def lag(self) -> Dict:
        """Per-(group, producer) consumer lag — dispatch watermark
        minus collective ack cursor; see ``LcapProxy.lag``."""
        return self._backend.lag()

    def close(self) -> None:
        try:
            for stream in list(self._streams):
                try:
                    stream.close()
                except OSError:
                    pass    # connection already gone; nothing to undo
        finally:
            self._backend.close()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _parse_address(address) -> Tuple[str, int]:
    if isinstance(address, str):
        host, _, port = address.rpartition(":")
        return (host, int(port))
    return tuple(address)


def connect(target: Union[LcapProxy, "LcapService", "LcapCluster",
                          "LcapClusterService", Address, List[Address]],
            ) -> Union[Session, ClusterSession]:
    """Open a ``Session`` (or, for sharded targets, a ``ClusterSession``
    that transparently fans subscriptions in from every shard) — one
    client API over every binding:

    - ``LcapProxy``                  in-process, single proxy
    - ``LcapService`` / ``(host, port)`` / ``"host:port"``   wire, single
    - ``LcapCluster``                in-process shards, fan-in
    - ``LcapClusterService``         its shard daemons' addresses, fan-in
    - a *list* of addresses          one wire session per shard, fan-in

    Close the session (or use it as a context manager) to release wire
    connections; closing individual streams only deregisters consumers.
    """
    from .cluster import LcapCluster, LcapClusterService
    if isinstance(target, LcapProxy):
        return Session(_LocalBackend(target))
    if isinstance(target, LcapCluster):
        sessions = [(i, Session(shard.backend()))
                    for i, shard in enumerate(target.shards)
                    if target.alive[i]]
        return ClusterSession(sessions, cluster=target)
    if isinstance(target, LcapClusterService):
        return ClusterSession(
            [(i, Session(_WireBackend(_parse_address(a))))
             for i, a in enumerate(target.addresses)],
            topology=target.cluster_info)
    if isinstance(target, list):           # a list of shard addresses
        return ClusterSession(
            [(i, Session(_WireBackend(_parse_address(a))))
             for i, a in enumerate(target)])
    address = getattr(target, "address", target)   # LcapService duck-type
    return Session(_WireBackend(_parse_address(address)))
