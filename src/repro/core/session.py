"""Unified changelog client API: Subscription / Session / Stream.

One consumer-facing surface over both bindings (in-process proxy and
TCP), replacing the ``LocalReader``/``RemoteReader`` split:

- a ``Subscription`` declares *what* to consume: group, optional durable
  consumer name, delivery mode, §IV-A field projection (``flags``) and
  an op-type mask (``types``).  Both filters are pushed down to
  ``LcapProxy._dispatch`` — filtered records are never copied into the
  consumer's outbox, extending the paper's "remote remap" idea from
  fields to whole records;
- a ``Session`` is a connection: ``connect(proxy_or_address)`` returns
  one object with one implementation, backed by either the in-process
  proxy or the wire protocol (``subscribe``/``resume``/``commit``
  verbs, versioned messages);
- a ``Stream`` is a live subscription: iterate it for ``(producer,
  RecordBatch)`` pairs with per-producer cursor tracking and automatic
  batched acknowledgement (commit-on-iterate), or drive ``fetch()`` /
  ``commit()`` explicitly.

Durable consumers (``name=``) survive disconnects: the proxy parks
their unacked records and ack watermark under ``(group, name)``, and
``session.resume(group, name)`` (or a plain ``subscribe`` under the
same name) picks up exactly at the cursor — the stream's
``resume_token`` reports the per-producer watermark that was restored.

    session = lcap.connect(service.address)      # or connect(proxy)
    stream = session.subscribe(
        "ckpt", name="committer-0", types={R.CL_CKPT_WRITE})
    for pid, batch in stream:                    # auto-commits batches
        handle(pid, batch)

Failures surface as typed exceptions (``UnknownConsumerError``,
``SubscriptionError``) on both bindings, never as error strings.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, Iterator, List, Optional, Tuple, Union

from . import records as R
from .errors import (SessionError, SubscriptionError,  # noqa: F401 (re-export)
                     UnknownConsumerError, raise_reply_error)
from .proxy import EPHEMERAL, PERSISTENT, LcapProxy
from .transport import PROTOCOL_VERSION, RpcClient

Address = Union[str, Tuple[str, int]]


@dataclass(frozen=True)
class Subscription:
    """Declarative consumer spec.

    group        consumer group (required for persistent mode)
    name         durable identity within the group; survives disconnects
    mode         PERSISTENT (default) or EPHEMERAL (§IV-B radio semantics)
    flags        CLF_* field projection; None = everything supported
    types        CL_* op-type mask; None = every operation
    auto_commit  iterate-commits-previous-batch (True) vs explicit commit()
    max_records  fetch granularity (records per fetch round)
    """

    group: Optional[str] = None
    name: Optional[str] = None
    mode: str = PERSISTENT
    flags: Optional[int] = None
    types: Optional[frozenset] = None
    auto_commit: bool = True
    max_records: int = 1024

    def __post_init__(self):
        if self.types is not None and not isinstance(self.types, frozenset):
            object.__setattr__(self, "types", frozenset(self.types))
        if self.mode == PERSISTENT and not self.group:
            raise SubscriptionError("persistent subscriptions need a group")
        if self.mode == EPHEMERAL and self.name:
            raise SubscriptionError("ephemeral subscriptions cannot be "
                                    "durable")


# ---------------------------------------------------------------------------
# One Session implementation, two backends.  A backend speaks attach /
# fetch / commit / unsubscribe / disconnect — the in-process one calls
# the proxy directly, the wire one frames the same verbs over TCP.
# ---------------------------------------------------------------------------
class _LocalBackend:
    def __init__(self, proxy: LcapProxy):
        self.proxy = proxy

    def attach(self, spec: Subscription,
               resume: Optional[bool] = None) -> Dict:
        return self.proxy.attach(spec.group, flags=spec.flags,
                                 mode=spec.mode, types=spec.types,
                                 name=spec.name, resume=resume)

    def fetch(self, cid: str, max_records: int,
              ) -> List[Tuple[str, R.RecordBatch]]:
        return self.proxy.fetch_batches(cid, max_records)

    def commit(self, cid: str, acks: Dict[str, List[int]]) -> None:
        self.proxy.commit(cid, acks)

    def unsubscribe(self, cid: str) -> None:
        self.proxy.unsubscribe(cid)

    def disconnect(self, cid: str) -> None:
        self.proxy.disconnect(cid)

    crash = disconnect          # an in-process "connection" just vanishes

    def stats(self) -> Dict:
        return dict(self.proxy.stats)

    def close(self) -> None:
        pass


class _WireBackend:
    def __init__(self, address: Tuple[str, int]):
        self.rpc = RpcClient(address)

    def _call(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        msg.setdefault("v", PROTOCOL_VERSION)
        reply = self.rpc.call(msg)
        raise_reply_error(reply)
        return reply

    def attach(self, spec: Subscription,
               resume: Optional[bool] = None) -> Dict:
        reply = self._call({
            "op": "resume" if resume else "subscribe",
            "group": spec.group, "name": spec.name, "mode": spec.mode,
            "flags": spec.flags, "resume": resume,
            "types": sorted(spec.types) if spec.types is not None else None,
        })
        return {"cid": reply["cid"], "resumed": reply.get("resumed", False),
                "flags": reply.get("flags"),
                "token": reply.get("token") or {}}

    def fetch(self, cid: str, max_records: int,
              ) -> List[Tuple[str, R.RecordBatch]]:
        reply = self._call({"op": "fetch", "cid": cid, "max": max_records})
        return [(pid, R.RecordBatch.from_wire(blob))
                for pid, blob in reply["batches"]]

    def commit(self, cid: str, acks: Dict[str, List[int]]) -> None:
        self._call({"op": "commit", "cid": cid,
                    "acks": {pid: list(ix) for pid, ix in acks.items()}})

    def unsubscribe(self, cid: str) -> None:
        self._call({"op": "close", "cid": cid})

    def disconnect(self, cid: str) -> None:
        self._call({"op": "detach", "cid": cid})

    def crash(self, cid: str) -> None:
        # simulate a crash: drop the socket without deregistering; the
        # service's disconnect hook parks (durable) or fails (anonymous)
        self.rpc.close()

    def stats(self) -> Dict:
        return self._call({"op": "stats"})["stats"]

    def close(self) -> None:
        self.rpc.close()


class Stream:
    """A live subscription: an iterator of ``(producer, RecordBatch)``
    pairs with cursor tracking and batched acknowledgement.

    Iterating auto-commits: each time the stream needs a new fetch
    round, every batch yielded so far is acknowledged in one ``commit``
    call (disable with ``auto_commit=False`` and call ``commit()``
    yourself — at-least-once either way).  Iteration stops when the
    proxy has nothing queued; poll again (or iterate again) later.
    """

    def __init__(self, session: "Session", spec: Subscription, info: Dict):
        self.session = session
        self.spec = spec
        self.cid: str = info["cid"]
        self.resumed: bool = info["resumed"]
        #: producer -> highest acked index (the durable cursor restored
        #: on resume, advanced by every commit)
        self.resume_token: Dict[str, int] = dict(info["token"])
        #: producer -> highest index delivered to the application
        self.cursors: Dict[str, int] = {}
        self._uncommitted: Dict[str, List[int]] = {}
        self._queue: Deque[Tuple[str, R.RecordBatch]] = deque()
        # the proxy reports the *effective* projection (a resumed
        # consumer may have inherited a narrower parked mask); the
        # local remap must match it, not the spec's default
        flags = info.get("flags")
        self._flags = R.normalize_flags(spec.flags if flags is None
                                        else flags)
        self._closed = False

    # -- delivery ------------------------------------------------------------
    def _remap(self, batch: R.RecordBatch) -> R.RecordBatch:
        # local remap: zero-fill requested-but-absent fields (§IV-A)
        return batch.remap(self._flags)

    def _note(self, pid: str, batch: R.RecordBatch) -> None:
        indices = batch.indices()
        if indices:
            # max, not last: a proxy module may reorder within a batch
            self.cursors[pid] = max(self.cursors.get(pid, 0), max(indices))
            if self.spec.mode != EPHEMERAL:
                self._uncommitted.setdefault(pid, []).extend(indices)

    def fetch(self, max_records: Optional[int] = None,
              ) -> List[Tuple[str, R.RecordBatch]]:
        """Explicitly drain up to ``max_records`` queued records; every
        returned batch becomes commit-pending.  Locally requeued batches
        (see ``requeue``) are returned first."""
        cap = max_records or self.spec.max_records
        out, taken = [], 0
        while self._queue and taken < cap:
            pid, batch = self._queue.popleft()
            self._note(pid, batch)
            out.append((pid, batch))
            taken += len(batch)
        if taken < cap:
            for pid, batch in self.session._backend.fetch(self.cid,
                                                          cap - taken):
                batch = self._remap(batch)
                self._note(pid, batch)
                out.append((pid, batch))
        return out

    def __iter__(self) -> Iterator[Tuple[str, R.RecordBatch]]:
        return self

    def __next__(self) -> Tuple[str, R.RecordBatch]:
        if not self._queue:
            if self.spec.auto_commit:
                self.commit()
            for pid, batch in self.session._backend.fetch(
                    self.cid, self.spec.max_records):
                self._queue.append((pid, self._remap(batch)))
            if not self._queue:
                raise StopIteration
        pid, batch = self._queue.popleft()
        self._note(pid, batch)
        return pid, batch

    def records(self) -> Iterator[Tuple[str, R.ChangelogRecord]]:
        """Record-level convenience over the batch iterator."""
        for pid, batch in self:
            for i in range(len(batch)):
                yield pid, batch.record(i)

    # -- acknowledgement -----------------------------------------------------
    @property
    def pending_commit(self) -> int:
        return sum(len(v) for v in self._uncommitted.values())

    def requeue(self, pairs: List[Tuple[str, R.RecordBatch]]) -> None:
        """Return delivered-but-unprocessed batches to the stream (a
        handler failed): they are withdrawn from the commit-pending set
        and handed out again at the front of the next fetch/iteration
        round, so a retrying consumer reprocesses them instead of
        wedging them in flight or acknowledging them unhandled."""
        for pid, batch in reversed(pairs):
            drop = set(batch.indices())
            left = [i for i in self._uncommitted.get(pid, ())
                    if i not in drop]
            if left:
                self._uncommitted[pid] = left
            else:
                self._uncommitted.pop(pid, None)
            self._queue.appendleft((pid, batch))

    def commit(self) -> int:
        """Acknowledge every delivered-but-uncommitted record in one
        call; returns how many were acknowledged.  A failed commit
        keeps the records commit-pending, so a later retry still
        acknowledges them (at-least-once)."""
        if not self._uncommitted:
            return 0
        acks, self._uncommitted = self._uncommitted, {}
        try:
            self.session._backend.commit(self.cid, acks)
        except Exception:
            for pid, indices in acks.items():
                self._uncommitted.setdefault(pid, [])[:0] = indices
            raise
        for pid, indices in acks.items():
            self.resume_token[pid] = max(self.resume_token.get(pid, 0),
                                         max(indices))
        return sum(len(v) for v in acks.values())

    # -- lifecycle -----------------------------------------------------------
    def detach(self) -> None:
        """Let go of the connection but keep the durable identity: a
        later ``resume`` under the same (group, name) continues at the
        cursor.  For anonymous consumers this is a failure (backlog
        redelivered)."""
        if not self._closed:
            self._closed = True
            self.session._backend.disconnect(self.cid)
            self.session._forget(self)

    def close(self, failed: bool = False) -> None:
        """Deregister.  ``failed=True`` simulates a crash instead; on
        the wire binding that drops the Session's socket — taking every
        sibling stream of the same Session down with it, exactly like a
        real process death (use one Session per consumer when streams
        must fail independently)."""
        if self._closed:
            return
        self._closed = True
        if failed:
            self.session._backend.crash(self.cid)
        else:
            self.session._backend.unsubscribe(self.cid)
        self.session._forget(self)


class Session:
    """A connection to one changelog proxy, local or remote.  Make one
    with ``connect``; open any number of subscriptions on it."""

    def __init__(self, backend):
        self._backend = backend
        self._streams: List[Stream] = []

    def subscribe(self, subscription: Union[Subscription, str, None] = None,
                  *, resume: Optional[bool] = None, **spec_kwargs) -> Stream:
        """Open a subscription.  Accepts a ``Subscription`` or builds one
        from kwargs (a plain string is shorthand for the group name).
        A durable name with parked state resumes transparently;
        ``resume=False`` refuses parked state instead (fresh identity or
        error), ``resume=True`` demands it (same as ``resume()``)."""
        if isinstance(subscription, Subscription):
            if spec_kwargs:
                raise SubscriptionError("pass either a Subscription or "
                                        "spec kwargs, not both")
            spec = subscription
        else:
            spec = Subscription(group=subscription, **spec_kwargs)
        return self._open(spec, resume=resume)

    def resume(self, group: str, name: str, **spec_kwargs) -> Stream:
        """Re-attach a durable consumer at its acknowledged cursor.
        Raises ``UnknownConsumerError`` when no parked state exists
        (never attached, expired, or already resumed)."""
        spec = Subscription(group=group, name=name, **spec_kwargs)
        return self._open(spec, resume=True)

    def _open(self, spec: Subscription, resume: Optional[bool]) -> Stream:
        info = self._backend.attach(spec, resume=resume)
        stream = Stream(self, spec, info)
        self._streams.append(stream)
        return stream

    def _forget(self, stream: Stream) -> None:
        if stream in self._streams:
            self._streams.remove(stream)

    def stats(self) -> Dict:
        return self._backend.stats()

    def close(self) -> None:
        try:
            for stream in list(self._streams):
                try:
                    stream.close()
                except OSError:
                    pass    # connection already gone; nothing to undo
        finally:
            self._backend.close()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def connect(target: Union[LcapProxy, "LcapService", Address]) -> Session:
    """Open a ``Session`` against an in-process ``LcapProxy``, a running
    ``LcapService`` (its address is used), a ``(host, port)`` tuple, or
    a ``"host:port"`` string — one client API over both bindings.
    Close the session (or use it as a context manager) to release the
    wire binding's connection; closing individual streams only
    deregisters the consumers."""
    if isinstance(target, LcapProxy):
        return Session(_LocalBackend(target))
    address = getattr(target, "address", target)   # LcapService duck-type
    if isinstance(address, str):
        host, _, port = address.rpartition(":")
        address = (host, int(port))
    return Session(_WireBackend(tuple(address)))
