"""Epoch-versioned routing table — the cluster's slot-ownership plane.

Slot ownership used to live in a mutable list on ``LcapCluster``; every
layer read it in place and nothing could tell *when* it had changed.
``RoutingTable`` makes ownership a first-class immutable snapshot:

- ``slot_owner[s]`` is the shard that owns routing slot ``s`` (the FID
  hash ring of ``fid_slot``); per-target ``cr_prev`` chains never split
  across shards because a target's slot has exactly one owner per epoch.
- ``epoch`` increments on **every** topology change — drain start,
  migration commit/cancel, forced failover reassignment.  The epoch is
  piggybacked on the wire (offer/subscribe/fetch replies, ``caps`` and
  ``topology`` verbs) so consumers detect topology changes from any
  reply instead of assuming a fixed shard set.
- ``draining`` marks slots that are mid-migration (slot → destination
  shard).  A draining slot is still *owned* by its old shard — records
  already offered there keep flowing to consumers — but the coordinator
  parks newly read records for it until the old owner's watermark shows
  the slot's in-flight share fully acknowledged.

The epoch invariant every layer relies on: **within one epoch the
owner of a slot never changes**, and a bump is published before any
record is offered under the new assignment.  A consumer that has seen
epoch ``e`` can therefore cache its shard fan-in until it observes
``e' > e``, then re-resolve once.

Tables are cheap value objects: mutation helpers (:meth:`drain`,
:meth:`commit_drain`, :meth:`cancel_drain`, :meth:`reassign`) return a
new snapshot at ``epoch + 1`` and never touch the receiver, so readers
on other threads keep a coherent view without locking.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Mapping, Tuple

import numpy as np

__all__ = ["RoutingTable"]


class RoutingTable:
    """One immutable snapshot of slot → shard ownership at an epoch."""

    __slots__ = ("epoch", "slot_owner", "draining", "_owner_arr",
                 "_drain_arr")

    def __init__(self, epoch: int, slot_owner: Iterable[int],
                 draining: Mapping[int, int] = ()):
        object.__setattr__(self, "epoch", int(epoch))
        object.__setattr__(self, "slot_owner", tuple(slot_owner))
        object.__setattr__(self, "draining", dict(draining))
        object.__setattr__(self, "_owner_arr", None)
        object.__setattr__(self, "_drain_arr", None)

    def __setattr__(self, name, value):          # immutability guard
        raise AttributeError("RoutingTable is immutable; use drain()/"
                             "commit_drain()/reassign() to derive a new "
                             "epoch")

    # ---------------------------------------------------------- constructors
    @classmethod
    def initial(cls, n_slots: int, n_shards: int) -> "RoutingTable":
        """Epoch 0: slots striped round-robin across the shards."""
        return cls(0, (i % n_shards for i in range(n_slots)))

    # -------------------------------------------------------------- queries
    @property
    def n_slots(self) -> int:
        return len(self.slot_owner)

    def owner_array(self) -> np.ndarray:
        """``slot_owner`` as an int64 array, cached — the table is
        immutable, so the vectorized routing paths (``_partition``,
        ``ClusterReplayReader``) index it without re-materializing."""
        arr = self._owner_arr
        if arr is None:
            arr = np.asarray(self.slot_owner, dtype=np.int64)
            arr.setflags(write=False)
            object.__setattr__(self, "_owner_arr", arr)
        return arr

    def draining_mask(self) -> np.ndarray:
        """Boolean per slot: True while the slot is mid-migration."""
        arr = self._drain_arr
        if arr is None:
            arr = np.zeros(len(self.slot_owner), dtype=bool)
            if self.draining:
                arr[list(self.draining)] = True
            arr.setflags(write=False)
            object.__setattr__(self, "_drain_arr", arr)
        return arr

    def slots_of(self, shard: int) -> Tuple[int, ...]:
        """The slots shard ``shard`` currently owns."""
        return tuple(s for s, o in enumerate(self.slot_owner) if o == shard)

    def counts(self, n_shards: int) -> List[int]:
        """Slots owned per shard (for balance decisions and gauges)."""
        owned = [0] * n_shards
        for o in self.slot_owner:
            owned[o] += 1
        return owned

    def describe(self) -> Dict:
        """Wire-friendly summary for ``topology`` replies and debugging."""
        return {"epoch": self.epoch, "n_slots": len(self.slot_owner),
                "draining": len(self.draining)}

    # ------------------------------------------------------------ evolution
    def bumped(self) -> "RoutingTable":
        """Epoch+1 with ownership and draining unchanged — announces a
        topology event that moved no slots (e.g. a shard joined with
        zero slots) so consumers re-resolve the shard set."""
        return RoutingTable(self.epoch + 1, self.slot_owner, self.draining)

    def drain(self, slots: Iterable[int], target: int) -> "RoutingTable":
        """Epoch+1 with ``slots`` marked draining toward ``target``.
        Ownership is unchanged — the old owner keeps serving what it
        already ingested while new offers for these slots park."""
        draining = dict(self.draining)
        for s in slots:
            draining[int(s)] = int(target)
        return RoutingTable(self.epoch + 1, self.slot_owner, draining)

    def commit_drain(self) -> "RoutingTable":
        """Epoch+1 with every draining slot handed to its destination
        and the draining set cleared — the migration commit point."""
        owner = list(self.slot_owner)
        for s, tgt in self.draining.items():
            owner[s] = tgt
        return RoutingTable(self.epoch + 1, owner)

    def cancel_drain(self) -> "RoutingTable":
        """Epoch+1 with the draining set cleared and ownership
        unchanged (migration aborted, e.g. its target died)."""
        return RoutingTable(self.epoch + 1, self.slot_owner)

    def reassign(self, mapping: Mapping[int, int]) -> "RoutingTable":
        """Epoch+1 with ``mapping`` (slot → new owner) applied directly
        — the forced path (failover), which cannot wait for a drain.
        Any draining marks on the reassigned slots are dropped."""
        owner = list(self.slot_owner)
        draining = dict(self.draining)
        for s, o in mapping.items():
            owner[int(s)] = int(o)
            draining.pop(int(s), None)
        return RoutingTable(self.epoch + 1, owner, draining)

    def __repr__(self) -> str:                   # pragma: no cover
        return (f"RoutingTable(epoch={self.epoch}, "
                f"n_slots={len(self.slot_owner)}, "
                f"draining={len(self.draining)})")
