"""Changelog consumer client API (paper §II's four-phase loop).

    1) start (register with a group / as ephemeral, express flags)
    2) receive/consume records
    3) acknowledge (may be delayed and batched)
    4) stop (deregister)

Two bindings share one interface:
- ``LocalReader`` talks to an in-process ``LcapProxy``;
- ``RemoteReader`` talks to an ``LcapService`` over TCP (server.py).

The client performs the *local* half of record remapping: fields the
consumer requested but the record (as stripped by the proxy) does not
carry are zero-filled locally (§IV-A).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from . import records as R
from .proxy import EPHEMERAL, PERSISTENT, LcapProxy
from .transport import RpcClient


class _Base:
    flags: int

    def _remap_local(self, buf: bytes) -> R.ChangelogRecord:
        # local remap: add (zero-fill) missing requested fields
        return R.unpack(R.remap(buf, self.flags))


class LocalReader(_Base):
    def __init__(self, proxy: LcapProxy, group: Optional[str],
                 flags: int = R.CLF_SUPPORTED, mode: str = PERSISTENT):
        self.proxy = proxy
        self.flags = flags & R.CLF_SUPPORTED
        self.cid = proxy.subscribe(group, flags, mode)
        self.mode = mode

    def fetch(self, max_records: int = 256) -> List[Tuple[str, R.ChangelogRecord]]:
        out = []
        for pid, idx, buf in self.proxy.fetch(self.cid, max_records):
            rec = self._remap_local(buf)
            rec.index = idx
            out.append((pid, rec))
        return out

    def ack(self, pid: str, index: int) -> None:
        self.proxy.ack(self.cid, pid, index)

    def close(self, failed: bool = False) -> None:
        self.proxy.unsubscribe(self.cid, failed=failed)


class RemoteReader(_Base):
    def __init__(self, address, group: Optional[str],
                 flags: int = R.CLF_SUPPORTED, mode: str = PERSISTENT):
        self.rpc = RpcClient(address)
        self.flags = flags & R.CLF_SUPPORTED
        reply = self.rpc.call({"op": "register", "group": group,
                               "flags": self.flags, "mode": mode})
        if reply.get("err"):
            raise RuntimeError(reply["err"])
        self.cid = reply["cid"]
        self.mode = mode

    def fetch(self, max_records: int = 256) -> List[Tuple[str, R.ChangelogRecord]]:
        reply = self.rpc.call({"op": "fetch", "cid": self.cid,
                               "max": max_records})
        out = []
        for pid, idx, buf in reply["recs"]:
            rec = self._remap_local(buf)
            rec.index = idx
            out.append((pid, rec))
        return out

    def ack(self, pid: str, index: int) -> None:
        self.rpc.call({"op": "ack", "cid": self.cid, "pid": pid,
                       "index": index})

    def close(self, failed: bool = False) -> None:
        if failed:
            # simulate a crash: drop the socket without deregistering;
            # the service's disconnect hook triggers redelivery
            self.rpc.close()
            return
        try:
            self.rpc.call({"op": "close", "cid": self.cid})
        finally:
            self.rpc.close()
