"""DEPRECATED changelog reader shims — use ``session.connect`` instead.

``LocalReader``/``RemoteReader`` were the seed's split consumer
bindings (paper §II's four-phase loop as raw plumbing: register, fetch,
ack, stop).  They survive as thin shims over the one ``Session``
backend so existing callers keep working, but new code should speak the
declarative API:

    session = connect(proxy_or_address)
    stream = session.subscribe(group, flags=..., types=...)

See ``session.py`` for the subscription contract (durable consumers,
op-type pushdown, auto-committing streams) and ARCHITECTURE.md for the
old-call -> new-call migration table.
"""

from __future__ import annotations

from typing import Iterable, List, Optional, Tuple

from . import records as R
from .proxy import EPHEMERAL, PERSISTENT, LcapProxy  # noqa: F401 (re-export)
from .session import Subscription, connect


class _ReaderShim:
    """Shared deprecated reader surface over a Session backend."""

    def __init__(self, target, group: Optional[str], flags: Optional[int],
                 mode: str):
        self._session = connect(target)
        self._backend = self._session._backend
        self.flags = R.normalize_flags(flags)
        info = self._backend.attach(
            Subscription(group=group, mode=mode, flags=flags))
        self.cid = info["cid"]
        self.mode = mode

    def fetch_batches(self, max_records: int = 256,
                      ) -> List[Tuple[str, R.RecordBatch]]:
        # local remap: add (zero-fill) missing requested fields (§IV-A)
        return [(pid, batch.remap(self.flags))
                for pid, batch in self._backend.fetch(self.cid, max_records)]

    # record-level convenience over the batch path ---------------------------
    def fetch(self, max_records: int = 256,
              ) -> List[Tuple[str, R.ChangelogRecord]]:
        return [(pid, batch.record(i))
                for pid, batch in self.fetch_batches(max_records)
                for i in range(len(batch))]

    def ack(self, pid: str, index: int) -> None:
        self._backend.commit(self.cid, {pid: [index]})

    def ack_batch(self, pid: str, indices: Iterable[int]) -> None:
        self._backend.commit(self.cid, {pid: list(indices)})

    def close(self, failed: bool = False) -> None:
        if failed:
            # simulate a crash: the connection just drops; the proxy's
            # disconnect handling redelivers (or parks durable state)
            self._backend.crash(self.cid)
        else:
            try:
                self._backend.unsubscribe(self.cid)
            finally:
                self._backend.close()


class LocalReader(_ReaderShim):
    def __init__(self, proxy: LcapProxy, group: Optional[str],
                 flags: Optional[int] = None, mode: str = PERSISTENT):
        super().__init__(proxy, group, flags, mode)
        self.proxy = proxy


class RemoteReader(_ReaderShim):
    def __init__(self, address, group: Optional[str],
                 flags: Optional[int] = None, mode: str = PERSISTENT):
        # connect() accepts (host, port) and "host:port" alike
        super().__init__(address, group, flags, mode)
        self.rpc = self._backend.rpc
