"""Changelog consumer client API (paper §II's four-phase loop).

    1) start (register with a group / as ephemeral, express flags)
    2) receive/consume records
    3) acknowledge (may be delayed and batched)
    4) stop (deregister)

Two bindings share one interface:
- ``LocalReader`` talks to an in-process ``LcapProxy``;
- ``RemoteReader`` talks to an ``LcapService`` over TCP (server.py).

Both move whole ``RecordBatch``es: ``fetch_batches()`` returns
``(producer, RecordBatch)`` pairs (one wire frame per batch for the
remote binding), and ``fetch()`` is the record-level convenience view
over the same path.  ``ack_batch()`` acknowledges a whole batch in one
call/RPC.

The client performs the *local* half of record remapping: fields the
consumer requested but the record (as stripped by the proxy) does not
carry are zero-filled locally (§IV-A) — per batch, through the remap
plan cache.
"""

from __future__ import annotations

from typing import Iterable, List, Optional, Tuple

from . import records as R
from .proxy import EPHEMERAL, PERSISTENT, LcapProxy
from .transport import RpcClient


class _Base:
    flags: int

    def _remap_local(self, batch: R.RecordBatch) -> R.RecordBatch:
        # local remap: add (zero-fill) missing requested fields
        return batch.remap(self.flags)

    def _flatten(self, batches: List[Tuple[str, R.RecordBatch]],
                 ) -> List[Tuple[str, R.ChangelogRecord]]:
        out = []
        for pid, batch in batches:
            for i in range(len(batch)):
                rec = batch.record(i)
                out.append((pid, rec))
        return out

    # record-level convenience over the batch path ---------------------------
    def fetch(self, max_records: int = 256,
              ) -> List[Tuple[str, R.ChangelogRecord]]:
        return self._flatten(self.fetch_batches(max_records))

    def fetch_batches(self, max_records: int = 256,
                      ) -> List[Tuple[str, R.RecordBatch]]:
        raise NotImplementedError

    def ack_batch(self, pid: str, indices: Iterable[int]) -> None:
        raise NotImplementedError


class LocalReader(_Base):
    def __init__(self, proxy: LcapProxy, group: Optional[str],
                 flags: int = R.CLF_SUPPORTED, mode: str = PERSISTENT):
        self.proxy = proxy
        self.flags = flags & R.CLF_SUPPORTED
        self.cid = proxy.subscribe(group, flags, mode)
        self.mode = mode

    def fetch_batches(self, max_records: int = 256,
                      ) -> List[Tuple[str, R.RecordBatch]]:
        return [(pid, self._remap_local(batch))
                for pid, batch in self.proxy.fetch_batches(self.cid,
                                                           max_records)]

    def ack(self, pid: str, index: int) -> None:
        self.proxy.ack(self.cid, pid, index)

    def ack_batch(self, pid: str, indices: Iterable[int]) -> None:
        self.proxy.ack_batch(self.cid, pid, list(indices))

    def close(self, failed: bool = False) -> None:
        self.proxy.unsubscribe(self.cid, failed=failed)


class RemoteReader(_Base):
    def __init__(self, address, group: Optional[str],
                 flags: int = R.CLF_SUPPORTED, mode: str = PERSISTENT):
        self.rpc = RpcClient(address)
        self.flags = flags & R.CLF_SUPPORTED
        reply = self.rpc.call({"op": "register", "group": group,
                               "flags": self.flags, "mode": mode})
        if reply.get("err"):
            raise RuntimeError(reply["err"])
        self.cid = reply["cid"]
        self.mode = mode

    def fetch_batches(self, max_records: int = 256,
                      ) -> List[Tuple[str, R.RecordBatch]]:
        reply = self.rpc.call({"op": "fetch", "cid": self.cid,
                               "max": max_records})
        if reply.get("err"):
            raise RuntimeError(reply["err"])
        return [(pid, self._remap_local(R.RecordBatch.from_wire(blob)))
                for pid, blob in reply["batches"]]

    def ack(self, pid: str, index: int) -> None:
        self.rpc.call({"op": "ack", "cid": self.cid, "pid": pid,
                       "index": index})

    def ack_batch(self, pid: str, indices: Iterable[int]) -> None:
        self.rpc.call({"op": "ack_batch", "cid": self.cid, "pid": pid,
                       "indices": list(indices)})

    def close(self, failed: bool = False) -> None:
        if failed:
            # simulate a crash: drop the socket without deregistering;
            # the service's disconnect hook triggers redelivery
            self.rpc.close()
            return
        try:
            self.rpc.call({"op": "close", "cid": self.cid})
        finally:
            self.rpc.close()
