"""Stream pre-processing modules (paper §III-A).

The paper's proxy loads shared-library modules that pre-process the
record stream before redistribution — e.g. "records can be dropped for
operations that compensate each other (creat/unlink) or re-ordered to
optimize downchain processing".  Same contract here, but the unit of
flow is a ``RecordBatch``: a module is a callable ``batch -> batch``
that inspects only the header *columns* it needs (type, target fid,
index — read zero-copy out of the packed buffer) and restructures the
batch with ``select``/``permute`` views.  No record is ever fully
decoded, repacked, or copied by a module.

For compatibility (and unit testing), every module also accepts a plain
``list[ChangelogRecord]`` and returns a list; the selection logic is
shared between both representations.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Dict, Iterable, List, Sequence, Set, Tuple, Union

import numpy as np

from . import records as R

Batch = Union[R.RecordBatch, List[R.ChangelogRecord]]


def _types(batch: Batch) -> List[int]:
    if isinstance(batch, R.RecordBatch):
        return batch.types()
    return [r.type for r in batch]


def _keys(batch: Batch) -> List[Tuple[int, int, int]]:
    if isinstance(batch, R.RecordBatch):
        return batch.keys()
    return [r.key() for r in batch]


def _indices(batch: Batch) -> List[int]:
    if isinstance(batch, R.RecordBatch):
        return batch.indices()
    return [r.index for r in batch]


def _take(batch: Batch, rows: Sequence[int]) -> Batch:
    """Rows ``rows`` of ``batch``, in order — a zero-copy view for a
    ``RecordBatch``, a plain sub-list otherwise."""
    if isinstance(batch, R.RecordBatch):
        return batch.select(rows)
    return [batch[i] for i in rows]


class CancelCompensating:
    """Drop (CREAT, UNLNK) pairs on the same target within a batch —
    the paper's canonical example.  Extended with the training-event
    analogue: a CKPT_WRITE superseded by a newer CKPT_WRITE of the same
    shard within the batch (only the latest write matters to the
    committer, exactly like creat/unlink compensating each other)."""

    CANCEL = {(R.CL_CREATE, R.CL_UNLINK), (R.CL_MKDIR, R.CL_RMDIR)}

    def __init__(self, supersede_ckpt: bool = True):
        self.supersede_ckpt = supersede_ckpt
        self._destroy_of = {d: c for c, d in self.CANCEL}

    def __call__(self, batch: Batch) -> Batch:
        if isinstance(batch, R.RecordBatch):
            # column precheck: a drop needs a destroy op (to pair with
            # an earlier create) or >1 checkpoint write — the vast
            # majority of batches have neither and pass through with
            # two vectorized scans and no per-record work
            t = batch.types_np()
            destroys = sorted(d for _, d in self.CANCEL)
            interesting = bool(np.isin(t, destroys).any())
            if not interesting and self.supersede_ckpt:
                interesting = int((t == R.CL_CKPT_WRITE).sum()) > 1
            if not interesting:
                return batch
        types, keys = _types(batch), _keys(batch)
        drop: Set[int] = set()
        open_by_key: Dict[tuple, List[int]] = defaultdict(list)
        creates = {c for c, _ in self.CANCEL}
        for i, t in enumerate(types):
            if t in creates:
                open_by_key[(keys[i], t)].append(i)
            else:
                c = self._destroy_of.get(t)
                if c is not None and open_by_key.get((keys[i], c)):
                    drop.add(open_by_key[(keys[i], c)].pop())
                    drop.add(i)
        if self.supersede_ckpt:
            last: Dict[tuple, int] = {}
            for i, t in enumerate(types):
                if t == R.CL_CKPT_WRITE:
                    k = keys[i][:2]            # (run, shard) identity
                    if k in last:
                        drop.add(last[k])
                    last[k] = i
        if not drop:
            return batch
        return _take(batch, [i for i in range(len(types)) if i not in drop])


class ReorderByTarget:
    """Stable-sort a batch by target fid then index, so a downstream
    consumer touching per-object state (robinhood's DB rows) gets runs of
    records on the same object — 'reordered to optimize downchain
    processing'."""

    def __call__(self, batch: Batch) -> Batch:
        if isinstance(batch, R.RecordBatch):
            seq, oid, ver = batch.tfid_cols()
            order = np.lexsort((batch.indices_np(), ver, oid, seq))
            if bool((order[1:] > order[:-1]).all()):
                return batch               # a sorted permutation is identity
            return batch.select(order)
        keys, indices = _keys(batch), _indices(batch)
        order = sorted(range(len(keys)),
                       key=lambda i: (keys[i], indices[i]))
        if order == list(range(len(keys))):
            return batch
        return _take(batch, order)


class TypeFilter:
    """Keep only the requested operation types (the administrator 'can
    select which operations to log' — the proxy can narrow further)."""

    def __init__(self, keep: Iterable[int]):
        self.keep = set(keep)
        self._keep_arr = np.array(sorted(self.keep), dtype=np.int64)

    def __call__(self, batch: Batch) -> Batch:
        if isinstance(batch, R.RecordBatch):
            mask = np.isin(batch.types_np(), self._keep_arr)
            if bool(mask.all()):
                return batch
            return batch.select(np.flatnonzero(mask))
        types = _types(batch)
        rows = [i for i, t in enumerate(types) if t in self.keep]
        if len(rows) == len(types):
            return batch
        return _take(batch, rows)


class CoalesceHeartbeats:
    """Keep only the newest heartbeat per host within a batch (liveness
    is level-triggered; history adds nothing downstream)."""

    def __call__(self, batch: Batch) -> Batch:
        if isinstance(batch, R.RecordBatch):
            t = batch.types_np()
            hb = np.flatnonzero(t == R.CL_HEARTBEAT)
            if hb.size <= 1:
                return batch
            host = batch.tfid_cols()[1][hb]    # oid = host id
            # first occurrence in the reversed host column is the last
            # heartbeat of that host in batch order
            _, first_rev = np.unique(host[::-1], return_index=True)
            mask = np.ones(len(batch), dtype=bool)
            mask[hb] = False
            mask[hb[hb.size - 1 - first_rev]] = True
            if bool(mask.all()):
                return batch
            return batch.select(np.flatnonzero(mask))
        types = _types(batch)
        last: Dict[int, int] = {}
        keys = None
        for i, t in enumerate(types):
            if t == R.CL_HEARTBEAT:
                if keys is None:
                    keys = _keys(batch)        # only when heartbeats exist
                last[keys[i][1]] = i           # oid = host id
        if not last:
            return batch
        rows = [i for i, t in enumerate(types)
                if t != R.CL_HEARTBEAT or last[keys[i][1]] == i]
        if len(rows) == len(types):
            return batch
        return _take(batch, rows)
