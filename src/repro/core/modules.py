"""Stream pre-processing modules (paper §III-A).

The paper's proxy loads shared-library modules that pre-process the
record stream before redistribution — e.g. "records can be dropped for
operations that compensate each other (creat/unlink) or re-ordered to
optimize downchain processing".  Same contract here: a module is a
callable ``batch -> batch`` over parsed records, composed in order.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Dict, Iterable, List, Set

from . import records as R

Batch = List[R.ChangelogRecord]


class CancelCompensating:
    """Drop (CREAT, UNLNK) pairs on the same target within a batch —
    the paper's canonical example.  Extended with the training-event
    analogue: a CKPT_WRITE superseded by a newer CKPT_WRITE of the same
    shard within the batch (only the latest write matters to the
    committer, exactly like creat/unlink compensating each other)."""

    CANCEL = {(R.CL_CREATE, R.CL_UNLINK), (R.CL_MKDIR, R.CL_RMDIR)}

    def __init__(self, supersede_ckpt: bool = True):
        self.supersede_ckpt = supersede_ckpt

    def __call__(self, batch: Batch) -> Batch:
        drop: Set[int] = set()
        open_by_key: Dict[tuple, List[int]] = defaultdict(list)
        for i, rec in enumerate(batch):
            k = rec.key()
            for create_t, destroy_t in self.CANCEL:
                if rec.type == create_t:
                    open_by_key[(k, create_t)].append(i)
                elif rec.type == destroy_t and open_by_key.get((k, create_t)):
                    j = open_by_key[(k, create_t)].pop()
                    drop.add(i)
                    drop.add(j)
        if self.supersede_ckpt:
            last: Dict[tuple, int] = {}
            for i, rec in enumerate(batch):
                if rec.type == R.CL_CKPT_WRITE:
                    k = (rec.tfid.seq, rec.tfid.oid)   # shard identity
                    if k in last:
                        drop.add(last[k])
                    last[k] = i
        return [r for i, r in enumerate(batch) if i not in drop]


class ReorderByTarget:
    """Stable-sort a batch by target fid then index, so a downstream
    consumer touching per-object state (robinhood's DB rows) gets runs of
    records on the same object — 'reordered to optimize downchain
    processing'."""

    def __call__(self, batch: Batch) -> Batch:
        return sorted(batch, key=lambda r: (r.tfid.seq, r.tfid.oid,
                                            r.tfid.ver, r.index))


class TypeFilter:
    """Keep only the requested operation types (the administrator 'can
    select which operations to log' — the proxy can narrow further)."""

    def __init__(self, keep: Iterable[int]):
        self.keep = set(keep)

    def __call__(self, batch: Batch) -> Batch:
        return [r for r in batch if r.type in self.keep]


class CoalesceHeartbeats:
    """Keep only the newest heartbeat per host within a batch (liveness
    is level-triggered; history adds nothing downstream)."""

    def __call__(self, batch: Batch) -> Batch:
        last: Dict[int, int] = {}
        for i, rec in enumerate(batch):
            if rec.type == R.CL_HEARTBEAT:
                last[rec.tfid.oid] = i
        return [r for i, r in enumerate(batch)
                if r.type != R.CL_HEARTBEAT or last[r.tfid.oid] == i]
