"""Extensible changelog record format (paper §IV-A, LU-1996).

Faithful reimplementation of the Lustre 2.7 ``struct changelog_rec`` layout:

    fixed header (64 B):
        cr_namelen  u16     length of trailing name (bytes, no NUL)
        cr_flags    u16     extension mask (CLF_*) | high bits reserved
        cr_type     u16     operation code (CL_*)
        <2 B pad>
        cr_index    u64     record index within its producer's llog
        cr_prev     u64     index of the previous record touching cr_tfid
        cr_time     u64     nanoseconds since epoch
        cr_tfid     fid     target object (seq u64, oid u32, ver u32)
        cr_pfid     fid     parent object
    optional, flag-gated, in canonical order:
        CLF_RENAME  -> cr_sfid (16 B) + cr_spfid (16 B)
        CLF_JOBID   -> cr_jobid (32 B, NUL padded)
        CLF_SHARD   -> pod u16, host u16, mesh_row u16, mesh_col u16
        CLF_METRICS -> count u16 + count * f64
        CLF_XATTR   -> len u32 + msgpack blob
    variable tail:
        name  (cr_namelen B)
        CLF_RENAME -> NUL + sname (to end of record)

Field access is by *inline offset computation from the flags mask*
(``_offset_after``), exactly as the paper describes — a record never
stores empty space for fields it does not carry.

``remap()`` converts a packed record between flag sets: adding fields
fills them with zeros (the "recent client, older server" direction, done
*locally* at the client); removing fields strips them (the "older client,
newer server" direction, done *remotely* at the proxy to save
bandwidth).  Both directions preserve every field present in both masks.
"""

from __future__ import annotations

import struct
import time as _time
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple, Union)

import msgpack
import numpy as np

# ---------------------------------------------------------------------------
# Operation types.  CL_* codes 0..13 mirror Lustre; >=32 are the training
# event extensions this framework layers on top (same record machinery).
# ---------------------------------------------------------------------------
CL_MARK = 0
CL_CREATE = 1
CL_MKDIR = 2
CL_HARDLINK = 3
CL_SOFTLINK = 4
CL_MKNOD = 5
CL_UNLINK = 6
CL_RMDIR = 7
CL_RENAME = 8
CL_EXT = 9          # rename target (legacy two-record form, pre LU-1331)
CL_OPEN = 10
CL_CLOSE = 11
CL_SETATTR = 13

# Training-event extension types (the framework's "metadata operations").
CL_STEP_COMMIT = 32      # a training step committed on a host
CL_CKPT_WRITE = 33       # one checkpoint shard persisted
CL_CKPT_COMMIT = 34      # full checkpoint committed (all shards seen)
CL_DATA_CONSUME = 35     # a data shard/batch range consumed
CL_HEARTBEAT = 36        # liveness + step-duration sample
CL_ELASTIC_JOIN = 37     # host/pod joined the mesh
CL_ELASTIC_LEAVE = 38    # host/pod left (failure or scale-down)
CL_STRAGGLER = 39        # straggler verdict for a host
CL_EVICT = 40            # cache invalidation notice (Ganesha analogue)

# Policy-action lifecycle types (the HSM hsm/actions analogue): a policy
# engine emits these *into* the changelog fabric, so actions are
# themselves a stream any consumer can subscribe to with pushdown.
# tfid is the TARGET object's fid (not an action id), so one action's
# whole NEW -> UPDATE -> COMPLETED -> PURGED chain shares the target's
# cr_prev chain and — under FID-hash cluster routing — one shard.
CL_ACTION_NEW = 41       # a policy rule matched: action enqueued
CL_ACTION_UPDATE = 42    # action state advanced (e.g. started)
CL_ACTION_COMPLETED = 43  # action finished (status: succeeded/failed)
CL_ACTION_PURGED = 44    # janitor trimmed the completed action chain

CL_LAST = 45

#: the action-lifecycle subset (subscription masks, reconciler replay)
CL_ACTION_TYPES = frozenset({CL_ACTION_NEW, CL_ACTION_UPDATE,
                             CL_ACTION_COMPLETED, CL_ACTION_PURGED})

TYPE_NAMES = {
    CL_MARK: "MARK", CL_CREATE: "CREAT", CL_MKDIR: "MKDIR",
    CL_HARDLINK: "HLINK", CL_SOFTLINK: "SLINK", CL_MKNOD: "MKNOD",
    CL_UNLINK: "UNLNK", CL_RMDIR: "RMDIR", CL_RENAME: "RENME",
    CL_EXT: "EXT", CL_OPEN: "OPEN", CL_CLOSE: "CLOSE", CL_SETATTR: "SATTR",
    CL_STEP_COMMIT: "STEP", CL_CKPT_WRITE: "CKPTW", CL_CKPT_COMMIT: "CKPTC",
    CL_DATA_CONSUME: "DATA", CL_HEARTBEAT: "HBEAT", CL_ELASTIC_JOIN: "EJOIN",
    CL_ELASTIC_LEAVE: "ELEAV", CL_STRAGGLER: "STRAG", CL_EVICT: "EVICT",
    CL_ACTION_NEW: "ACTNW", CL_ACTION_UPDATE: "ACTUP",
    CL_ACTION_COMPLETED: "ACTOK", CL_ACTION_PURGED: "ACTPG",
}

# ---------------------------------------------------------------------------
# Extension flags (canonical order == wire order).
# ---------------------------------------------------------------------------
CLF_RENAME = 0x0001
CLF_JOBID = 0x0002
CLF_SHARD = 0x0004
CLF_METRICS = 0x0008
CLF_XATTR = 0x0010

CLF_SUPPORTED = CLF_RENAME | CLF_JOBID | CLF_SHARD | CLF_METRICS | CLF_XATTR
# Flag masks of the historical formats (fig. 3)
CLF_V20 = 0x0000                 # struct changelog_rec (v2.0)
CLF_EXT_REC = CLF_RENAME         # struct changelog_ext_rec
CLF_V27 = CLF_RENAME | CLF_JOBID  # struct changelog_rec (v2.7)

_HDR = struct.Struct("<HHHxxQQQ")          # namelen, flags, type, index, prev, time
_FID = struct.Struct("<QII")               # seq, oid, ver
HDR_SIZE = _HDR.size + 2 * _FID.size       # 64
assert HDR_SIZE == 64

_JOBID_LEN = 32
_SHARD = struct.Struct("<HHHH")


@dataclass(frozen=True)
class Fid:
    """Object identifier: (sequence, object id, version).

    In the framework: seq = run id, oid = object id (host, shard, tensor,
    batch-range...), ver = version/step.
    """
    seq: int = 0
    oid: int = 0
    ver: int = 0

    def pack(self) -> bytes:
        return _FID.pack(self.seq, self.oid, self.ver)

    @staticmethod
    def unpack(buf: bytes, off: int = 0) -> "Fid":
        return Fid(*_FID.unpack_from(buf, off))


NULL_FID = Fid()


@dataclass
class ChangelogRecord:
    type: int = CL_MARK
    index: int = 0
    prev: int = 0
    time: int = 0
    tfid: Fid = NULL_FID
    pfid: Fid = NULL_FID
    name: bytes = b""
    # flag-gated extensions
    sfid: Optional[Fid] = None           # CLF_RENAME
    spfid: Optional[Fid] = None
    sname: bytes = b""                   # rename source name (tail)
    jobid: Optional[bytes] = None        # CLF_JOBID (<=32 B)
    shard: Optional[Tuple[int, int, int, int]] = None  # CLF_SHARD
    metrics: Optional[Tuple[float, ...]] = None        # CLF_METRICS
    xattr: Optional[Dict[str, Any]] = None             # CLF_XATTR

    @property
    def flags(self) -> int:
        f = 0
        if self.sfid is not None:
            f |= CLF_RENAME
        if self.jobid is not None:
            f |= CLF_JOBID
        if self.shard is not None:
            f |= CLF_SHARD
        if self.metrics is not None:
            f |= CLF_METRICS
        if self.xattr is not None:
            f |= CLF_XATTR
        return f

    @property
    def type_name(self) -> str:
        return TYPE_NAMES.get(self.type, f"?{self.type}")

    def key(self) -> Tuple[int, int, int]:
        """Identity of the target object (used by compaction modules)."""
        return (self.tfid.seq, self.tfid.oid, self.tfid.ver)

    def __str__(self) -> str:  # lfs changelog-like rendering
        return (f"{self.index} {self.type:02d}{self.type_name} "
                f"t=[{self.tfid.seq:#x}:{self.tfid.oid:#x}:{self.tfid.ver:#x}] "
                f"p=[{self.pfid.seq:#x}:{self.pfid.oid:#x}:{self.pfid.ver:#x}] "
                f"{self.name.decode(errors='replace')}")


def now_ns() -> int:
    return _time.time_ns()


# ---------------------------------------------------------------------------
# Offset computation (the LU-1996 inline functions).
# ---------------------------------------------------------------------------
def _ext_sizes(flags: int, buf: Optional[bytes] = None, base: int = 0):
    """Yield (flag, size) for each extension present in ``flags``.

    CLF_METRICS / CLF_XATTR are variable: when ``buf`` is given, sizes are
    read from the wire; otherwise they cannot be computed (callers that
    only add/strip fixed fields never need them without a buffer).
    """
    off = base
    if flags & CLF_RENAME:
        yield CLF_RENAME, 2 * _FID.size
        off += 2 * _FID.size
    if flags & CLF_JOBID:
        yield CLF_JOBID, _JOBID_LEN
        off += _JOBID_LEN
    if flags & CLF_SHARD:
        yield CLF_SHARD, _SHARD.size
        off += _SHARD.size
    if flags & CLF_METRICS:
        if buf is None:
            raise ValueError("CLF_METRICS size needs the buffer")
        (cnt,) = struct.unpack_from("<H", buf, off)
        sz = 2 + 8 * cnt
        yield CLF_METRICS, sz
        off += sz
    if flags & CLF_XATTR:
        if buf is None:
            raise ValueError("CLF_XATTR size needs the buffer")
        (ln,) = struct.unpack_from("<I", buf, off)
        yield CLF_XATTR, 4 + ln


def rec_offset(flags: int, upto: int, buf: Optional[bytes] = None) -> int:
    """Offset of extension ``upto`` (or of the name tail if upto==0)
    within a record carrying ``flags`` — the paper's inline offset
    computation."""
    off = HDR_SIZE
    for flag, size in _ext_sizes(flags, buf, HDR_SIZE):
        if flag == upto:
            return off
        off += size
    if upto:
        raise KeyError(f"flag {upto:#x} not in mask {flags:#x}")
    return off


def pack(rec: ChangelogRecord) -> bytes:
    """Serialize to the wire format described in the module docstring."""
    flags = rec.flags
    parts = [
        _HDR.pack(len(rec.name), flags, rec.type, rec.index, rec.prev,
                  rec.time),
        rec.tfid.pack(), rec.pfid.pack(),
    ]
    if flags & CLF_RENAME:
        parts.append(rec.sfid.pack())
        parts.append((rec.spfid or NULL_FID).pack())
    if flags & CLF_JOBID:
        jb = (rec.jobid or b"")[:_JOBID_LEN]
        parts.append(jb.ljust(_JOBID_LEN, b"\0"))
    if flags & CLF_SHARD:
        parts.append(_SHARD.pack(*rec.shard))
    if flags & CLF_METRICS:
        vals = rec.metrics or ()
        parts.append(struct.pack(f"<H{len(vals)}d", len(vals), *vals))
    if flags & CLF_XATTR:
        blob = msgpack.packb(rec.xattr or {})
        parts.append(struct.pack("<I", len(blob)) + blob)
    parts.append(rec.name)
    if flags & CLF_RENAME:
        parts.append(b"\0" + rec.sname)
    return b"".join(parts)


def unpack(buf: bytes) -> ChangelogRecord:
    namelen, flags, rtype, index, prev, tns = _HDR.unpack_from(buf, 0)
    tfid = Fid.unpack(buf, _HDR.size)
    pfid = Fid.unpack(buf, _HDR.size + _FID.size)
    rec = ChangelogRecord(type=rtype, index=index, prev=prev, time=tns,
                          tfid=tfid, pfid=pfid)
    off = HDR_SIZE
    if flags & CLF_RENAME:
        rec.sfid = Fid.unpack(buf, off)
        rec.spfid = Fid.unpack(buf, off + _FID.size)
        off += 2 * _FID.size
    if flags & CLF_JOBID:
        rec.jobid = buf[off:off + _JOBID_LEN].rstrip(b"\0")
        off += _JOBID_LEN
    if flags & CLF_SHARD:
        rec.shard = _SHARD.unpack_from(buf, off)
        off += _SHARD.size
    if flags & CLF_METRICS:
        (cnt,) = struct.unpack_from("<H", buf, off)
        rec.metrics = struct.unpack_from(f"<{cnt}d", buf, off + 2)
        off += 2 + 8 * cnt
    if flags & CLF_XATTR:
        (ln,) = struct.unpack_from("<I", buf, off)
        rec.xattr = msgpack.unpackb(buf[off + 4:off + 4 + ln])
        off += 4 + ln
    rec.name = buf[off:off + namelen]
    off += namelen
    if flags & CLF_RENAME and off < len(buf):
        rec.sname = buf[off + 1:]  # skip NUL separator
    return rec


def packed_flags(buf: bytes) -> int:
    return struct.unpack_from("<H", buf, 2)[0]


def packed_type(buf: bytes) -> int:
    return struct.unpack_from("<H", buf, 4)[0]


def packed_jobid(buf: bytes) -> bytes:
    """The CLF_JOBID extension of a packed record, NUL-trimmed
    (``b""`` when the flag is absent) — the scalar twin of
    ``RecordBatch.jobid_col`` for the per-record dispatch path."""
    flags = struct.unpack_from("<H", buf, 2)[0]
    if not flags & CLF_JOBID:
        return b""
    off = HDR_SIZE + (2 * _FID.size if flags & CLF_RENAME else 0)
    return bytes(buf[off:off + _JOBID_LEN]).rstrip(b"\0")


def normalize_flags(flags: Optional[int]) -> int:
    """The single place subscription flag masks are normalized: ``None``
    means "everything supported", unknown bits are masked off (a newer
    client talking to this proxy gets the intersection, per §IV-A)."""
    if flags is None:
        return CLF_SUPPORTED
    return flags & CLF_SUPPORTED


def remap(buf: bytes, target_flags: int) -> bytes:
    """Remap a *packed* record to ``target_flags`` (paper §IV-A).

    Fields present in both masks are copied; fields only in the target are
    zero-filled (local remap at a newer client); fields only in the source
    are stripped (remote remap at the proxy for an older client).  Works
    directly on the byte representation using offset arithmetic — no
    oversized intermediate with empty fields is ever stored.
    """
    target_flags &= CLF_SUPPORTED
    src_flags = packed_flags(buf)
    if src_flags == target_flags:
        return buf
    namelen = struct.unpack_from("<H", buf, 0)[0]

    # slice source extensions
    src_ext: Dict[int, bytes] = {}
    off = HDR_SIZE
    for flag, size in _ext_sizes(src_flags, buf, HDR_SIZE):
        src_ext[flag] = buf[off:off + size]
        off += size
    name_and_tail = buf[off:]

    head = bytearray(buf[:HDR_SIZE])
    struct.pack_into("<H", head, 2, target_flags)
    parts = [bytes(head)]
    zero_default = {
        CLF_RENAME: b"\0" * (2 * _FID.size),
        CLF_JOBID: b"\0" * _JOBID_LEN,
        CLF_SHARD: b"\0" * _SHARD.size,
        CLF_METRICS: struct.pack("<H", 0),
        CLF_XATTR: struct.pack("<I", 1) + msgpack.packb({}),
    }
    for flag in (CLF_RENAME, CLF_JOBID, CLF_SHARD, CLF_METRICS, CLF_XATTR):
        if target_flags & flag:
            parts.append(src_ext.get(flag, zero_default[flag]))
    # tail: name, and sname only if the target still carries CLF_RENAME
    if src_flags & CLF_RENAME and not target_flags & CLF_RENAME:
        # strip the sname tail, keep only name
        parts.append(name_and_tail[:namelen])
    elif target_flags & CLF_RENAME and not src_flags & CLF_RENAME:
        parts.append(name_and_tail[:namelen] + b"\0")
    else:
        parts.append(name_and_tail)
    return b"".join(parts)


# ---------------------------------------------------------------------------
# Cached remap plans.
#
# ``remap`` rebuilds its slicing decisions from the flag masks on every
# call.  The proxy remaps every dispatched record against every
# consumer's mask, but the number of distinct (src_flags, target_flags)
# pairs is tiny (<= 32 x 32); a compiled per-pair plan amortizes all of
# the mask branching.  Pairs whose fields are all fixed-size get a fully
# static slicing closure; pairs involving CLF_METRICS/CLF_XATTR fall
# back to the generic path (their sizes live in the record itself).
# ---------------------------------------------------------------------------
CLF_VARIABLE = CLF_METRICS | CLF_XATTR
_FIXED_SIZES = {CLF_RENAME: 2 * _FID.size, CLF_JOBID: _JOBID_LEN,
                CLF_SHARD: _SHARD.size}
_FLAG_ORDER = (CLF_RENAME, CLF_JOBID, CLF_SHARD, CLF_METRICS, CLF_XATTR)

_REMAP_PLANS: Dict[Tuple[int, int], Callable[[bytes], bytes]] = {}


def _compile_remap(src: int, dst: int) -> Callable[[bytes], bytes]:
    if (src | dst) & CLF_VARIABLE:
        return lambda buf: remap(buf, dst)
    src_off: Dict[int, int] = {}
    off = HDR_SIZE
    for f in _FLAG_ORDER:
        if src & f:
            src_off[f] = off
            off += _FIXED_SIZES[f]
    name_off = off
    # ('copy', lo, hi) slices from the source; ('zero', blob) fills
    segs: List[Tuple[str, Any, Any]] = []
    for f in _FLAG_ORDER:
        if dst & f:
            if src & f:
                lo = src_off[f]
                if segs and segs[-1][0] == "copy" and segs[-1][2] == lo:
                    segs[-1] = ("copy", segs[-1][1], lo + _FIXED_SIZES[f])
                else:
                    segs.append(("copy", lo, lo + _FIXED_SIZES[f]))
            else:
                zero = b"\0" * _FIXED_SIZES[f]
                segs.append(("zero", zero, None))
    flags_patch = struct.pack("<H", dst)
    add_rename = bool(dst & CLF_RENAME) and not (src & CLF_RENAME)
    strip_rename = bool(src & CLF_RENAME) and not (dst & CLF_RENAME)

    def plan(buf: bytes) -> bytes:
        parts = [buf[:2], flags_patch, buf[4:HDR_SIZE]]
        for kind, a, b in segs:
            parts.append(buf[a:b] if kind == "copy" else a)
        if strip_rename:
            namelen = buf[0] | (buf[1] << 8)
            parts.append(buf[name_off:name_off + namelen])
        elif add_rename:
            parts.append(buf[name_off:])
            parts.append(b"\0")
        else:
            parts.append(buf[name_off:])
        return b"".join(parts)

    return plan


def remap_cached(buf: bytes, target_flags: int) -> bytes:
    """Plan-cached equivalent of ``remap`` (identical output)."""
    dst = target_flags & CLF_SUPPORTED
    src = packed_flags(buf)
    if src == dst:
        return buf
    try:
        plan = _REMAP_PLANS[(src, dst)]
    except KeyError:
        plan = _REMAP_PLANS[(src, dst)] = _compile_remap(src, dst)
    return plan(buf)


# ---------------------------------------------------------------------------
# RecordBatch — the batch-native, *columnar* unit of flow.
#
# A batch is a packed buffer plus an offsets/lengths table (numpy int64
# columns, built lazily from whatever sequence the caller hands in).
# The 64-byte fixed header of every record is decoded **once per
# batch** — a single byte gather viewed as a structured dtype — into
# contiguous per-field columns (index, type, flags, time, tfid/pfid
# triples).  Hot paths (dispatch masks, slot hashing, compaction folds)
# read those arrays; the packed buffer is retained only for payload
# passthrough, and full decode (``record(i)``) stays lazy and
# per-record.  ``select``/``permute``/slicing produce views sharing the
# payload buffer *and* the decoded columns, so stream modules that drop
# or reorder records copy neither payload bytes nor header columns.
# ---------------------------------------------------------------------------
_U16 = struct.Struct("<H")
_U64 = struct.Struct("<Q")
_TFID_AT = struct.Struct("<QII")

#: structured view of the 64-byte fixed header (wire layout, LE)
HDR_DTYPE = np.dtype([
    ("namelen", "<u2"), ("flags", "<u2"), ("type", "<u2"), ("pad", "<u2"),
    ("index", "<u8"), ("prev", "<u8"), ("time", "<u8"),
    ("tseq", "<u8"), ("toid", "<u4"), ("tver", "<u4"),
    ("pseq", "<u8"), ("poid", "<u4"), ("pver", "<u4")])
assert HDR_DTYPE.itemsize == HDR_SIZE

_HDR_RANGE = np.arange(HDR_SIZE, dtype=np.int64)
_I64 = np.int64

Buffer = Union[bytes, bytearray, memoryview]

#: wire frame versions (see ``RecordBatch.to_wire``)
WIRE_V1 = 1
WIRE_V2 = 2
#: first word of a v2 frame; a v1 frame starts with the record count,
#: which stays far below this in any real batch
WIRE2_MAGIC = 0xC015FEED

#: first word of the optional origin trailer a v2 frame may carry
#: *after* its payload.  ``from_wire`` computes every record offset
#: from the lens table and never validates total blob length, so a
#: receiver that predates the trailer simply never looks at it —
#: batch-level origin tagging is backward compatible by construction.
WIRE2_ORIGIN_MAGIC = 0xFEDE0716

#: capability keys exchanged on the cluster control plane (the ``caps``
#: verb, subscribe negotiation) and piggybacked on data-path replies:
#: record-frame generation, deep-batched offer support, and the
#: epoch-versioned routing plane (a peer advertising CAP_EPOCH stamps
#: the current routing epoch on its subscribe/fetch/commit replies and
#: answers the ``topology`` verb, so consumers re-resolve the shard
#: fan-in when the epoch bumps instead of assuming a fixed shard set)
CAP_WIRE = "wire"
CAP_DEEP = "deep"
CAP_EPOCH = "epoch"


def _as_i64(seq) -> np.ndarray:
    if type(seq) is np.ndarray and seq.dtype == np.int64:
        return seq
    return np.asarray(seq, dtype=np.int64)


def _is_frozen(buf) -> bool:
    """True for buffers that can never be resized or mutated under a
    numpy view: bytes, or a read-only memoryview (wire receive path)."""
    return type(buf) is bytes or (type(buf) is memoryview and buf.readonly)


# Shared zero-fill source for the vectorized rebuild: 32 zero bytes
# cover every fixed default (rename 32, jobid 32, shard 8, the metrics
# count prefix 2, and the rename-tail NUL); the empty-xattr default
# (u32 len=1 + msgpack ``{}``) follows at _ZX_OFF.
_ZX_OFF = 32
_ZFILL = np.frombuffer(b"\0" * _ZX_OFF + struct.pack("<I", 1)
                       + msgpack.packb({}), dtype=np.uint8)
_ZFILL_LEN = {CLF_RENAME: 2 * _FID.size, CLF_JOBID: _JOBID_LEN,
              CLF_SHARD: _SHARD.size, CLF_METRICS: 2,
              CLF_XATTR: 4 + len(msgpack.packb({}))}


class RecordBatch:
    __slots__ = ("buf", "_off", "_len", "_recs", "_hdr", "_ext", "_pb",
                 "origin")

    def __init__(self, buf: Buffer, offsets: Sequence[int],
                 lengths: Sequence[int]):
        self.buf = buf
        # kept as handed in (list for append-path callers, ndarray for
        # views); normalized to int64 columns on first columnar use
        self._off = offsets if isinstance(offsets, (list, np.ndarray)) \
            else list(offsets)
        self._len = lengths if isinstance(lengths, (list, np.ndarray)) \
            else list(lengths)
        self._recs: Dict[int, ChangelogRecord] = {}
        self._hdr: Optional[np.ndarray] = None   # decoded header columns
        self._ext = None                         # cached extension layout
        self._pb = None                          # cached payload-base view
        #: which filesystem/cluster the batch came from — a *batch*-level
        #: federation tag (one string per frame, never per-record bytes);
        #: rides the v2 wire as a trailer old receivers ignore
        self.origin: Optional[str] = None

    # -- construction -------------------------------------------------------
    @classmethod
    def empty(cls) -> "RecordBatch":
        return cls(b"", np.empty(0, _I64), np.empty(0, _I64))

    @classmethod
    def from_packed(cls, bufs: Iterable[bytes]) -> "RecordBatch":
        chunks = list(bufs)
        n = len(chunks)
        lengths = np.fromiter(map(len, chunks), dtype=_I64, count=n)
        offsets = np.zeros(n, _I64)
        if n > 1:
            np.cumsum(lengths[:-1], out=offsets[1:])
        return cls(b"".join(chunks), offsets, lengths)

    @classmethod
    def from_records(cls, recs: Iterable[ChangelogRecord]) -> "RecordBatch":
        return cls.from_packed(pack(r) for r in recs)

    # -- sizing / iteration (list-of-packed-bytes compatible) ---------------
    def __len__(self) -> int:
        return len(self._off)

    def __bool__(self) -> bool:
        return len(self._off) > 0

    def __iter__(self):
        for i in range(len(self._off)):
            yield self.packed(i)

    def __getitem__(self, i):
        if isinstance(i, slice):
            sub = RecordBatch(self.buf, self._off[i], self._len[i])
            if self._hdr is not None:
                sub._hdr = self._hdr[i]
            sub.origin = self.origin
            return sub
        return self.packed(i)

    def __eq__(self, other) -> bool:
        if isinstance(other, RecordBatch):
            return list(self) == list(other)
        if isinstance(other, (list, tuple)):
            return list(self) == list(other)
        return NotImplemented

    def __repr__(self) -> str:
        return f"RecordBatch({len(self)} records, {self.nbytes}B)"

    @property
    def nbytes(self) -> int:
        return int(self._len_col().sum())

    # -- columnar core ------------------------------------------------------
    def _off_col(self) -> np.ndarray:
        off = self._off
        if type(off) is not np.ndarray:
            off = self._off = _as_i64(off)
        return off

    def _len_col(self) -> np.ndarray:
        ln = self._len
        if type(ln) is not np.ndarray:
            ln = self._len = _as_i64(ln)
        return ln

    def header(self) -> np.ndarray:
        """The decoded fixed-header table: one structured row per
        record (``HDR_DTYPE`` fields), gathered from the packed buffer
        in a single vectorized pass and cached.  A mutable (bytearray)
        buffer is region-copied first — holding a numpy view of a live
        journal segment would lock it against append resizing."""
        h = self._hdr
        if h is None:
            n = len(self._off)
            if n == 0:
                h = np.empty(0, HDR_DTYPE)
            else:
                base, off = self._payload_base()
                gathered = base[off[:, None] + _HDR_RANGE]
                h = gathered.view(HDR_DTYPE).reshape(n)
            self._hdr = h
        return h

    # numpy column accessors (the hot-path surface)
    def indices_np(self) -> np.ndarray:          # u64 cr_index
        return self.header()["index"]

    def types_np(self) -> np.ndarray:            # u16 cr_type
        return self.header()["type"]

    def flags_np(self) -> np.ndarray:            # u16 cr_flags
        return self.header()["flags"]

    def times_np(self) -> np.ndarray:            # u64 cr_time
        return self.header()["time"]

    def tfid_cols(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        h = self.header()
        return h["tseq"], h["toid"], h["tver"]

    def pfid_cols(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        h = self.header()
        return h["pseq"], h["poid"], h["pver"]

    # -- payload-extension columns ------------------------------------------
    # Extensions live at flag-computable offsets (wire order: RENAME,
    # JOBID, SHARD, METRICS, XATTR — rec_offset()), so the fixed-size
    # ones gather vectorized: per-row offset arithmetic on the flags
    # column, one fancy index into the packed buffer, no per-record
    # decode.  The aggregation tier folds whole batches through these.
    def _payload_base(self) -> Tuple[np.ndarray, np.ndarray]:
        """(uint8 view of the packed buffer, per-record offsets into
        it), cached — records are immutable once written, so the
        snapshot a mutable (live-journal) buffer forces is taken once
        per batch, not once per columnar gather."""
        pb = self._pb
        if pb is None:
            off = self._off_col()
            buf = self.buf
            if not _is_frozen(buf):
                lo = int(off.min())
                hi = int((off + self._len_col()).max())
                pb = (np.frombuffer(bytes(buf[lo:hi]), dtype=np.uint8),
                      off - lo)
            else:
                pb = (np.frombuffer(buf, dtype=np.uint8), off)
            self._pb = pb
        return pb

    def _ext_off(self, flags: np.ndarray, upto: int) -> np.ndarray:
        """Per-row offset of fixed-position extension ``upto`` relative
        to each record's start (valid where the flag is present)."""
        off = np.full(len(flags), HDR_SIZE, dtype=np.int64)
        if upto == CLF_RENAME:
            return off
        off += (flags & CLF_RENAME).astype(np.int64) * (2 * _FID.size)
        if upto == CLF_JOBID:
            return off
        off += ((flags & CLF_JOBID) >> 1).astype(np.int64) * _JOBID_LEN
        if upto == CLF_SHARD:
            return off
        off += ((flags & CLF_SHARD) >> 2).astype(np.int64) * _SHARD.size
        if upto == CLF_METRICS:
            return off
        raise KeyError(f"flag {upto:#x} has no fixed offset")

    def jobid_col(self, width: int = _JOBID_LEN) -> np.ndarray:
        """The CLF_JOBID extension as an ``(n, width)`` uint8 matrix;
        rows without the flag are all-zero (the empty jobid).

        ``width`` trims the gather to the leading bytes a caller will
        actually compare (jobids are NUL-padded, so a prefix or
        NUL-terminated-exact match never needs the full field) — the
        tenant-scope pushdown asks only for its widest scope entry."""
        n = len(self)
        width = max(1, min(int(width), _JOBID_LEN))
        out = np.zeros((n, width), dtype=np.uint8)
        if not n:
            return out
        flags = self.flags_np()
        has = (flags & CLF_JOBID) != 0
        rows = np.flatnonzero(has)
        if rows.size:
            # JOBID sits at a flag-computable offset (only RENAME
            # precedes it), so the full extension walk ``_layout``
            # performs is skipped on this per-dispatch path
            base, off = self._payload_base()
            jo = off + self._ext_off(flags, CLF_JOBID)
            if width == 8 and base.size >= 8:
                # the tenant-pushdown shape: one windowed gather, no
                # index-matrix build or scatter.  Flagless rows gather
                # whatever follows their header (clamped in-bounds)
                # and are zeroed after; jobid-bearing rows always have
                # the full 32-byte field behind ``jo``.
                jo = np.minimum(jo, base.size - 8)
                out = np.lib.stride_tricks.sliding_window_view(
                    base, 8)[jo]
                if rows.size != n:
                    out[~has] = 0
                return out
            jo = jo[rows]
            out[rows] = base[jo[:, None] + np.arange(width)]
        return out

    def jobid_word(self) -> np.ndarray:
        """The leading 8 bytes of each record's CLF_JOBID field as one
        native-endian uint64 per row (0 where the flag is absent) —
        the word-at-a-time form of ``jobid_col`` the tenant pushdown
        compares against ``TenantPrincipal`` masked-word tests.  One
        1-D gather through an unaligned sliding uint64 view: no index
        matrix, no ``(n, 8)`` intermediate."""
        n = len(self)
        out = np.zeros(n, dtype=np.uint64)
        if not n:
            return out
        # densify the strided header field once: three flag tests over
        # a contiguous copy beat one over the structured view
        flags = np.ascontiguousarray(self.flags_np())
        has = (flags & CLF_JOBID) != 0
        all_flagged = bool(has.all())
        if not all_flagged and not has.any():
            return out
        base, off = self._payload_base()
        if base.size < 8:
            col = self.jobid_col(8)
            return np.ascontiguousarray(col).view(np.uint64).ravel()
        if (flags & CLF_RENAME).any():
            jo = off + self._ext_off(flags, CLF_JOBID)
        else:                       # JOBID right past the fixed header
            jo = off + np.int64(HDR_SIZE)
        np.minimum(jo, base.size - 8, out=jo)
        words = np.lib.stride_tricks.as_strided(
            base[:(base.size // 8) * 8].view(np.uint64),
            shape=(base.size - 7,), strides=(1,))
        out = words[jo]
        if not all_flagged:         # clamped garbage where no jobid
            out[~has] = 0
        return out

    def shard_cols(self) -> Tuple[np.ndarray, np.ndarray]:
        """The CLF_SHARD (pod, host) u16 pair as int64 columns; rows
        without the flag read (0, 0)."""
        n = len(self)
        pod = np.zeros(n, dtype=np.int64)
        host = np.zeros(n, dtype=np.int64)
        if not n:
            return pod, host
        flags = self.flags_np()
        rows = np.flatnonzero((flags & CLF_SHARD) != 0)
        if rows.size:
            base, _off, starts, _sizes, _name = self._layout()
            so = starts[CLF_SHARD][rows]
            raw = base[so[:, None] + np.arange(4)].astype(np.int64)
            pod[rows] = raw[:, 0] | (raw[:, 1] << 8)
            host[rows] = raw[:, 2] | (raw[:, 3] << 8)
        return pod, host

    def metric0_col(self) -> np.ndarray:
        """The first CLF_METRICS value per record as float64 (0.0 where
        the extension is absent or empty) — the stream's primary gauge
        (loss / bytes / step time, by op type)."""
        n = len(self)
        out = np.zeros(n, dtype=np.float64)
        if not n:
            return out
        flags = self.flags_np()
        rows = np.flatnonzero((flags & CLF_METRICS) != 0)
        if rows.size:
            base, _off, starts, _sizes, _name = self._layout()
            mo = starts[CLF_METRICS][rows]
            cnt = (base[mo].astype(np.int64)
                   | (base[mo + 1].astype(np.int64) << 8))
            have = np.flatnonzero(cnt > 0)
            if have.size:
                vo = mo[have] + 2
                raw = base[vo[:, None] + np.arange(8)]
                out[rows[have]] = raw.view("<f8").ravel()
        return out

    def _ext_layout(self, base: np.ndarray, off: np.ndarray):
        """Per-row absolute ``(starts, sizes)`` of every canonical
        extension (size 0 where the flag is absent) plus the name
        offset — one vectorized walk of the flag-gated payload, shared
        by the variable-size gathers and the whole-batch rebuild."""
        src = self.flags_np().astype(np.int64)
        n = len(src)
        cur = off + np.int64(HDR_SIZE)
        starts: Dict[int, np.ndarray] = {}
        sizes: Dict[int, np.ndarray] = {}
        for flag in _FLAG_ORDER:
            has = (src & flag) != 0
            if flag in _FIXED_SIZES:
                size = np.where(has, np.int64(_FIXED_SIZES[flag]), 0)
            else:
                size = np.zeros(n, dtype=np.int64)
                rows = np.flatnonzero(has)
                if rows.size:
                    o = cur[rows]
                    if flag == CLF_METRICS:       # u16 value count
                        cnt = (base[o].astype(np.int64)
                               | (base[o + 1].astype(np.int64) << 8))
                        size[rows] = 2 + 8 * cnt
                    else:                         # CLF_XATTR: u32 blob len
                        bl = (base[o].astype(np.int64)
                              | (base[o + 1].astype(np.int64) << 8)
                              | (base[o + 2].astype(np.int64) << 16)
                              | (base[o + 3].astype(np.int64) << 24))
                        size[rows] = 4 + bl
            starts[flag] = cur
            sizes[flag] = size
            cur = cur + size
        return starts, sizes, cur

    def _layout(self):
        """``(base, off, starts, sizes, name_off)`` — the payload view
        plus the extension layout, computed once per batch and cached:
        every columnar gather on the same batch (the consumer hot path
        touches several per delivery) shares one canonical walk.
        Mutable buffers get the same snapshot semantics as
        ``header()`` — records are immutable once written."""
        lay = self._ext
        if lay is None:
            base, off = self._payload_base()
            starts, sizes, name_off = self._ext_layout(base, off)
            lay = self._ext = (base, off, starts, sizes, name_off)
        return lay

    def _names_packed(self) -> Tuple[bytes, np.ndarray, np.ndarray]:
        """All names pulled in one ragged gather: ``(packed, lo, hi)``
        with record i's name at ``packed[lo[i]:hi[i]]``."""
        base, _off, _starts, _sizes, name_off = self._layout()
        namelen = self.header()["namelen"].astype(np.int64)
        n = len(self)
        out = np.zeros(n, dtype=np.int64)
        np.cumsum(namelen[:-1], out=out[1:])
        total = int(out[-1] + namelen[-1])
        src = np.arange(total, dtype=np.int64) \
            + np.repeat(name_off - out, namelen)
        return base[src].tobytes(), out, out + namelen

    def name_col(self) -> List[bytes]:
        """Per-record name bytes sliced straight out of the packed
        buffer past the flag-gated extensions — no record decode.  All
        names are pulled in one ragged gather, then sliced off the
        small contiguous result (cheaper than per-row buffer views)."""
        if not len(self):
            return []
        packed, lo, hi = self._names_packed()
        return [packed[s:e] for s, e in zip(lo.tolist(), hi.tolist())]

    def name_col_str(self, errors: str = "replace") -> List[str]:
        """``name_col`` decoded to ``str``: one bulk decode plus string
        slicing when the packed names are pure ASCII (byte offsets ==
        char offsets, and the overwhelmingly common case), per-record
        decode otherwise."""
        if not len(self):
            return []
        packed, lo, hi = self._names_packed()
        if packed.isascii():
            s = packed.decode("ascii")
            return [s[a:b] for a, b in zip(lo.tolist(), hi.tolist())]
        return [packed[a:b].decode(errors=errors)
                for a, b in zip(lo.tolist(), hi.tolist())]

    def metrics_cols(self, k: int = 3) -> Tuple[np.ndarray, np.ndarray]:
        """The first ``k`` CLF_METRICS values as an ``(n, k)`` float64
        matrix plus the per-row value count (0 where the extension is
        absent); unfilled cells read 0.0."""
        n = len(self)
        out = np.zeros((n, k), dtype=np.float64)
        cnt = np.zeros(n, dtype=np.int64)
        if not n:
            return out, cnt
        flags = self.flags_np()
        rows = np.flatnonzero((flags & CLF_METRICS) != 0)
        if rows.size:
            base, _off, starts, _sizes, _name = self._layout()
            mo = starts[CLF_METRICS][rows]
            c = (base[mo].astype(np.int64)
                 | (base[mo + 1].astype(np.int64) << 8))
            cnt[rows] = c
            kk = min(k, int(c.max()))
            if kk > 0:
                # one gather of the first kk values per row (offsets
                # clipped to the buffer for rows with fewer values),
                # then mask the unfilled tail in place
                src = np.minimum(mo[:, None] + 2 + np.arange(8 * kk),
                                 np.int64(len(base) - 1))
                vals = base[src].view("<f8")
                vals[np.arange(kk) >= c[:, None]] = 0.0
                out[rows, :kk] = vals
        return out, cnt

    def xattrs_col(self) -> List[Optional[Dict[str, Any]]]:
        """Per-row CLF_XATTR dicts (None where absent).  Only the
        msgpack blob itself is decoded — the fixed header and the other
        extensions are never re-parsed."""
        n = len(self)
        out: List[Optional[Dict[str, Any]]] = [None] * n
        if not n:
            return out
        flags = self.flags_np()
        if not bool((flags & CLF_XATTR).any()):
            return out
        base, _off, starts, sizes, _name = self._layout()
        xo, xs = starts[CLF_XATTR], sizes[CLF_XATTR]
        mem = memoryview(base)
        unpackb = msgpack.unpackb
        for i in np.flatnonzero(xs).tolist():
            s = int(xo[i])
            out[i] = unpackb(mem[s + 4:s + int(xs[i])])
        return out

    # -- zero-copy header accessors (per record) ----------------------------
    def packed(self, i: int) -> bytes:
        o = self._off[i]
        buf = self.buf
        if type(buf) is bytes:
            return buf[o:o + self._len[i]]       # one copy
        return bytes(buf[o:o + self._len[i]])    # bytearray: slice + freeze

    def packed_namelen(self, i: int) -> int:
        h = self._hdr
        if h is not None:
            return int(h["namelen"][i])
        return _U16.unpack_from(self.buf, self._off[i])[0]

    def packed_flags(self, i: int) -> int:
        h = self._hdr
        if h is not None:
            return int(h["flags"][i])
        return _U16.unpack_from(self.buf, self._off[i] + 2)[0]

    def packed_type(self, i: int) -> int:
        h = self._hdr
        if h is not None:
            return int(h["type"][i])
        return _U16.unpack_from(self.buf, self._off[i] + 4)[0]

    def packed_index(self, i: int) -> int:
        h = self._hdr
        if h is not None:
            return int(h["index"][i])
        return _U64.unpack_from(self.buf, self._off[i] + 8)[0]

    def packed_time(self, i: int) -> int:
        h = self._hdr
        if h is not None:
            return int(h["time"][i])
        return _U64.unpack_from(self.buf, self._off[i] + 24)[0]

    def packed_tfid(self, i: int) -> Tuple[int, int, int]:
        h = self._hdr
        if h is not None:
            return (int(h["tseq"][i]), int(h["toid"][i]), int(h["tver"][i]))
        return _TFID_AT.unpack_from(self.buf, self._off[i] + 32)

    packed_key = packed_tfid   # target identity == tfid triple

    # -- whole columns, list-typed (module/test compatibility) --------------
    def types(self) -> List[int]:
        return self.types_np().tolist()

    def indices(self) -> List[int]:
        return self.indices_np().tolist()

    def flags_column(self) -> List[int]:
        return self.flags_np().tolist()

    def keys(self) -> List[Tuple[int, int, int]]:
        seq, oid, ver = self.tfid_cols()
        return list(zip(seq.tolist(), oid.tolist(), ver.tolist()))

    # -- lazy decode ---------------------------------------------------------
    def record(self, i: int) -> ChangelogRecord:
        rec = self._recs.get(i)
        if rec is None:
            rec = self._recs[i] = unpack(self.packed(i))
        return rec

    def to_records(self) -> List[ChangelogRecord]:
        return [self.record(i) for i in range(len(self))]

    # -- zero-copy restructuring --------------------------------------------
    def freeze(self) -> "RecordBatch":
        """A frozen-buffer twin of this batch (``self`` when the buffer
        is already frozen): one compacting copy up front so every later
        gather / ``select`` / ``to_wire`` on it — and on views derived
        from it — sees a zero-copy ``frombuffer`` base instead of
        re-snapshotting a mutable journal segment per call."""
        if _is_frozen(self.buf):
            return self
        blob, off, ln = self._compact()
        out = RecordBatch(blob, off, ln)
        if self._hdr is not None:
            out._hdr = self._hdr
        return out

    def select(self, keep) -> "RecordBatch":
        """View containing rows ``keep`` (an index sequence or int
        array, in the given order), sharing the payload buffer and any
        already-decoded header columns."""
        keep = _as_i64(keep)
        sub = RecordBatch(self.buf, self._off_col()[keep],
                          self._len_col()[keep])
        sub.origin = self.origin
        if self._hdr is not None:
            sub._hdr = self._hdr[keep]
        lay = self._ext
        if lay is not None:
            # the extension layout is per-row over a shared base:
            # subset it instead of re-walking the payload per view
            base, off, starts, sizes, name_off = lay
            sub._ext = (base, off[keep],
                        {f: s[keep] for f, s in starts.items()},
                        {f: s[keep] for f, s in sizes.items()},
                        name_off[keep])
        return sub

    permute = select

    def _compact(self) -> Tuple[bytes, np.ndarray, np.ndarray]:
        """``(blob, offsets, lengths)`` with the records contiguous in
        ``blob`` and offsets rebased to 0 — one range copy when the
        rows already sit back to back (journal segment views), a
        per-record gather otherwise (selected/permuted views)."""
        n = len(self._off)
        if n == 0:
            return b"", np.empty(0, _I64), np.empty(0, _I64)
        off, ln = self._off_col(), self._len_col()
        if n == 1 or bool(np.all(off[1:] == off[:-1] + ln[:-1])):
            lo, hi = int(off[0]), int(off[-1] + ln[-1])
            buf = self.buf
            if type(buf) is bytes and lo == 0 and hi == len(buf):
                return buf, off, ln
            return bytes(buf[lo:hi]), off - lo, ln
        out = np.zeros(n, _I64)
        np.cumsum(ln[:-1], out=out[1:])
        total = int(out[-1] + ln[-1])
        base, poff = self._payload_base()
        # one ragged-range gather instead of a per-record slice+join
        src = np.arange(total, dtype=_I64) + np.repeat(poff - out, ln)
        return base[src].tobytes(), out, ln

    @staticmethod
    def concat(batches: Sequence["RecordBatch"]) -> "RecordBatch":
        batches = [b for b in batches if len(b)]
        if not batches:
            return RecordBatch.empty()
        if len(batches) == 1:
            return batches[0]
        blobs, offs, lens = [], [], []
        base = 0
        for b in batches:
            blob, off, ln = b._compact()
            blobs.append(blob)
            offs.append(off + base if base else off)
            lens.append(ln)
            base += len(blob)
        out = RecordBatch(b"".join(blobs), np.concatenate(offs),
                          np.concatenate(lens))
        origins = {b.origin for b in batches}
        if len(origins) == 1:            # mixed-origin concat drops the tag
            out.origin = origins.pop()
        if all(b._hdr is not None for b in batches):
            out._hdr = np.concatenate([b._hdr for b in batches])
        return out

    # -- per-batch remap (vectorized) ---------------------------------------
    def _rebuild(self, want: np.ndarray) -> "RecordBatch":
        """Whole-batch remap to a per-row target mask, vectorized: one
        canonical-order layout pass, then a single ragged byte gather
        assembles every output record (header | kept / zero-filled
        extensions | name | rename tail).  Bit-identical to mapping
        ``remap_cached`` over the rows, and the rebuilt batch keeps its
        header columns (flags patched in place) with zero re-gather."""
        n = len(self)
        hdr = self.header()
        src = hdr["flags"].astype(np.int64)
        want = want.astype(np.int64) & CLF_SUPPORTED
        base, off, starts, sizes, name_off = self._layout()
        ln = self._len_col()
        zbase = np.int64(len(base))

        # 8 output segments per row: header, the 5 canonical
        # extensions, name, rename tail.  Zero-filled extensions point
        # into the shared _ZFILL block appended past the payload.
        seg_start = np.empty((n, 8), dtype=np.int64)
        seg_len = np.zeros((n, 8), dtype=np.int64)
        seg_start[:, 0] = off
        seg_len[:, 0] = HDR_SIZE
        for col, flag in enumerate(_FLAG_ORDER, start=1):
            has = (src & flag) != 0
            keep = (want & flag) != 0
            zoff = zbase + (_ZX_OFF if flag == CLF_XATTR else 0)
            seg_start[:, col] = np.where(has, starts[flag], zoff)
            fill = np.where(has, sizes[flag], np.int64(_ZFILL_LEN[flag]))
            seg_len[:, col] = np.where(keep, fill, 0)
        namelen = hdr["namelen"].astype(np.int64)
        seg_start[:, 6] = name_off
        seg_len[:, 6] = namelen
        # rename tail: copy "\0" + sname when kept, a single NUL when
        # zero-filled, nothing when stripped or absent
        has_r = (src & CLF_RENAME) != 0
        keep_r = (want & CLF_RENAME) != 0
        tail = np.where(has_r, off + ln - (name_off + namelen),
                        np.int64(1))
        seg_start[:, 7] = np.where(has_r, name_off + namelen, zbase)
        seg_len[:, 7] = np.where(keep_r, tail, 0)

        out_len = seg_len.sum(axis=1)
        out_off = np.zeros(n, _I64)
        if n > 1:
            np.cumsum(out_len[:-1], out=out_off[1:])
        flat_start = seg_start.ravel()
        flat_len = seg_len.ravel()
        ends = np.cumsum(flat_len)
        total = int(ends[-1]) if ends.size else 0
        idx = (np.arange(total, dtype=np.int64)
               - np.repeat(ends - flat_len, flat_len)
               + np.repeat(flat_start, flat_len))
        big = np.concatenate([base, _ZFILL]) if bool(
            ((flat_start >= zbase) & (flat_len > 0)).any()) else base
        out = big[idx]
        fpos = out_off + 2                 # patch cr_flags (LE u16)
        out[fpos] = (want & 0xFF).astype(np.uint8)
        out[fpos + 1] = ((want >> 8) & 0xFF).astype(np.uint8)
        res = RecordBatch(out.tobytes(), out_off, out_len)
        res.origin = self.origin
        new_hdr = hdr.copy()
        new_hdr["flags"] = want
        res._hdr = new_hdr
        return res

    def remap(self, target_flags: int) -> "RecordBatch":
        dst = target_flags & CLF_SUPPORTED
        fl = self.flags_np()
        if not bool((fl != dst).any()):
            return self
        return self._rebuild(np.full(len(self), dst, dtype=np.int64))

    def project(self, target_flags: int) -> "RecordBatch":
        """Strip-only remap: every record keeps ``src & target_flags``
        (the proxy's §IV-A remote remap — fields the consumer did not
        ask for are stripped, absent fields are never zero-filled).
        Identity — no copy at all — when nothing needs stripping, which
        is the steady state of a consumer asking for everything the
        producers write."""
        strip = CLF_SUPPORTED & ~target_flags
        fl = self.flags_np()
        if not strip or not bool((fl & strip).any()):
            return self
        return self._rebuild(fl.astype(np.int64) & target_flags)

    # -- wire framing --------------------------------------------------------
    # v1: u32 count | count * u32 record length | concatenated payload
    # v2: u32 WIRE2_MAGIC | u32 count | count * u32 record length
    #     | count * 64 B header rows (HDR_DTYPE, LE) | payload
    # A v1 count can never collide with the magic (batches are bounded
    # far below 2^31), so ``from_wire`` sniffs the first word and
    # accepts both frames; version negotiation only controls what a
    # sender *emits*, so a v1-only peer never receives a v2 frame.
    def to_wire(self, version: int = WIRE_V1) -> bytes:
        if version >= WIRE_V2:
            return self.to_wire2()
        blob, _off, ln = self._compact()
        return struct.pack("<I", len(self)) + \
            ln.astype("<u4").tobytes() + blob

    def to_wire2(self) -> bytes:
        """v2 frame: the decoded header table rides alongside the
        payload, so the receiver attaches the columns as a zero-copy
        view instead of re-gathering 64 bytes per record.  A batch with
        an ``origin`` tag appends it as a trailer past the payload —
        one string per frame (never per-record bytes), invisible to
        receivers that predate federation."""
        blob, _off, ln = self._compact()
        hdr = self.header()
        frame = (struct.pack("<II", WIRE2_MAGIC, len(self))
                 + ln.astype("<u4").tobytes()
                 + (hdr.tobytes() if hdr.size else b"") + blob)
        if self.origin is not None:
            tag = self.origin.encode("utf-8")
            frame += struct.pack("<IH", WIRE2_ORIGIN_MAGIC, len(tag)) + tag
        return frame

    @staticmethod
    def from_wire(blob: Buffer) -> "RecordBatch":
        if type(blob) is not bytes:
            mv = blob if type(blob) is memoryview else memoryview(blob)
            blob = mv if mv.readonly else bytes(mv)   # zero-copy receive
        (first,) = struct.unpack_from("<I", blob, 0)
        if first != WIRE2_MAGIC:
            n = first
            lengths = np.frombuffer(blob, dtype="<u4", count=n,
                                    offset=4).astype(_I64)
            offsets = np.full(n, 4 + 4 * n, _I64)
            if n > 1:
                offsets[1:] += np.cumsum(lengths[:-1])
            return RecordBatch(blob, offsets, lengths)
        (n,) = struct.unpack_from("<I", blob, 4)
        lengths = np.frombuffer(blob, dtype="<u4", count=n,
                                offset=8).astype(_I64)
        head = 8 + 4 * n
        offsets = np.full(n, head + HDR_SIZE * n, _I64)
        if n > 1:
            offsets[1:] += np.cumsum(lengths[:-1])
        out = RecordBatch(blob, offsets, lengths)
        out._hdr = np.frombuffer(blob, dtype=HDR_DTYPE, count=n,
                                 offset=head)
        # origin trailer past the payload (absent on pre-federation
        # senders; record offsets never reach it either way)
        end = head + HDR_SIZE * n + int(lengths.sum())
        if len(blob) >= end + 6:
            magic, tlen = struct.unpack_from("<IH", blob, end)
            if magic == WIRE2_ORIGIN_MAGIC and len(blob) >= end + 6 + tlen:
                out.origin = bytes(blob[end + 6:end + 6 + tlen]) \
                    .decode("utf-8")
        return out
