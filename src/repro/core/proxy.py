"""LCAP proxy — Lustre Changelog Aggregate and Publish (paper §III).

Broker between N producers (each exposing an ``Llog``) and M consumers:

- **greedy batched reads**: each ``pump()`` drains every producer's
  journal into an in-memory buffer (bounded; persistence stays upstream,
  which is what makes at-least-once acceptable — paper §III-A);
- **stream modules** pre-process batches at ingest (drop compensating
  pairs, reorder, filter — paper: shared-library modules);
- **consumer groups**: every record is delivered to *each* group and to
  exactly *one member* within a group (least-loaded dispatch →
  load-balanced processing);
- **ephemeral readers** receive only records ingested after they
  subscribed and never acknowledge (paper §IV-B);
- **collective acknowledgement**: a record is acknowledged upstream to
  the producer's journal only once every group has acknowledged it;
- **at-least-once**: when a consumer dies, its in-flight records are
  redelivered to surviving group members;
- **per-group backpressure**: a group with a saturated member parks its
  records (``Group.pending``, bounded by the outbox cap) while the
  other groups keep draining — one slow consumer never stalls the rest
  of the fleet;
- **restart resume**: the proxy registers as a named changelog reader
  per producer and, on restart, resumes at its *own* acked watermark —
  never at a trim point a slower co-registered reader holds back;
- **push-fed producers**: ``add_source``/``offer`` let a cluster
  coordinator (cluster.py) route record batches in by FID hash instead
  of the proxy pulling from a journal — the building block of the
  sharded deployment.

The unit of flow is a ``RecordBatch`` end to end: journals hand the
proxy zero-copy batch views, stream modules restructure them without
decoding payloads, and dispatch reads only the 8-byte packed index of
each record.  Records are materialized (one memcpy, still no decode)
only when placed in a consumer's outbox; per-consumer flag remapping
uses the plan cache in ``records`` and is a no-op for consumers that
ask for everything.

Subscriptions may carry an **op-type mask** in addition to the §IV-A
flag projection; both are enforced here at dispatch (server-side filter
pushdown): a record no subscriber asked for is acknowledged in place —
never materialized, never copied into an outbox.  Consumers that name a
**durable identity** (``name=``) survive disconnects: the proxy parks
their unacknowledged records and per-producer ack watermark under
``(group, name)`` for ``resume_ttl`` seconds, and a reconnecting
consumer under the same name resumes exactly at its cursor (its own
unacked records are replayed to it alone — no group-wide redelivery
storm).  Only when the park expires is the backlog redelivered to the
surviving members.

The core is synchronous (``pump()``) for determinism; ``LcapService``
(server.py) wraps it with a polling thread + TCP transport.
"""

from __future__ import annotations

import bisect
import itertools
import operator
import threading
import time
from collections import deque
from typing import (Callable, Deque, Dict, Iterable, List, Optional, Tuple)

import numpy as np

from . import records as R
from .ack import AckTracker
from .errors import (SubscriptionError, TenantError, UnknownConsumerError,
                     UnknownProducerError)
from .history import JournalReplayReader
from .llog import Llog
from .tenancy import TenantAccount, TenantPrincipal

Module = Callable[[R.RecordBatch], R.RecordBatch]

PERSISTENT = "persistent"
EPHEMERAL = "ephemeral"

_by_load = operator.attrgetter("load")   # Consumer.load, single definition


class PushSource:
    """Llog-protocol facade for a *push-fed* producer: a cluster
    coordinator (cluster.py) routes already-read record batches into the
    proxy with ``offer()`` instead of the proxy pulling from a journal.
    Reads return nothing, and upstream acks are recorded here for the
    coordinator to collect (the shard's per-journal watermark)."""

    __slots__ = ("producer_id", "first_index", "last_index", "acked",
                 "history_reader")

    def __init__(self, pid: str, first: int = 1):
        self.producer_id = pid
        self.first_index = first
        self.last_index = first - 1      # highest offered index
        self.acked = first - 1           # this shard's upstream watermark
        # replay source for push-fed shards: the cluster coordinator
        # installs a journal-backed, slot-filtered reader here so a
        # replay-bootstrap consumer on this shard can stream history
        self.history_reader = None

    def has_reader(self, rid: str) -> bool:
        return False

    def register_reader(self, name=None, resume: bool = False) -> str:
        return name or "push"

    def attach_reader(self, name: str) -> Tuple[str, int]:
        return name, self.first_index

    def read(self, start: int, max_records: int = 1024) -> R.RecordBatch:
        return R.RecordBatch.empty()     # push model: never pulled

    def ack(self, rid: str, index: int) -> None:
        if index > self.acked:
            self.acked = index


class _Outbox:
    """A consumer's delivery queue.  Entries are either single
    ``(pid, idx, packed)`` tuples (the per-record dispatch path) or
    whole stamped ``RecordBatch`` chunks (the columnar path) — a chunk
    enqueues and drains in O(1) and ``fetch_batches`` hands its rows
    out as a view, so the steady state never touches individual
    records.  ``len()`` counts *records*, matching the old deque of
    tuples that backpressure caps are written against."""

    __slots__ = ("_q", "_n")

    def __init__(self):
        self._q: Deque = deque()
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0

    def append(self, item: Tuple[str, int, bytes]) -> None:
        self._q.append(item)
        self._n += 1

    def append_chunk(self, pid: str, batch: R.RecordBatch,
                     idx: np.ndarray) -> None:
        self._q.append([pid, batch, idx, 0])   # mutable: [.., cursor]
        self._n += len(idx)

    def popleft(self) -> Tuple[str, int, bytes]:
        q = self._q
        e = q[0]
        if type(e) is tuple:
            q.popleft()
            self._n -= 1
            return e
        pid, batch, idx, pos = e               # explode one chunk row
        out = (pid, int(idx[pos]), batch.packed(pos))
        pos += 1
        if pos == len(idx):
            q.popleft()
        else:
            e[3] = pos
        self._n -= 1
        return out

    def pop_batches(self, max_records: int) -> List[Tuple[str,
                                                          R.RecordBatch]]:
        """Drain up to ``max_records`` as ``(pid, RecordBatch)`` runs.
        Chunks pop whole (or split at the budget boundary, a view);
        consecutive same-producer singles coalesce into one batch."""
        out: List[Tuple[str, R.RecordBatch]] = []
        q = self._q
        taken = 0
        run_pid: Optional[str] = None
        run_bufs: Optional[List[bytes]] = None
        while q and taken < max_records:
            e = q[0]
            if type(e) is tuple:
                pid, _idx, buf = e
                if run_pid != pid or run_bufs is None:
                    if run_bufs:
                        out.append((run_pid,
                                    R.RecordBatch.from_packed(run_bufs)))
                    run_pid, run_bufs = pid, []
                run_bufs.append(buf)
                q.popleft()
                self._n -= 1
                taken += 1
                continue
            if run_bufs:
                out.append((run_pid, R.RecordBatch.from_packed(run_bufs)))
                run_pid, run_bufs = None, None
            pid, batch, idx, pos = e
            avail = len(idx) - pos
            k = min(avail, max_records - taken)
            sub = batch if (pos == 0 and k == avail) else batch[pos:pos + k]
            out.append((pid, sub))
            taken += k
            self._n -= k
            if k == avail:
                q.popleft()
            else:
                e[3] = pos + k
        if run_bufs:
            out.append((run_pid, R.RecordBatch.from_packed(run_bufs)))
        return out


class _InFlight:
    """``(pid, idx) -> packed record`` for redelivery, stored either
    singly (dict) or as whole original-batch chunks with an alive mask
    so a columnar dispatch records a thousand in-flight entries in O(1)
    and a batched commit retires them with one vectorized membership
    test.  ``len()`` counts records (feeds ``Consumer.load``)."""

    __slots__ = ("_map", "_chunks", "_nchunk")

    def __init__(self):
        self._map: Dict[Tuple[str, int], bytes] = {}
        # [pid, batch, idx, alive mask (None == all), alive count]
        self._chunks: List[list] = []
        self._nchunk = 0

    def __len__(self) -> int:
        return len(self._map) + self._nchunk

    def __bool__(self) -> bool:
        return bool(self._map) or self._nchunk > 0

    def __setitem__(self, key: Tuple[str, int], buf: bytes) -> None:
        self._map[key] = buf

    def add_chunk(self, pid: str, batch: R.RecordBatch,
                  idx: np.ndarray) -> None:
        self._chunks.append([pid, batch, idx, None, len(idx)])
        self._nchunk += len(idx)

    def discard_many(self, pid: str, arr: np.ndarray) -> None:
        """Retire every ``(pid, i)`` for i in ``arr`` (int64 array);
        absent indices are ignored, like ``dict.pop(..., None)``."""
        if self._map:
            if len(self._map) * 4 < arr.size:
                # few singles, big ack batch: test each key against the
                # sorted ack array instead of popping per index
                lst = arr.tolist()
                n = len(lst)
                for key in [k for k in self._map if k[0] == pid]:
                    j = bisect.bisect_left(lst, key[1])
                    if j < n and lst[j] == key[1]:
                        del self._map[key]
            else:
                pop = self._map.pop
                for i in arr.tolist():
                    pop((pid, i), None)
        if not self._nchunk:
            return
        kept = []
        removed = 0
        for ch in self._chunks:
            if ch[0] != pid:
                kept.append(ch)
                continue
            hit = np.isin(ch[2], arr)
            if ch[3] is not None:
                hit &= ch[3]
            nhit = int(np.count_nonzero(hit))
            if nhit == 0:
                kept.append(ch)
                continue
            removed += nhit
            if nhit == ch[4]:
                continue                       # chunk fully retired
            ch[3] = ~hit if ch[3] is None else ch[3] & ~hit
            ch[4] -= nhit
            kept.append(ch)
        self._chunks = kept
        self._nchunk -= removed

    def items(self):
        yield from self._map.items()
        for pid, batch, idx, alive, nalive in self._chunks:
            rows = range(len(idx)) if alive is None \
                else np.flatnonzero(alive).tolist()
            for j in rows:
                yield (pid, int(idx[j])), batch.packed(j)


class Consumer:
    def __init__(self, cid: str, group: Optional[str], flags: int, mode: str,
                 types: Optional[Iterable[int]] = None,
                 name: Optional[str] = None,
                 tenant: Optional[TenantPrincipal] = None):
        self.cid = cid
        self.group = group
        self.flags = R.normalize_flags(flags)
        self.mode = mode
        self.types = frozenset(types) if types is not None else None
        self.name = name                     # durable identity within group
        #: visibility scope; None = trusted unscoped consumer.  Scope is
        #: enforced at dispatch exactly like the op-type mask (pushdown)
        self.tenant = tenant
        #: the proxy's per-tenant accounting record (quota buckets,
        #: delivered counters); installed at attach, shared per tenant
        self.account: Optional[TenantAccount] = None
        self.outbox = _Outbox()
        # (producer, index) -> packed record, for redelivery
        self.in_flight = _InFlight()
        self.acked_hi: Dict[str, int] = {}   # pid -> highest acked index
        self.alive = True
        self.delivered = 0
        # replay-bootstrap state: while any pid is listed here the
        # consumer streams history (fetch_replay); live fetches wait
        self.replay_src: Dict[str, object] = {}   # pid -> replay reader
        self.replay_pos: Dict[str, int] = {}      # pid -> next index
        self.replay_hw: Dict[str, int] = {}       # pid -> handoff watermark
        self.replay_lo: Dict[str, int] = {}       # pid -> bootstrap start

    @property
    def load(self) -> int:
        return len(self.outbox) + len(self.in_flight)

    def wants(self, rtype: int) -> bool:
        return self.types is None or rtype in self.types


class Group:
    def __init__(self, name: str):
        self.name = name
        self.members: Dict[str, Consumer] = {}
        self.trackers: Dict[str, AckTracker] = {}
        self.pending: Deque[Tuple[str, int, bytes]] = deque()  # no member yet
        self.durable: Dict[str, str] = {}    # durable name -> active cid
        # durable name -> (parked consumer, resume deadline)
        self.parked: Dict[str, Tuple[Consumer, float]] = {}

    def tracker(self, pid: str) -> AckTracker:
        if pid not in self.trackers:
            self.trackers[pid] = AckTracker()
        return self.trackers[pid]


class LcapProxy:
    def __init__(self, producers: Dict[str, Llog],
                 modules: Optional[List[Module]] = None,
                 batch_size: int = 1024, max_buffer: int = 1 << 20,
                 outbox_cap: int = 1 << 16, resume_ttl: float = 30.0,
                 dispatch_quantum: Optional[int] = None):
        self.producers = dict(producers)
        self.modules = list(modules or [])
        self.batch_size = batch_size
        self.max_buffer = max_buffer          # records, across buffered batches
        self.outbox_cap = outbox_cap
        self.resume_ttl = resume_ttl          # durable park window (seconds)
        # records dispatched per _dispatch call (None = drain the whole
        # buffer).  A server proxy sets a quantum so one pump never
        # holds the lock across a huge buffer while fetch/commit
        # requests from live consumers queue behind it.
        self.dispatch_quantum = dispatch_quantum
        self._lock = threading.RLock()
        self._cid_seq = itertools.count(1)
        self._ingest_rotation = itertools.count()  # producer fairness
        self.reader_ids: Dict[str, str] = {}
        self.cursors: Dict[str, int] = {}
        self.ingested: Dict[str, int] = {}
        self.upstream_acked: Dict[str, int] = {}
        # register as a regular changelog reader with every producer (§III)
        for pid, log in self.producers.items():
            self._register_producer(pid, log)
        self.groups: Dict[str, Group] = {}
        self.consumers: Dict[str, Consumer] = {}
        self._buffer: Deque[Tuple[str, R.RecordBatch]] = deque()
        self._buffered = 0                    # records currently in _buffer
        self.stats = {"ingested": 0, "dispatched": 0, "dropped_by_modules": 0,
                      "redelivered": 0, "acked_upstream": 0,
                      "ephemeral_drops": 0, "batches_ingested": 0,
                      "filtered_out": 0, "parked": 0, "resumed": 0,
                      "resume_replayed": 0, "parks_expired": 0,
                      "replayed": 0, "tenant_filtered": 0}
        #: tenant name -> TenantAccount (quota buckets + delivery
        #: counters), created lazily on first attach or set_tenant_quota
        self.tenants: Dict[str, TenantAccount] = {}
        # observability plane (attach_registry): None until attached, so
        # the hot path pays a single identity check when unused
        self._obs = None
        self._obs_pump_hist = None

    def _register_producer(self, pid: str, log: Llog) -> None:
        """Register with ``log`` as the lcap reader and position the
        ingest cursor (``Llog.attach_reader``).  A fresh proxy consumes
        the journal's whole live backlog and owes acks for it; a
        *restarted* proxy resumes at its own acked watermark, not at
        the journal's ``first_index`` — another registered reader
        lagging behind holds the trim point back, and re-ingesting
        records this proxy already delivered and acked would duplicate
        them to every group."""
        rid, start = log.attach_reader(f"lcap-{pid}")
        self.reader_ids[pid] = rid
        self.cursors[pid] = start
        self.ingested[pid] = start - 1
        self.upstream_acked[pid] = start - 1

    # ------------------------------------------------------------------ API
    def add_producer(self, pid: str, log: Llog) -> None:
        with self._lock:
            self.producers[pid] = log
            self._register_producer(pid, log)
            # live ephemeral consumers connected before this producer
            # joined: stamp their connection point, or ``since.get(pid,
            # -1)`` hands them every record already in the journal —
            # history, which §IV-B forbids
            for cons in self.consumers.values():
                if cons.mode == EPHEMERAL:
                    cons.since[pid] = log.last_index  # type: ignore

    def add_source(self, pid: str, first: int = 1) -> None:
        """Register a push-fed producer: the records of journal ``pid``
        arrive via ``offer()`` (routed there by a cluster coordinator)
        instead of being pulled.  ``first`` is the journal index the
        feed starts at; the shard's collective watermark for the journal
        is collected from the source's ``acked``."""
        self.add_producer(pid, PushSource(pid, first))

    def offer(self, pid: str, batch: R.RecordBatch,
              hi: Optional[int] = None) -> int:
        """Push a batch of journal ``pid`` records into the ingest
        buffer (the cluster-routing counterpart of ``_ingest``).

        ``hi`` is the highest journal index *scanned* on the caller's
        side — it may exceed the batch's own highest index when the
        records in between were routed to other shards, and the ingest
        watermark advances to it so a shard that owns none of a range
        still lets the collective upstream ack progress.  Re-offering
        records below the watermark (failover redelivery) never moves
        it backwards.  Returns the number of records admitted."""
        with self._lock:
            src = self.producers.get(pid)
            if src is None:
                raise UnknownProducerError(f"unknown producer {pid!r}")
            got = len(batch)
            if hi is None:
                if not got:
                    return 0
                hi = batch.packed_index(got - 1)
            if isinstance(src, PushSource) and hi > src.last_index:
                src.last_index = hi
            if got:
                kept = self._admit_locked(pid, batch, hi)
            else:                          # bare watermark advance
                kept = 0
                if hi > self.ingested.get(pid, -1):
                    self.ingested[pid] = hi
            self.stats["ingested"] += got
            if not kept:
                # a pure watermark advance (or a fully module-dropped
                # batch) completes this shard's position without any
                # consumer commit — propagate, exactly like the
                # filter-pushdown path in pump()
                self._flush_upstream_locked()
            return kept

    def offer_many(self, offers: Iterable[Tuple[str, R.RecordBatch,
                                                Optional[int]]]) -> int:
        """A whole routing round of ``(pid, batch, hi)`` offers admitted
        under one lock acquisition — the deep-batched cluster ingest
        path (one wire call, one lock, N batches)."""
        admitted = 0
        with self._lock:
            for pid, batch, hi in offers:
                admitted += self.offer(pid, batch, hi)
        return admitted

    def ensure_group(self, name: str) -> None:
        """Pre-create consumer group ``name`` with no members: records
        dispatched to it park in the group's pending backlog (and gate
        the collective ack) until a member subscribes.  This is how the
        cluster replicates existing group registrations onto a shard
        that joins *after* the groups did — nothing routed to the new
        shard is consumed-and-acked before the groups' fan-in streams
        discover it."""
        with self._lock:
            self.groups.setdefault(name, Group(name))

    def subscribe(self, group: Optional[str], flags: Optional[int] = None,
                  mode: str = PERSISTENT, cid: Optional[str] = None,
                  types: Optional[Iterable[int]] = None,
                  name: Optional[str] = None,
                  tenant: Optional[TenantPrincipal] = None) -> str:
        """Register a consumer; returns its cid.  See ``attach`` for the
        full subscription contract (this is the thin historical form)."""
        return self.attach(group, flags=flags, mode=mode, cid=cid,
                           types=types, name=name, tenant=tenant)["cid"]

    def attach(self, group: Optional[str], flags: Optional[int] = None,
               mode: str = PERSISTENT, cid: Optional[str] = None,
               types: Optional[Iterable[int]] = None,
               name: Optional[str] = None,
               resume: Optional[bool] = None,
               replay: Optional[object] = None,
               tenant: Optional[TenantPrincipal] = None) -> Dict:
        """Register a consumer and return ``{"cid", "resumed", "token"}``.

        Persistent consumers name a group and share its stream; ephemeral
        consumers pass ``mode=EPHEMERAL`` (group may be None) and only see
        records ingested afterwards.  ``flags`` is the §IV-A field
        projection (None = everything supported; unknown bits are masked
        here, the single enforcement point) and ``types`` the op-type
        mask — both pushed down to dispatch.  Masks are evaluated
        against the *live* membership at dispatch/redelivery time: a
        record no live member asks for is acknowledged in place, so
        groups that care about completeness should keep member masks
        homogeneous.  ``name`` makes a persistent consumer durable: if
        parked state exists under ``(group, name)`` the consumer
        resumes at its ack cursor, inheriting the parked flags/types
        unless new ones are passed (``resume=True`` demands that state
        exists, ``resume=False`` forbids using it).  The returned
        ``token`` maps producer -> highest acked index.

        ``replay`` bootstraps the consumer from the compacted history
        tier: ``True`` replays from the beginning, an integer from that
        journal index.  History batches are streamed first (via
        ``fetch_replay``); the live stream takes over at a per-producer
        handoff watermark recorded at attach time — no gap, no
        duplicate.  Replay requires every producer to have a replayable
        history source and, for persistent mode, a *fresh* group (a
        group with existing delivery state already consumed part of the
        stream and would double-apply it).

        ``tenant`` scopes the consumer to a ``TenantPrincipal``: only
        records whose jobid matches the tenant's scope are ever
        delivered (live, replay, redelivery, resume); everything else
        is acknowledged in place server-side, like the type mask.  A
        durable consumer's tenant parks with it — resuming under a
        *different* tenant (or dropping a parked tenant) raises
        ``TenantError``.
        """
        tenant = TenantPrincipal.from_wire(tenant)
        with self._lock:
            self._expire_parked_locked()
            if resume and not name:
                raise SubscriptionError("resume requires a durable "
                                        "consumer name")
            if replay not in (None, False):
                if resume:
                    raise SubscriptionError("replay cannot be combined "
                                            "with resume: a resumed durable "
                                            "consumer already has a cursor")
                if mode == PERSISTENT and group in self.groups:
                    raise SubscriptionError(
                        f"replay-bootstrap requires a fresh group "
                        f"({group!r} already has delivery state)")
            cid = cid or f"c{next(self._cid_seq)}"
            if cid in self.consumers:
                raise SubscriptionError(f"consumer {cid} exists")
            if mode == PERSISTENT:
                if not group:
                    raise SubscriptionError("persistent consumers need a "
                                            "group")
                grp = self.groups.setdefault(group, Group(group))
                if name:
                    if name in grp.durable:
                        raise SubscriptionError(
                            f"durable consumer {group}/{name} is already "
                            f"attached as {grp.durable[name]}")
                    if name in grp.parked:
                        if resume is False:
                            raise SubscriptionError(
                                f"durable consumer {group}/{name} has "
                                f"parked state; resume or forget it first")
                        return self._resume_locked(grp, name, cid, flags,
                                                   types, tenant)
                if resume:
                    raise UnknownConsumerError(
                        f"no parked state for durable consumer "
                        f"{group}/{name!r}")
                cons = Consumer(cid, group, flags, mode, types=types,
                                name=name, tenant=tenant)
                self._bind_tenant(cons)
                self._join_group(grp, cons)
                self._flush_upstream_locked()   # drain may ack in place
            elif mode == EPHEMERAL:
                if name:
                    raise SubscriptionError("ephemeral consumers cannot be "
                                            "durable")
                cons = Consumer(cid, None, flags, mode, types=types,
                                tenant=tenant)
                self._bind_tenant(cons)
                # connection point: nothing *emitted* before now (§IV-B).
                # Producer last_index, not the ingest cursor — records
                # journaled but not yet pumped at attach time are
                # history, regardless of poller timing.
                cons.since = {  # type: ignore[attr-defined]
                    pid: log.last_index
                    for pid, log in self.producers.items()}
            else:
                raise SubscriptionError(f"unknown mode {mode}")
            if replay not in (None, False):
                try:
                    self._arm_replay_locked(cons, replay)
                except Exception:
                    # the group was fresh (checked above): undo its
                    # creation so a failed replay attach leaves no state
                    if cons.mode == PERSISTENT:
                        self.groups.pop(cons.group, None)
                    raise
            self.consumers[cid] = cons
            return {"cid": cid, "resumed": False, "flags": cons.flags,
                    "token": dict(cons.acked_hi),
                    "replay": bool(cons.replay_pos)}

    def _join_group(self, grp: Group, cons: Consumer) -> None:
        grp.members[cons.cid] = cons
        if cons.name:
            grp.durable[cons.name] = cons.cid
        # drain records parked while the group had no members through
        # normal group dispatch (deliver is a dedup no-op).  The batch
        # hot loop in _dispatch inlines this same policy — keep the two
        # in step when changing either.
        pending, grp.pending = grp.pending, deque()
        for pid, idx, buf in pending:
            self._dispatch_to_group(grp, pid, idx, buf)

    def _resume_locked(self, grp: Group, name: str, cid: str,
                       flags: Optional[int],
                       types: Optional[Iterable[int]],
                       tenant: Optional[TenantPrincipal] = None) -> Dict:
        old = grp.parked[name][0]
        # tenant identity is part of the durable cursor: a bare resume
        # inherits the parked tenant, but a *different* principal can
        # never take over the cursor, and a parked scope can never be
        # widened by resuming with a different one — that would hand
        # one tenant another tenant's in-flight records
        if old.tenant is not None and tenant is not None \
                and tenant != old.tenant:
            raise TenantError(
                f"durable consumer {grp.name}/{name} is owned by tenant "
                f"{old.tenant.name!r}; cannot resume as {tenant.name!r}")
        if old.tenant is None and tenant is not None:
            raise TenantError(
                f"durable consumer {grp.name}/{name} parked unscoped; "
                f"resuming it under tenant {tenant.name!r} would "
                f"re-scope another identity's cursor")
        grp.parked.pop(name)
        # the parked subscription spec is the default: a bare
        # resume(group, name) keeps the filters the consumer declared;
        # passing flags/types explicitly overrides them
        cons = Consumer(cid, grp.name,
                        old.flags if flags is None else flags,
                        PERSISTENT,
                        types=old.types if types is None else types,
                        name=name, tenant=old.tenant)
        self._bind_tenant(cons)
        cons.acked_hi = old.acked_hi
        # an interrupted replay bootstrap continues where it stopped
        cons.replay_src = old.replay_src
        cons.replay_pos = old.replay_pos
        cons.replay_hw = old.replay_hw
        cons.replay_lo = old.replay_lo
        # exact cursor resume: everything the old incarnation had not
        # acked is replayed to the resuming consumer alone — the group
        # never sees a redelivery storm.  Records an explicitly
        # narrowed type mask no longer covers go back through group
        # dispatch instead (another member that wants them, or acked in
        # place) — cons is not yet a member, so it cannot get them.
        replayed = 0
        for (pid, idx), buf in sorted(old.in_flight.items()):
            if cons.wants(R.packed_type(buf)):
                self._hand_to(cons, pid, idx, buf)
                replayed += 1
            else:
                self._dispatch_to_group(grp, pid, idx, buf)
        self.stats["resumed"] += 1
        self.stats["resume_replayed"] += replayed
        self._join_group(grp, cons)
        self.consumers[cid] = cons
        self._flush_upstream_locked()       # narrowing may ack in place
        return {"cid": cid, "resumed": True, "flags": cons.flags,
                "token": dict(cons.acked_hi),
                "replay": bool(cons.replay_pos)}

    def unsubscribe(self, cid: str, failed: bool = False) -> None:
        """Remove a consumer for good (durable state included).  Its
        undelivered/unacked records go back to the group
        (at-least-once)."""
        with self._lock:
            cons = self.consumers.pop(cid, None)
            if cons is None:
                return
            cons.alive = False
            if cons.mode == EPHEMERAL:
                return
            grp = self.groups[cons.group]
            del grp.members[cid]
            if cons.name:
                grp.durable.pop(cons.name, None)
            # in_flight covers everything undelivered OR unacked (records
            # are tracked there from dispatch until ack), so it alone is
            # the redelivery backlog — using outbox too would duplicate
            # queued-but-unfetched records.
            self._redeliver(grp, cons)
            self._flush_upstream_locked()   # redelivery may ack in place

    def _redeliver(self, grp: Group, cons: Consumer) -> None:
        backlog = sorted(
            (pid, idx, buf) for (pid, idx), buf in cons.in_flight.items())
        self.stats["redelivered"] += len(backlog)
        for pid, idx, buf in backlog:
            self._dispatch_to_group(grp, pid, idx, buf)

    fail = lambda self, cid: self.unsubscribe(cid, failed=True)  # noqa: E731

    def disconnect(self, cid: str) -> None:
        """A consumer's connection went away without a clean close.
        Durable consumers are parked: their unacked records and ack
        cursor wait ``resume_ttl`` seconds under ``(group, name)`` for
        the same name to reconnect.  Anonymous consumers fail
        immediately (backlog redelivered to the group)."""
        with self._lock:
            cons = self.consumers.get(cid)
            if cons is None:
                return
            if cons.mode == EPHEMERAL or not cons.name:
                self.unsubscribe(cid, failed=True)
                return
            del self.consumers[cid]
            cons.alive = False
            grp = self.groups[cons.group]
            del grp.members[cid]
            grp.durable.pop(cons.name, None)
            grp.parked[cons.name] = (cons, self._now() + self.resume_ttl)
            self.stats["parked"] += 1

    def forget(self, group: str, name: str) -> None:
        """Drop a parked durable consumer without waiting for its TTL;
        its backlog is redelivered to the surviving members."""
        with self._lock:
            grp = self.groups.get(group)
            if grp is None or name not in grp.parked:
                raise UnknownConsumerError(
                    f"no parked state for durable consumer {group}/{name!r}")
            cons, _ = grp.parked.pop(name)
            self._redeliver(grp, cons)
            self._flush_upstream_locked()   # redelivery may ack in place

    _now = staticmethod(time.monotonic)

    def _expire_parked_locked(self) -> None:
        now = self._now()
        expired = False
        for grp in self.groups.values():
            if not grp.parked:
                continue
            for name in [n for n, (_, dl) in grp.parked.items() if dl <= now]:
                cons, _ = grp.parked.pop(name)
                self.stats["parks_expired"] += 1
                self._redeliver(grp, cons)
                expired = True
        if expired:
            self._flush_upstream_locked()   # redelivery may ack in place

    def expire_parked(self) -> None:
        """Redeliver the backlog of parked durable consumers whose
        resume window has lapsed (also runs on every ``pump``)."""
        with self._lock:
            self._expire_parked_locked()

    def _consumer(self, cid: str) -> Consumer:
        try:
            return self.consumers[cid]
        except KeyError:
            raise UnknownConsumerError(
                f"unknown or unsubscribed consumer {cid!r}") from None

    # ------------------------------------------------------------- ingest
    def _ingest(self) -> int:
        n = 0
        # rotate the producer order across pumps: draining dict order
        # first starves late producers whenever the buffer cap is hit
        # before the loop reaches them
        items = list(self.producers.items())
        if len(items) > 1:
            k = next(self._ingest_rotation) % len(items)
            items = items[k:] + items[:k]
        for pid, log in items:
            while self._buffered < self.max_buffer:
                batch = log.read(self.cursors[pid], self.batch_size)
                if not batch:
                    break
                got = len(batch)
                hi = batch.packed_index(got - 1)   # journal order: ascending
                self.cursors[pid] = hi + 1
                self._admit_locked(pid, batch, hi)
                n += got
                if got < self.batch_size:
                    break
        self.stats["ingested"] += n
        return n

    def _admit_locked(self, pid: str, batch: R.RecordBatch, hi: int) -> int:
        """Run the stream modules over ``batch`` and buffer the
        survivors; advance the ingest watermark to ``hi`` (the highest
        *scanned* journal index, which may exceed the highest kept one).
        Shared by the pull path (``_ingest``) and the push path
        (``offer``); returns how many records were kept."""
        got = len(batch)
        kept = batch
        for mod in self.modules:
            kept = mod(kept)
        if not isinstance(kept, R.RecordBatch):      # legacy list module
            kept = R.RecordBatch.from_records(kept)
        self.stats["dropped_by_modules"] += got - len(kept)
        if len(kept):
            self._buffer.append((pid, kept))
            self._buffered += len(kept)
        if hi > self.ingested.get(pid, -1):
            self.ingested[pid] = hi
        self.stats["batches_ingested"] += 1
        return len(kept)

    # ----------------------------------------------------------- dispatch
    def _hand_to(self, cons: Consumer, pid: str, idx: int, buf: bytes) -> None:
        # remote remap: strip fields the consumer did not ask for (§IV-A)
        out = R.remap_cached(buf, R.packed_flags(buf) & cons.flags)
        cons.outbox.append((pid, idx, out))
        cons.in_flight[(pid, idx)] = buf
        cons.delivered += 1
        if cons.account is not None:
            cons.account.charge(1, len(buf))
        self.stats["dispatched"] += 1

    def _dispatch_to_group(self, grp: Group, pid: str, idx: int,
                           buf: bytes) -> None:
        grp.tracker(pid).deliver(idx)
        live = [m for m in grp.members.values() if m.alive]
        if not live:
            grp.pending.append((pid, idx, buf))
            return
        want = [m for m in live if m.wants(R.packed_type(buf))]
        if want and any(m.tenant is not None for m in want):
            jb = R.packed_jobid(buf)
            kept = [m for m in want
                    if m.tenant is None or m.tenant.allows(jb)]
            if not kept and want:
                self.stats["tenant_filtered"] += 1
            want = kept
        if not want:                             # pushdown: nobody asked
            grp.tracker(pid).ack(idx)
            self.stats["filtered_out"] += 1
            return
        cons = min(want, key=_by_load)           # least-loaded (§III-A)
        self._hand_to(cons, pid, idx, buf)

    def _saturated(self, grp: Group) -> bool:
        cap = self.outbox_cap
        return any(len(m.outbox) >= cap
                   for m in grp.members.values() if m.alive)

    # ------------------------------------------------------------- tenancy
    def _bind_tenant(self, cons: Consumer) -> None:
        """Point the consumer at its tenant's shared accounting record
        (created on first sight) so the hot path charges quota with one
        attribute read instead of a dict lookup."""
        if cons.tenant is not None:
            cons.account = self.tenants.setdefault(
                cons.tenant.name, TenantAccount(cons.tenant.name))

    def set_tenant_quota(self, tenant: str,
                         records_per_s: Optional[float] = None,
                         bytes_per_s: Optional[float] = None,
                         burst_records: Optional[float] = None,
                         burst_bytes: Optional[float] = None) -> None:
        """Install (or clear, with both rates None) delivery token
        buckets for ``tenant``.  An over-quota tenant's groups park
        through the per-group backpressure path and resume as the
        buckets refill — records are delayed, never lost."""
        with self._lock:
            acct = self.tenants.setdefault(tenant, TenantAccount(tenant))
            acct.set_quota(records_per_s, bytes_per_s,
                           burst_records, burst_bytes)

    def _quota_blocked(self, grp: Group) -> bool:
        """True when any live member's tenant has an exhausted bucket:
        the whole group parks (backpressure is per group, and a group
        is one logical subscriber)."""
        for m in grp.members.values():
            if m.alive and m.account is not None and m.account.exhausted:
                return True
        return False

    def _blocked(self, grp: Group) -> bool:
        return self._saturated(grp) or self._quota_blocked(grp)

    def _refill_quota_locked(self) -> None:
        if self.tenants:
            now = self._now()
            for acct in self.tenants.values():
                acct.refill(now)

    @staticmethod
    def _spread(loads: List[int], k: int) -> List[int]:
        """How many of ``k`` records each member takes when every record
        goes to the currently least-loaded member.  Matches the scalar
        loop exactly: each assignment raises that member's load by 2
        (outbox + in_flight), ties break on list position.

        Closed form instead of simulating k heap pops: member ``j``'s
        successive pick keys are ``loads[j], loads[j]+2, loads[j]+4,
        ...`` and the scalar loop takes the k lexicographically
        smallest ``(key, j)`` pairs, so counts fall out of the k-th
        smallest key (binary search) plus position-ordered tie-breaks
        at that key."""
        if len(loads) == 1:
            return [k]
        if not k:
            return [0] * len(loads)
        arr = np.asarray(loads, dtype=np.int64)
        # smallest T with >= k pick keys valued <= T
        lo, hi = int(arr.min()), int(arr.min()) + 2 * k
        while lo < hi:
            mid = (lo + hi) // 2
            if int(np.where(arr <= mid,
                            (mid - arr) // 2 + 1, 0).sum()) >= k:
                hi = mid
            else:
                lo = mid + 1
        counts = np.where(arr <= lo - 1, (lo - 1 - arr) // 2 + 1, 0)
        rem = k - int(counts.sum())
        if rem:                       # members holding a key == T, in
            at = np.flatnonzero(      # list position order
                (arr <= lo) & ((lo - arr) % 2 == 0))
            counts[at[:rem]] += 1
        return counts.tolist()

    def _fast_eligible(self, groups, ephemerals, states_sat, total: int,
                       done: int) -> bool:
        """Whole-batch columnar dispatch preserves the scalar loop's
        observable behavior only when nothing can interrupt the batch:
        no quantum boundary, no group without live members or with a
        parked backlog, and enough outbox headroom that not even the
        most loaded member could hit the cap mid-batch."""
        q = self.dispatch_quantum
        if q is not None and done + total > q:
            return False
        cap = self.outbox_cap
        for g in groups:
            if states_sat[g.name] or g.pending:
                return False
            live_out = [len(m.outbox) for m in g.members.values() if m.alive]
            if not live_out or max(live_out) + total >= cap:
                return False
        return all(len(c.outbox) + total <= cap for c in ephemerals)

    def _dispatch_batch(self, pid: str, batch: R.RecordBatch,
                        groups, ephemerals) -> Tuple[int, int]:
        """Columnar whole-batch dispatch (the hot path): one header
        decode, one bulk tracker delivery per group, boolean-mask type
        pushdown, water-fill assignment, and O(1) chunk handoff to each
        chosen member.  Returns (dispatched, filtered_out)."""
        total = len(batch)
        idx = batch.indices_np().astype(np.int64)
        types: Optional[np.ndarray] = None
        jobids: Optional[np.ndarray] = None
        dispatched = 0
        filtered_out = 0
        tenant_filtered = 0
        all_rows = np.arange(total)

        def jobid_cols() -> np.ndarray:
            # one jobid gather per batch, shared by every scoped
            # consumer in this call: the uint64 word form when every
            # scope fits a machine word (the overwhelmingly common
            # case), else a byte matrix trimmed to the widest scope
            # entry (NUL padding makes the tail bytes redundant)
            w = 1
            word = True
            for g2 in groups:
                for m2 in g2.members.values():
                    if m2.alive and m2.tenant is not None:
                        w = max(w, m2.tenant.mask_width)
                        word = word and m2.tenant.word_scoped
            for c2 in ephemerals:
                if c2.tenant is not None:
                    w = max(w, c2.tenant.mask_width)
                    word = word and c2.tenant.word_scoped
            return batch.jobid_word() if word else batch.jobid_col(w)
        for g in groups:
            live = [m for m in g.members.values() if m.alive]
            tracker = g.tracker(pid)
            tracker.deliver_many(idx)
            scoped = any(m.tenant is not None for m in live)
            if scoped and len(live) == 1:
                # the common shape — one scoped member — needs no
                # bitset partition: one scope mask, a two-way split
                # (and no split at all when every row is in scope)
                m = live[0]
                if jobids is None:
                    jobids = jobid_cols()
                sm = m.tenant.scope_mask(jobids)
                if m.types is not None:
                    if types is None:
                        types = batch.types_np()
                    tm = np.isin(types, sorted(m.types))
                    sm &= tm
                    nf = int(tm.sum() - sm.sum())
                else:
                    nf = int(total - sm.sum())
                tenant_filtered += nf
                if nf and m.account is not None:
                    m.account.filtered_records += nf
                if sm.all():
                    parts = [(live, all_rows)]
                else:
                    parts = [(live, np.flatnonzero(sm)),
                             ([], np.flatnonzero(~sm))]
            elif scoped:
                # tenant pushdown: eligibility depends on (type, jobid),
                # so rows partition by the per-member eligibility bitset
                # — one vectorized scope mask per scoped member, one
                # water-fill per distinct set, never per record
                if types is None:
                    types = batch.types_np()
                if jobids is None:
                    jobids = jobid_cols()
                key = np.zeros(total, dtype=np.int64)
                key_any = np.zeros(total, dtype=bool)  # type-eligible only
                for bit, m in enumerate(live):
                    if m.types is None and m.tenant is None:
                        key |= np.int64(1) << bit
                        key_any[:] = True
                        continue
                    if m.types is not None:
                        tmask = np.isin(types, sorted(m.types))
                    else:
                        tmask = np.ones(total, dtype=bool)
                    key_any |= tmask
                    if m.tenant is not None:
                        sm = tmask & m.tenant.scope_mask(jobids)
                        if m.account is not None:
                            nf = int(tmask.sum() - sm.sum())
                            if nf:
                                m.account.filtered_records += nf
                        tmask = sm
                    key |= tmask.astype(np.int64) << bit
                parts = []
                for k in np.unique(key).tolist():
                    rows = np.flatnonzero(key == k)
                    members = [m for bit, m in enumerate(live)
                               if (k >> bit) & 1]
                    if not members:
                        # out-of-scope rows a type-eligible member would
                        # otherwise have received: the tenant mask (not
                        # the type mask) is what acked them in place
                        tenant_filtered += int(key_any[rows].sum())
                    parts.append((members, rows))
            elif any(m.types is not None for m in live):
                if types is None:
                    types = batch.types_np()
                # rows partition by *eligible member set*: one water-fill
                # per distinct set, never per record
                classes: Dict[tuple, List[int]] = {}
                for t in np.unique(types).tolist():
                    want = tuple(m.cid for m in live if m.wants(t))
                    classes.setdefault(want, []).append(t)
                parts = []
                for want, ts in classes.items():
                    rows = np.flatnonzero(np.isin(types, ts))
                    members = [m for m in live if m.cid in set(want)]
                    parts.append((members, rows))
            else:
                parts = [(live, all_rows)]
            for members, rows in parts:
                if not members:              # pushdown: nobody asked
                    tracker.ack_many(idx[rows])
                    filtered_out += len(rows)
                    continue
                counts = self._spread([m.load for m in members], len(rows))
                lo = 0
                for m, cnt in zip(members, counts):
                    if not cnt:
                        continue
                    sel = rows[lo:lo + cnt]
                    lo += cnt
                    sub = batch if len(sel) == total else batch.select(sel)
                    m.outbox.append_chunk(pid, sub.project(m.flags),
                                          idx[sel])
                    m.in_flight.add_chunk(pid, sub, idx[sel])
                    m.delivered += cnt
                    if m.account is not None:
                        m.account.charge(cnt, sub.nbytes)
                    dispatched += cnt
        for c in ephemerals:
            mask = idx > c.since.get(pid, -1)   # type: ignore[attr-defined]
            if c.types is not None:
                if types is None:
                    types = batch.types_np()
                mask &= np.isin(types, sorted(c.types))
            if c.tenant is not None:
                if jobids is None:
                    jobids = jobid_cols()
                sm = mask & c.tenant.scope_mask(jobids)
                if c.account is not None:
                    nf = int(mask.sum() - sm.sum())
                    if nf:
                        c.account.filtered_records += nf
                mask = sm
            rows = np.flatnonzero(mask)
            if not rows.size:
                continue
            sub = batch if rows.size == total else batch.select(rows)
            c.outbox.append_chunk(pid, sub.project(c.flags), idx[rows])
            if c.account is not None:
                c.account.charge(rows.size, sub.nbytes)
        if tenant_filtered:
            self.stats["tenant_filtered"] += tenant_filtered
        return dispatched, filtered_out

    def _dispatch(self) -> int:
        n = 0
        cap = self.outbox_cap
        groups = list(self.groups.values())
        ephemerals = [c for c in self.consumers.values()
                      if c.mode == EPHEMERAL and c.alive]
        # per-tenant quota: refill the token buckets once per dispatch;
        # a group whose tenant is over quota parks exactly like a group
        # with a saturated member (the same backpressure path) and
        # drains again as the buckets refill
        self._refill_quota_locked()
        # backpressure is per *group*: a group with a saturated member
        # parks its records under grp.pending while the other groups
        # keep draining.  Groups that have recovered drain their parked
        # backlog first (journal order is older than the buffer).
        for g in groups:
            if not any(m.alive for m in g.members.values()):
                continue    # memberless: records stay parked until join
            while g.pending and not self._blocked(g):
                pid, idx, buf = g.pending.popleft()
                self._dispatch_to_group(g, pid, idx, buf)
        n_sat = 0
        states_sat = {}
        for g in groups:
            s = self._saturated(g)
            if not s and self._quota_blocked(g):
                s = True
                for m in g.members.values():
                    if m.alive and m.account is not None \
                            and m.account.exhausted:
                        m.account.quota_blocked_pumps += 1
            states_sat[g.name] = s
            n_sat += s
        # every group saturated: stall the whole dispatch — requeued
        # batch views are cheaper than per-record parked copies, and
        # nothing could drain anyway (ephemerals wait too, as before)
        if groups and n_sat == len(groups):
            return 0
        pflags = R.packed_flags
        remap = R.remap_cached
        by_load = _by_load

        def stamp(cons: Consumer, buf: bytes) -> bytes:
            # remote remap: strip fields the consumer did not ask for
            # (§IV-A); identity (no copy) when it asked for everything
            src = pflags(buf)
            want = src & cons.flags
            return buf if want == src else remap(buf, want)

        dispatched = 0
        filtered_out = 0
        halt = False
        quantum = self.dispatch_quantum
        while self._buffer:
            pid, batch = self._buffer.popleft()
            self._buffered -= len(batch)
            if self._fast_eligible(groups, ephemerals, states_sat,
                                   len(batch), n):
                d, f = self._dispatch_batch(pid, batch, groups, ephemerals)
                dispatched += d
                filtered_out += f
                n += len(batch)
                if quantum is not None and n >= quantum:
                    break
                continue
            # per-(batch, group) state — membership cannot change while
            # the proxy lock is held: [group, tracker, live members,
            # pushdown active, rtype -> eligible-members cache,
            # saturated, tenant-scoped]
            states = []
            for g in groups:
                live = [m for m in g.members.values() if m.alive]
                states.append([g, g.tracker(pid), live,
                               any(m.types is not None for m in live), {},
                               states_sat[g.name],
                               any(m.tenant is not None for m in live)])
            need_type = any(st[3] for st in states) or \
                any(c.types is not None for c in ephemerals)
            pjobid = R.packed_jobid
            packed_index = batch.packed_index
            packed_type = batch.packed_type
            packed = batch.packed
            total = len(batch)
            stop = None
            for i in range(total):
                idx = packed_index(i)
                rtype = packed_type(i) if need_type else -1
                # pushdown means a record may reach no outbox at all:
                # materialize the packed bytes only on first real use
                buf = None
                jb = None          # lazily extracted jobid, shared by groups
                for st in states:
                    grp, tracker, live, filtered, eligible, full_g, \
                        scoped = st
                    tracker.deliver(idx)
                    if not live or full_g:
                        # no member yet, or per-group backpressure:
                        # park for this group alone; drained on join /
                        # recovery.  A group whose parked backlog
                        # reaches the outbox cap halts the whole
                        # dispatch: beyond that window the healthy
                        # groups intentionally degrade to a trickle
                        # (one record per pump) rather than let parked
                        # copies grow unboundedly — operators should
                        # fail or expire a consumer stuck that long.
                        if buf is None:
                            buf = packed(i)
                        grp.pending.append((pid, idx, buf))
                        if full_g and len(grp.pending) >= cap:
                            halt = True
                        continue
                    if filtered:
                        want = eligible.get(rtype)
                        if want is None:
                            want = eligible[rtype] = \
                                [m for m in live if m.wants(rtype)]
                        if not want:
                            # nobody in this group asked for this op
                            # type: acknowledged in place, never copied
                            tracker.ack(idx)
                            filtered_out += 1
                            continue
                    else:
                        want = live
                    if scoped:
                        # tenant pushdown, scalar flavor: out-of-scope
                        # records are acked in place for the scoped
                        # members, never copied
                        if buf is None:
                            buf = packed(i)
                        if jb is None:
                            jb = pjobid(buf)
                        kept = []
                        for m in want:
                            if m.tenant is None or m.tenant.allows(jb):
                                kept.append(m)
                            elif m.account is not None:
                                m.account.filtered_records += 1
                        if not kept:
                            tracker.ack(idx)
                            filtered_out += 1
                            self.stats["tenant_filtered"] += 1
                            continue
                        want = kept
                    cons = want[0] if len(want) == 1 else min(want,
                                                              key=by_load)
                    if buf is None:
                        buf = packed(i)
                    cons.outbox.append((pid, idx, stamp(cons, buf)))
                    cons.in_flight[(pid, idx)] = buf
                    cons.delivered += 1
                    if cons.account is not None:
                        cons.account.charge(1, len(buf))
                    dispatched += 1
                    if len(cons.outbox) >= cap:
                        st[5] = True
                        states_sat[grp.name] = True
                        n_sat += 1
                        if n_sat == len(groups):
                            halt = True   # nobody left to drain for
                for cons in ephemerals:
                    if idx <= cons.since.get(pid, -1):  # type: ignore
                        continue  # emitted before connection (§IV-B)
                    if not cons.wants(rtype):
                        continue  # pushdown for ephemerals: just skip
                    if cons.tenant is not None:
                        if buf is None:
                            buf = packed(i)
                        if jb is None:
                            jb = pjobid(buf)
                        if not cons.tenant.allows(jb):
                            if cons.account is not None:
                                cons.account.filtered_records += 1
                            continue  # out of scope: skip, like the mask
                    if len(cons.outbox) >= cap:
                        self.stats["ephemeral_drops"] += 1   # radio semantics
                        continue
                    if buf is None:
                        buf = packed(i)
                    cons.outbox.append((pid, idx, stamp(cons, buf)))
                    if cons.account is not None:
                        cons.account.charge(1, len(buf))
                n += 1
                if halt or (quantum is not None and n >= quantum):
                    halt = True
                    stop = i + 1
                    break
            if stop is not None:
                if stop < total:
                    # the rest of the batch goes back (a view — no copy)
                    rest = batch[stop:]
                    self._buffer.appendleft((pid, rest))
                    self._buffered += len(rest)
                break
        self.stats["dispatched"] += dispatched
        self.stats["filtered_out"] += filtered_out
        return n

    def pump(self) -> int:
        """One synchronous ingest+dispatch cycle; returns records moved."""
        hist = self._obs_pump_hist
        t0 = time.monotonic() if hist is not None else 0.0
        with self._lock:
            self._expire_parked_locked()
            filtered_before = self.stats["filtered_out"]
            a = self._ingest()
            b = self._dispatch()
            if self.stats["filtered_out"] != filtered_before:
                # in-place acks (pushdown) can complete a producer's
                # collective watermark without any consumer commit —
                # propagate, or a fully-filtered journal never trims
                self._flush_upstream_locked()
        if hist is not None and a + b:
            hist.observe(time.monotonic() - t0)
        return a + b

    # ------------------------------------------------------------- replay
    def _replay_reader(self, src):
        """The replay source of a producer: journals read their own
        history tier + retained records; push-fed sources use whatever
        reader the cluster coordinator installed."""
        if isinstance(src, PushSource):
            return src.history_reader
        if isinstance(src, Llog):
            return JournalReplayReader(src)
        return getattr(src, "history_reader", None)

    def _arm_replay_locked(self, cons: Consumer, replay) -> None:
        """Record, per producer, the replay range ``[start, hw]`` where
        ``hw`` is the handoff watermark: the highest index the live
        stream will *not* deliver to this consumer.  For a fresh
        persistent group that is everything already dispatched (the
        buffered backlog and all later ingests arrive live); for an
        ephemeral consumer it is the §IV-B connection point."""
        start = 1 if replay is True else int(replay)
        if start < 1:
            raise SubscriptionError(f"replay index must be >= 1 ({start})")
        buf_lo: Dict[str, int] = {}
        for pid, batch in self._buffer:
            if len(batch):
                lo = int(batch.indices_np().min())
                if lo < buf_lo.get(pid, lo + 1):
                    buf_lo[pid] = lo
        for pid, src in self.producers.items():
            reader = self._replay_reader(src)
            if reader is None:
                raise SubscriptionError(
                    f"producer {pid!r} has no replayable history "
                    f"(attach a HistoryStore, or subscribe without replay)")
            lo = reader.available_lo()
            pid_start = start
            if pid_start < lo:
                if replay is not True or \
                        not getattr(reader, "floor_is_retention", False):
                    raise SubscriptionError(
                        f"history of {pid!r} starts at index {lo}; cannot "
                        f"replay from {start}")
                # replay=True means "from the oldest retained history";
                # a retention trim (history.StreamJanitor) legitimately
                # moves that point forward.  Only with a history tier
                # attached, though — a bare journal whose head trimmed
                # has no retention policy, the records are just gone.
                pid_start = lo
            if cons.mode == EPHEMERAL:
                hw = cons.since.get(pid, 0)  # type: ignore[attr-defined]
            elif pid in buf_lo:
                hw = buf_lo[pid] - 1
            else:
                hw = self.ingested.get(pid, 0)
            if hw >= pid_start:
                cons.replay_src[pid] = reader
                cons.replay_pos[pid] = pid_start
                cons.replay_hw[pid] = hw
                cons.replay_lo[pid] = pid_start

    def fetch_replay(self, cid: str, max_records: int = 1024,
                     ) -> Tuple[List[Tuple[str, R.RecordBatch]], bool]:
        """Stream the next slice of the consumer's replay bootstrap as
        ``(batches, done)``.  Batches carry compacted history (sparse
        indices) up to each producer's handoff watermark, filtered and
        remapped exactly like live dispatch; once ``done`` the live
        stream continues at watermark + 1 with no gap and no
        duplicate."""
        with self._lock:
            cons = self._consumer(cid)
            out: List[Tuple[str, R.RecordBatch]] = []
            taken = 0
            for pid in sorted(cons.replay_pos):
                if taken >= max_records:
                    break
                reader = cons.replay_src[pid]
                hw = cons.replay_hw[pid]
                pos = cons.replay_pos[pid]
                while pos <= hw and taken < max_records:
                    batch, nxt = reader.read(
                        pos, min(self.batch_size, max_records - taken))
                    nxt = max(nxt, pos + 1)          # always advance
                    bidx = batch.indices_np()
                    rows = np.flatnonzero((bidx >= pos) & (bidx <= hw))
                    if len(rows) != len(batch):
                        batch = batch.select(rows)
                    # same pre-processing as ingest (_admit_locked): a
                    # replay consumer must see the stream the modules
                    # produce, not the raw archive, or its state
                    # diverges from every live consumer's
                    for mod in self.modules:
                        batch = mod(batch)
                    if not isinstance(batch, R.RecordBatch):
                        batch = R.RecordBatch.from_records(batch)
                    if cons.types is not None:
                        rows = np.flatnonzero(
                            np.isin(batch.types_np(), sorted(cons.types)))
                        if len(rows) != len(batch):
                            batch = batch.select(rows)
                    if cons.tenant is not None and len(batch):
                        # replay honors the same scope pushdown as live
                        # dispatch: history a tenant may not see never
                        # leaves the proxy, even on bootstrap
                        rows = np.flatnonzero(
                            cons.tenant.scope_mask(batch.jobid_col()))
                        if len(rows) != len(batch):
                            self.stats["tenant_filtered"] += \
                                len(batch) - len(rows)
                            batch = batch.select(rows)
                    if len(batch):
                        if cons.account is not None:
                            cons.account.replayed_records += len(batch)
                        out.append((pid, batch.remap(cons.flags)))
                        taken += len(batch)
                    pos = min(nxt, hw + 1)
                cons.replay_pos[pid] = pos
                if pos > hw:
                    del cons.replay_pos[pid]
                    del cons.replay_src[pid]
                    del cons.replay_hw[pid]
            self.stats["replayed"] += taken
            return out, not cons.replay_pos

    def rewind_active_replays(self) -> int:
        """Restart every unfinished replay bootstrap from its original
        start index.  A cluster coordinator calls this on the surviving
        shards after a failover: re-routed slots now pass this shard's
        slot filter, and indices the bootstrap already scanned while
        the dead shard owned them would otherwise never be revisited.
        Re-replaying a prefix redelivers records (at-least-once during
        failover, exactly like the live path's backlog re-offer); a
        bootstrap that already *finished* cannot be rewound — the
        client stopped polling ``fetch_replay`` — which is the
        documented residual window of the cluster's cascading-failure
        caveat.  Returns the number of consumers rewound."""
        with self._lock:
            n = 0
            parked = (c for g in self.groups.values()
                      for c, _dl in g.parked.values())
            for cons in (*self.consumers.values(), *parked):
                if cons.replay_pos:
                    for pid in cons.replay_pos:
                        cons.replay_pos[pid] = cons.replay_lo[pid]
                    n += 1
            return n

    @property
    def buffered(self) -> int:
        """Records admitted but not yet dispatched — the offer-queue
        depth, the primary backpressure/autoscaling signal (also
        exported as ``lcap_buffered_records``)."""
        return self._buffered

    def replay_floor(self, pid: str) -> Optional[int]:
        """The lowest history index an *unfinished* replay bootstrap of
        producer ``pid`` may still (re)read, across active consumers
        and parked durables — a rewind (``rewind_active_replays``)
        sends the bootstrap back to its start, so the start is what
        pins retention, not the current position.  None when no
        bootstrap of ``pid`` is in flight."""
        with self._lock:
            floor = None
            parked = (c for g in self.groups.values()
                      for c, _dl in g.parked.values())
            for cons in (*self.consumers.values(), *parked):
                if pid in cons.replay_pos:
                    lo = cons.replay_lo[pid]
                    if floor is None or lo < floor:
                        floor = lo
            return floor

    def retention_horizons(self) -> Dict[str, int]:
        """Per journal-backed producer, the oldest still-live cursor
        (see ``LcapCluster.retention_horizons`` for the cluster
        flavor): the collective ack frontier, held back by any
        unfinished replay bootstrap's rewind point.  Input to the
        history tier's ``StreamJanitor``."""
        with self._lock:
            out: Dict[str, int] = {}
            for pid, src in self.producers.items():
                if not isinstance(src, Llog):
                    continue
                h = self.upstream_acked.get(pid, 0) + 1
                floor = self.replay_floor(pid)
                if floor is not None:
                    h = min(h, floor)
                out[pid] = h
            return out

    # -------------------------------------------------------------- fetch
    def fetch(self, cid: str,
              max_records: int = 256) -> List[Tuple[str, int, bytes]]:
        with self._lock:
            cons = self._consumer(cid)
            if cons.replay_pos:
                return []     # bootstrap first: drain fetch_replay
            out = []
            while cons.outbox and len(out) < max_records:
                out.append(cons.outbox.popleft())
            return out

    def fetch_batches(self, cid: str, max_records: int = 1024,
                      ) -> List[Tuple[str, R.RecordBatch]]:
        """Drain up to ``max_records`` from the consumer's outbox as
        per-producer ``RecordBatch``es (consecutive same-producer runs
        stay one batch — the unit that goes on the wire).  A consumer
        with an unfinished replay bootstrap gets nothing here until
        ``fetch_replay`` reports done — history strictly precedes the
        live stream."""
        with self._lock:
            cons = self._consumer(cid)
            if cons.replay_pos:
                return []
            return cons.outbox.pop_batches(max_records)

    # ---------------------------------------------------------------- ack
    def ack(self, cid: str, pid: str, index: int) -> None:
        self.commit(cid, {pid: (index,)})

    def ack_batch(self, cid: str, pid: str, indices: List[int]) -> None:
        """Acknowledge many records of one producer under a single lock
        acquisition and a single upstream-watermark propagation."""
        self.commit(cid, {pid: indices})

    def commit(self, cid: str, acks: Dict[str, Iterable[int]]) -> None:
        """Acknowledge records of any number of producers in one call
        (one lock acquisition, one upstream propagation per producer).
        Also advances the consumer's durable ack watermark — the cursor
        a resuming consumer of the same name picks up."""
        with self._lock:
            cons = self._consumer(cid)
            if cons.mode == EPHEMERAL:
                return  # ephemeral readers are not expected to ack (§IV-B)
            grp = self.groups[cons.group]
            for pid in acks:               # validate first: all or nothing
                if pid not in self.producers:
                    raise UnknownProducerError(f"unknown producer {pid!r}")
            for pid, indices in acks.items():
                if not isinstance(indices, (list, tuple, np.ndarray)):
                    indices = list(indices)
                arr = np.sort(np.asarray(indices, dtype=np.int64))
                if not arr.size:
                    continue
                cons.in_flight.discard_many(pid, arr)
                hi = int(arr[-1])
                if hi > cons.acked_hi.get(pid, 0):
                    cons.acked_hi[pid] = hi
                grp.tracker(pid).ack_many(arr)
                self._ack_upstream(pid)

    def _group_position(self, grp: Group, pid: str) -> int:
        tr = grp.tracker(pid)
        if tr.in_flight or grp.pending:
            return tr.watermark
        # nothing outstanding: the group is current through everything
        # ingested (records dropped by modules must not block the trim)
        return max(tr.watermark, self.ingested.get(pid, 0))

    def _ack_upstream(self, pid: str) -> None:
        if not self.groups:
            return
        horizon = min(self._group_position(g, pid)
                      for g in self.groups.values())
        if horizon > self.upstream_acked.get(pid, 0):
            self.producers[pid].ack(self.reader_ids[pid], horizon)
            self.upstream_acked[pid] = horizon
            self.stats["acked_upstream"] += 1

    def _flush_upstream_locked(self) -> None:
        for pid in self.producers:
            self._ack_upstream(pid)

    def flush_upstream(self) -> None:
        """Propagate collective acks for producers with no outstanding
        records (e.g. after module-dropped batches)."""
        with self._lock:
            self._flush_upstream_locked()

    # ------------------------------------------------------- observability
    def attach_registry(self, registry, labels: Optional[Dict[str, str]]
                        = None) -> None:
        """Publish this proxy's metrics into ``registry`` (any object
        with the ``MetricsRegistry`` factory surface).  Everything except
        the pump-latency histogram is exported by a pull collector read
        at snapshot time, so the dispatch hot path pays nothing."""
        base = dict(labels or {})
        names = tuple(sorted(base))
        self._obs = registry
        self._obs_pump_hist = registry.histogram(
            "lcap_pump_latency_seconds",
            "latency of one ingest+dispatch pump cycle",
            labels=names).labels(**base)
        registry.register_collector(lambda: self._collect_samples(base))

    def _collect_samples(self, base: Dict[str, str]):
        with self._lock:
            stats = dict(self.stats)
            buffered = self._buffered
            groups = [(g.name,
                       [(pid, tr.watermark, tr.in_flight,
                         tr.delivered_total, tr.acked_total)
                        for pid, tr in g.trackers.items()],
                       len(g.pending), len(g.parked))
                      for g in self.groups.values()]
            consumers = [(c.cid, c.group or "", c.mode, len(c.outbox),
                          len(c.in_flight)) for c in self.consumers.values()
                         if c.alive]
            live_by_tenant: Dict[str, int] = {}
            for c in self.consumers.values():
                if c.alive and c.tenant is not None:
                    live_by_tenant[c.tenant.name] = \
                        live_by_tenant.get(c.tenant.name, 0) + 1
            tenants = [(a.name, a.delivered_records, a.delivered_bytes,
                        a.replayed_records, a.filtered_records,
                        a.quota_blocked_pumps,
                        a.record_bucket.level if a.record_bucket else None,
                        a.byte_bucket.level if a.byte_bucket else None,
                        live_by_tenant.get(a.name, 0))
                       for a in self.tenants.values()]
            ingested_hw = dict(self.ingested)
            upstream = dict(self.upstream_acked)
        out = []
        for key, v in stats.items():
            out.append((f"lcap_proxy_{key}_total", "counter",
                        f"proxy stats[{key}]", base, v))
        out.append(("lcap_buffered_records", "gauge",
                    "records admitted but not yet dispatched", base,
                    buffered))
        for pid in ingested_hw:
            lb = dict(base, producer=pid)
            out.append(("lcap_ingest_watermark", "gauge",
                        "highest journal index ingested", lb,
                        ingested_hw[pid]))
            out.append(("lcap_upstream_acked", "gauge",
                        "collective ack watermark sent upstream", lb,
                        upstream.get(pid, 0)))
        for gname, trackers, pending, parked in groups:
            glb = dict(base, group=gname)
            out.append(("lcap_group_pending", "gauge",
                        "records parked by group backpressure", glb,
                        pending))
            out.append(("lcap_group_parked_consumers", "gauge",
                        "durable members parked awaiting resume", glb,
                        parked))
            for pid, wm, infl, deliv, acked in trackers:
                lb = dict(glb, producer=pid)
                out.append(("lcap_ack_watermark", "gauge",
                            "contiguous acked index per group/producer",
                            lb, wm))
                out.append(("lcap_ack_in_flight", "gauge",
                            "delivered but unacknowledged records", lb,
                            infl))
                out.append(("lcap_ack_delivered_records_total", "counter",
                            "records handed to the group (ack layer)", lb,
                            deliv))
                out.append(("lcap_ack_acked_records_total", "counter",
                            "records acknowledged by the group (ack layer)",
                            lb, acked))
        for cid, gname, mode, outbox, infl in consumers:
            lb = dict(base, consumer=cid, group=gname, mode=mode)
            out.append(("lcap_consumer_outbox_depth", "gauge",
                        "records staged for fetch", lb, outbox))
            out.append(("lcap_consumer_in_flight", "gauge",
                        "records fetched but uncommitted", lb, infl))
        for (tname, deliv, nbytes, replayed, filtered, blocked,
             rec_lvl, byte_lvl, live) in tenants:
            lb = dict(base, tenant=tname)
            out.append(("lcap_tenant_delivered_records_total", "counter",
                        "records delivered to this tenant's consumers",
                        lb, deliv))
            out.append(("lcap_tenant_delivered_bytes_total", "counter",
                        "payload bytes delivered to this tenant", lb,
                        nbytes))
            out.append(("lcap_tenant_replayed_records_total", "counter",
                        "history-tier records replayed to this tenant",
                        lb, replayed))
            out.append(("lcap_tenant_filtered_records_total", "counter",
                        "records this tenant's scope denied its "
                        "consumers (acked in place)", lb, filtered))
            out.append(("lcap_tenant_quota_blocked_pumps_total", "counter",
                        "dispatch rounds this tenant's groups parked on "
                        "quota", lb, blocked))
            out.append(("lcap_tenant_consumers", "gauge",
                        "live consumers under this tenant", lb, live))
            if rec_lvl is not None:
                out.append(("lcap_tenant_quota_level_records", "gauge",
                            "record token-bucket level (<=0 parks)", lb,
                            rec_lvl))
            if byte_lvl is not None:
                out.append(("lcap_tenant_quota_level_bytes", "gauge",
                            "byte token-bucket level (<=0 parks)", lb,
                            byte_lvl))
        return out

    def metrics_snapshot(self) -> Dict[str, dict]:
        """Snapshot of the attached registry (``{}`` when none)."""
        reg = self._obs
        return reg.snapshot() if reg is not None else {}

    def lag(self) -> Dict[str, Dict[str, Dict[str, int]]]:
        """Consumer lag per (group, producer): the distance between the
        dispatch watermark (highest journal index this proxy has
        ingested) and the group's collective ack cursor.  Never
        negative; exactly zero once nothing is outstanding, because the
        group position then jumps to the ingest watermark (module-
        dropped and filter-acked records don't hold lag up)."""
        with self._lock:
            out: Dict[str, Dict[str, Dict[str, int]]] = {}
            for gname, grp in self.groups.items():
                gout = out[gname] = {}
                for pid in self.producers:
                    hw = self.ingested.get(pid, 0)
                    tr = grp.trackers.get(pid)
                    pos = self._group_position(grp, pid)
                    gout[pid] = {
                        "dispatch_hw": hw,
                        "ack": pos,
                        "lag": max(0, hw - pos),
                        "in_flight": tr.in_flight if tr is not None else 0,
                    }
            return out
