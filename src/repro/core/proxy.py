"""LCAP proxy — Lustre Changelog Aggregate and Publish (paper §III).

Broker between N producers (each exposing an ``Llog``) and M consumers:

- **greedy batched reads**: each ``pump()`` drains every producer's
  journal into an in-memory buffer (bounded; persistence stays upstream,
  which is what makes at-least-once acceptable — paper §III-A);
- **stream modules** pre-process batches at ingest (drop compensating
  pairs, reorder, filter — paper: shared-library modules);
- **consumer groups**: every record is delivered to *each* group and to
  exactly *one member* within a group (least-loaded dispatch →
  load-balanced processing);
- **ephemeral readers** receive only records ingested after they
  subscribed and never acknowledge (paper §IV-B);
- **collective acknowledgement**: a record is acknowledged upstream to
  the producer's journal only once every group has acknowledged it;
- **at-least-once**: when a consumer dies, its in-flight records are
  redelivered to surviving group members.

The unit of flow is a ``RecordBatch`` end to end: journals hand the
proxy zero-copy batch views, stream modules restructure them without
decoding payloads, and dispatch reads only the 8-byte packed index of
each record.  Records are materialized (one memcpy, still no decode)
only when placed in a consumer's outbox; per-consumer flag remapping
uses the plan cache in ``records`` and is a no-op for consumers that
ask for everything.

The core is synchronous (``pump()``) for determinism; ``LcapService``
(server.py) wraps it with a polling thread + TCP transport.
"""

from __future__ import annotations

import itertools
import operator
import threading
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

from . import records as R
from .ack import AckTracker
from .llog import Llog

Module = Callable[[R.RecordBatch], R.RecordBatch]

PERSISTENT = "persistent"
EPHEMERAL = "ephemeral"

_by_load = operator.attrgetter("load")   # Consumer.load, single definition


class Consumer:
    def __init__(self, cid: str, group: Optional[str], flags: int, mode: str):
        self.cid = cid
        self.group = group
        self.flags = flags & R.CLF_SUPPORTED
        self.mode = mode
        self.outbox: Deque[Tuple[str, int, bytes]] = deque()
        # (producer, index) -> packed record, for redelivery
        self.in_flight: Dict[Tuple[str, int], bytes] = {}
        self.alive = True
        self.delivered = 0

    @property
    def load(self) -> int:
        return len(self.outbox) + len(self.in_flight)


class Group:
    def __init__(self, name: str):
        self.name = name
        self.members: Dict[str, Consumer] = {}
        self.trackers: Dict[str, AckTracker] = {}
        self.pending: Deque[Tuple[str, int, bytes]] = deque()  # no member yet

    def tracker(self, pid: str) -> AckTracker:
        if pid not in self.trackers:
            self.trackers[pid] = AckTracker()
        return self.trackers[pid]


class LcapProxy:
    def __init__(self, producers: Dict[str, Llog],
                 modules: Optional[List[Module]] = None,
                 batch_size: int = 1024, max_buffer: int = 1 << 20,
                 outbox_cap: int = 1 << 16):
        self.producers = dict(producers)
        self.modules = list(modules or [])
        self.batch_size = batch_size
        self.max_buffer = max_buffer          # records, across buffered batches
        self.outbox_cap = outbox_cap
        self._lock = threading.RLock()
        self._cid_seq = itertools.count(1)
        # register as a regular changelog reader with every producer (§III)
        self.reader_ids: Dict[str, str] = {
            pid: log.register_reader(f"lcap-{pid}", resume=True)
            for pid, log in self.producers.items()}
        self.cursors: Dict[str, int] = {
            pid: log.first_index for pid, log in self.producers.items()}
        self.ingested: Dict[str, int] = {
            pid: log.first_index - 1 for pid, log in self.producers.items()}
        self.upstream_acked: Dict[str, int] = dict(self.ingested)
        self.groups: Dict[str, Group] = {}
        self.consumers: Dict[str, Consumer] = {}
        self._buffer: Deque[Tuple[str, R.RecordBatch]] = deque()
        self._buffered = 0                    # records currently in _buffer
        self.stats = {"ingested": 0, "dispatched": 0, "dropped_by_modules": 0,
                      "redelivered": 0, "acked_upstream": 0,
                      "ephemeral_drops": 0, "batches_ingested": 0}

    # ------------------------------------------------------------------ API
    def add_producer(self, pid: str, log: Llog) -> None:
        with self._lock:
            self.producers[pid] = log
            self.reader_ids[pid] = log.register_reader(f"lcap-{pid}",
                                                       resume=True)
            self.cursors[pid] = log.first_index
            self.ingested[pid] = log.first_index - 1
            self.upstream_acked[pid] = self.ingested[pid]

    def subscribe(self, group: Optional[str], flags: int = R.CLF_SUPPORTED,
                  mode: str = PERSISTENT, cid: Optional[str] = None) -> str:
        """Register a consumer.  Persistent consumers name a group and
        share its stream; ephemeral consumers pass ``mode=EPHEMERAL``
        (group may be None) and only see records ingested afterwards."""
        with self._lock:
            cid = cid or f"c{next(self._cid_seq)}"
            if cid in self.consumers:
                raise ValueError(f"consumer {cid} exists")
            if mode == PERSISTENT:
                if not group:
                    raise ValueError("persistent consumers need a group")
                cons = Consumer(cid, group, flags, mode)
                grp = self.groups.setdefault(group, Group(group))
                grp.members[cid] = cons
                # drain records parked while the group had no members
                while grp.pending:
                    pid, idx, buf = grp.pending.popleft()
                    self._hand_to(cons, pid, idx, buf)
            elif mode == EPHEMERAL:
                cons = Consumer(cid, None, flags, mode)
                # connection point: nothing *emitted* before now (§IV-B).
                # Producer last_index, not the ingest cursor — records
                # journaled but not yet pumped at attach time are
                # history, regardless of poller timing.
                cons.since = {  # type: ignore[attr-defined]
                    pid: log.last_index
                    for pid, log in self.producers.items()}
            else:
                raise ValueError(f"unknown mode {mode}")
            self.consumers[cid] = cons
            return cid

    def unsubscribe(self, cid: str, failed: bool = False) -> None:
        """Remove a consumer.  Its undelivered/unacked records go back to
        the group (at-least-once)."""
        with self._lock:
            cons = self.consumers.pop(cid, None)
            if cons is None:
                return
            cons.alive = False
            if cons.mode == EPHEMERAL:
                return
            grp = self.groups[cons.group]
            del grp.members[cid]
            # in_flight covers everything undelivered OR unacked (records
            # are tracked there from dispatch until ack), so it alone is
            # the redelivery backlog — using outbox too would duplicate
            # queued-but-unfetched records.
            backlog = sorted(
                (pid, idx, buf) for (pid, idx), buf in cons.in_flight.items())
            self.stats["redelivered"] += len(backlog)
            for pid, idx, buf in backlog:
                self._dispatch_to_group(grp, pid, idx, buf)

    fail = lambda self, cid: self.unsubscribe(cid, failed=True)  # noqa: E731

    def _consumer(self, cid: str) -> Consumer:
        try:
            return self.consumers[cid]
        except KeyError:
            raise KeyError(f"unknown or unsubscribed consumer {cid!r}") \
                from None

    # ------------------------------------------------------------- ingest
    def _ingest(self) -> int:
        n = 0
        for pid, log in self.producers.items():
            while self._buffered < self.max_buffer:
                batch = log.read(self.cursors[pid], self.batch_size)
                if not batch:
                    break
                got = len(batch)
                hi = batch.packed_index(got - 1)   # journal order: ascending
                self.cursors[pid] = hi + 1
                kept = batch
                for mod in self.modules:
                    kept = mod(kept)
                if not isinstance(kept, R.RecordBatch):  # legacy list module
                    kept = R.RecordBatch.from_records(kept)
                self.stats["dropped_by_modules"] += got - len(kept)
                if len(kept):
                    self._buffer.append((pid, kept))
                    self._buffered += len(kept)
                self.ingested[pid] = hi
                self.stats["batches_ingested"] += 1
                n += got
                if got < self.batch_size:
                    break
        self.stats["ingested"] += n
        return n

    # ----------------------------------------------------------- dispatch
    def _hand_to(self, cons: Consumer, pid: str, idx: int, buf: bytes) -> None:
        # remote remap: strip fields the consumer did not ask for (§IV-A)
        out = R.remap_cached(buf, R.packed_flags(buf) & cons.flags)
        cons.outbox.append((pid, idx, out))
        cons.in_flight[(pid, idx)] = buf
        cons.delivered += 1
        self.stats["dispatched"] += 1

    def _dispatch_to_group(self, grp: Group, pid: str, idx: int,
                           buf: bytes) -> None:
        grp.tracker(pid).deliver(idx)
        live = [m for m in grp.members.values() if m.alive]
        if not live:
            grp.pending.append((pid, idx, buf))
            return
        cons = min(live, key=lambda m: m.load)   # least-loaded (§III-A)
        self._hand_to(cons, pid, idx, buf)

    def _dispatch(self) -> int:
        n = 0
        cap = self.outbox_cap
        groups = list(self.groups.values())
        persistent = [c for c in self.consumers.values()
                      if c.mode == PERSISTENT and c.alive]
        ephemerals = [c for c in self.consumers.values()
                      if c.mode == EPHEMERAL and c.alive]
        # backpressure: never dispatch into a saturated persistent
        # consumer.  Checked once at entry; afterwards O(1) per record
        # (only an outbox we just appended to can newly saturate).
        if any(len(c.outbox) >= cap for c in persistent):
            return 0
        pflags = R.packed_flags
        remap = R.remap_cached
        by_load = _by_load

        def stamp(cons: Consumer, buf: bytes) -> bytes:
            # remote remap: strip fields the consumer did not ask for
            # (§IV-A); identity (no copy) when it asked for everything
            src = pflags(buf)
            want = src & cons.flags
            return buf if want == src else remap(buf, want)

        dispatched = 0
        while self._buffer:
            pid, batch = self._buffer.popleft()
            self._buffered -= len(batch)
            # per-(batch, group) state — membership cannot change while
            # the proxy lock is held
            states = [(g, g.tracker(pid),
                       [m for m in g.members.values() if m.alive])
                      for g in groups]
            packed_index = batch.packed_index
            packed = batch.packed
            total = len(batch)
            stop = None
            for i in range(total):
                idx = packed_index(i)
                buf = packed(i) if (states or ephemerals) else None
                full = False
                for grp, tracker, live in states:
                    tracker.deliver(idx)
                    if not live:
                        grp.pending.append((pid, idx, buf))
                        continue
                    cons = live[0] if len(live) == 1 else min(live,
                                                              key=by_load)
                    cons.outbox.append((pid, idx, stamp(cons, buf)))
                    cons.in_flight[(pid, idx)] = buf
                    cons.delivered += 1
                    dispatched += 1
                    if len(cons.outbox) >= cap:
                        full = True
                for cons in ephemerals:
                    if idx <= cons.since.get(pid, -1):  # type: ignore
                        continue  # emitted before connection (§IV-B)
                    if len(cons.outbox) >= cap:
                        self.stats["ephemeral_drops"] += 1   # radio semantics
                        continue
                    cons.outbox.append((pid, idx, stamp(cons, buf)))
                n += 1
                if full:
                    stop = i + 1
                    break
            if stop is not None:
                if stop < total:
                    # the rest of the batch goes back (a view — no copy)
                    rest = batch[stop:]
                    self._buffer.appendleft((pid, rest))
                    self._buffered += len(rest)
                break
        self.stats["dispatched"] += dispatched
        return n

    def pump(self) -> int:
        """One synchronous ingest+dispatch cycle; returns records moved."""
        with self._lock:
            a = self._ingest()
            b = self._dispatch()
            return a + b

    # -------------------------------------------------------------- fetch
    def fetch(self, cid: str,
              max_records: int = 256) -> List[Tuple[str, int, bytes]]:
        with self._lock:
            cons = self._consumer(cid)
            out = []
            while cons.outbox and len(out) < max_records:
                out.append(cons.outbox.popleft())
            return out

    def fetch_batches(self, cid: str, max_records: int = 1024,
                      ) -> List[Tuple[str, R.RecordBatch]]:
        """Drain up to ``max_records`` from the consumer's outbox as
        per-producer ``RecordBatch``es (consecutive same-producer runs
        stay one batch — the unit that goes on the wire)."""
        with self._lock:
            cons = self._consumer(cid)
            runs: List[Tuple[str, List[bytes]]] = []
            taken = 0
            while cons.outbox and taken < max_records:
                pid, idx, buf = cons.outbox.popleft()
                if not runs or runs[-1][0] != pid:
                    runs.append((pid, []))
                runs[-1][1].append(buf)
                taken += 1
            return [(pid, R.RecordBatch.from_packed(bufs))
                    for pid, bufs in runs]

    # ---------------------------------------------------------------- ack
    def ack(self, cid: str, pid: str, index: int) -> None:
        with self._lock:
            cons = self._consumer(cid)
            if cons.mode == EPHEMERAL:
                return  # ephemeral readers are not expected to ack (§IV-B)
            cons.in_flight.pop((pid, index), None)
            grp = self.groups[cons.group]
            grp.tracker(pid).ack(index)
            self._ack_upstream(pid)

    def ack_batch(self, cid: str, pid: str, indices: List[int]) -> None:
        """Acknowledge many records of one producer under a single lock
        acquisition and a single upstream-watermark propagation."""
        with self._lock:
            cons = self._consumer(cid)
            if cons.mode == EPHEMERAL or not indices:
                return
            grp = self.groups[cons.group]
            pop = cons.in_flight.pop
            for index in indices:
                pop((pid, index), None)
            grp.tracker(pid).ack_many(indices)
            self._ack_upstream(pid)

    def _group_position(self, grp: Group, pid: str) -> int:
        tr = grp.tracker(pid)
        if tr.in_flight or grp.pending:
            return tr.watermark
        # nothing outstanding: the group is current through everything
        # ingested (records dropped by modules must not block the trim)
        return max(tr.watermark, self.ingested.get(pid, 0))

    def _ack_upstream(self, pid: str) -> None:
        if not self.groups:
            return
        horizon = min(self._group_position(g, pid)
                      for g in self.groups.values())
        if horizon > self.upstream_acked.get(pid, 0):
            self.producers[pid].ack(self.reader_ids[pid], horizon)
            self.upstream_acked[pid] = horizon
            self.stats["acked_upstream"] += 1

    def flush_upstream(self) -> None:
        """Propagate collective acks for producers with no outstanding
        records (e.g. after module-dropped batches)."""
        with self._lock:
            for pid in self.producers:
                self._ack_upstream(pid)
