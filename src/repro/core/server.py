"""LcapService — the proxy as a network daemon (paper fig. 1).

Wraps ``LcapProxy`` with a greedy polling thread (reads records from the
producers as soon as possible) and the TCP request/response service the
``RemoteReader`` client speaks.  A consumer disconnect without ``close``
is treated as a failure → its in-flight records are redelivered to the
surviving members of its group (at-least-once, §III-A).
"""

from __future__ import annotations

import threading
import time
from typing import Dict, Optional

from .proxy import LcapProxy
from .transport import RpcServer


class LcapService:
    def __init__(self, proxy: LcapProxy, host: str = "127.0.0.1",
                 port: int = 0, poll_interval: float = 0.002):
        self.proxy = proxy
        self.poll_interval = poll_interval
        self._stop = threading.Event()
        self.server = RpcServer(self._handle, self._disconnected, host, port)
        self.address = self.server.address
        self._poller = threading.Thread(target=self._poll_loop, daemon=True)

    # ------------------------------------------------------------- service
    def _handle(self, msg: Dict, session: Dict) -> Dict:
        op = msg.get("op")
        try:
            if op == "register":
                cid = self.proxy.subscribe(msg.get("group"),
                                           msg.get("flags", 0xFFFF),
                                           msg.get("mode", "persistent"))
                session["cid"] = cid
                return {"cid": cid}
            if op == "fetch":
                # whole batches on the wire: one (producer, frame) pair
                # per consecutive same-producer run (u32 count + u32
                # lengths + concatenated packed records)
                batches = self.proxy.fetch_batches(msg["cid"],
                                                   msg.get("max", 256))
                return {"batches": [(pid, batch.to_wire())
                                    for pid, batch in batches]}
            if op == "ack":
                self.proxy.ack(msg["cid"], msg["pid"], msg["index"])
                return {"ok": True}
            if op == "ack_batch":
                self.proxy.ack_batch(msg["cid"], msg["pid"], msg["indices"])
                return {"ok": True}
            if op == "close":
                session.pop("cid", None)
                self.proxy.unsubscribe(msg["cid"])
                return {"ok": True}
            if op == "stats":
                return {"stats": dict(self.proxy.stats)}
            return {"err": f"unknown op {op!r}"}
        except Exception as exc:  # noqa: BLE001 — reported to the peer
            return {"err": f"{type(exc).__name__}: {exc}"}

    def _disconnected(self, session: Dict) -> None:
        cid = session.get("cid")
        if cid:
            self.proxy.unsubscribe(cid, failed=True)

    # -------------------------------------------------------------- poller
    def _poll_loop(self) -> None:
        while not self._stop.is_set():
            moved = self.proxy.pump()
            self.proxy.flush_upstream()
            if not moved:
                time.sleep(self.poll_interval)

    def start(self) -> "LcapService":
        self.server.start()
        self._poller.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._poller.join(timeout=5)
        self.server.stop()
