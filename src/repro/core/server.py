"""LcapService — the proxy as a network daemon (paper fig. 1).

Wraps ``LcapProxy`` with a greedy polling thread (reads records from the
producers as soon as possible) and the TCP request/response service the
``Session`` client (session.py) speaks.  Messages are versioned
(``"v"``); the consumer surface is:

    subscribe   declarative spec (group/name/mode/flags/types) -> cid;
                transparently resumes a parked durable consumer
    resume      like subscribe, but demands parked durable state
    fetch       drain queued records as per-producer batch frames
    fetch_replay  stream the compacted-history bootstrap of a replay
                subscription (history first, then fetch takes over at
                the handoff watermark)
    commit      acknowledge batches of records across producers
    detach      drop the connection but keep the durable identity
    close       deregister for good
    stats       proxy counters

plus the legacy ``register``/``ack``/``ack_batch`` verbs for the
deprecated reader shims.  Errors travel as ``{"err", "err_type"}`` and
surface client-side as typed exceptions, never strings.

A consumer disconnect without ``close`` is treated as a failure: durable
consumers are parked for the proxy's resume TTL (reconnecting under the
same name resumes at the cursor), anonymous consumers' in-flight records
are redelivered to the surviving members of the group (at-least-once,
§III-A).
"""

from __future__ import annotations

import threading
import time
from typing import Dict

from .errors import SessionError
from .proxy import LcapProxy
from .records import RecordBatch, WIRE_V1, WIRE_V2
from .tenancy import TenantPrincipal
from .transport import PROTOCOL_VERSION, RpcServer


class LcapService:
    def __init__(self, proxy: LcapProxy, host: str = "127.0.0.1",
                 port: int = 0, poll_interval: float = 0.002,
                 shard_index: int = None, shard_count: int = None,
                 cluster_info=None):
        self.proxy = proxy
        self.poll_interval = poll_interval
        # cluster awareness: a shard daemon stamps its position into
        # subscribe replies so fan-in clients can sanity-check topology
        self.shard_index = shard_index
        self.shard_count = shard_count
        # topology awareness: a callable returning {"epoch", "shards",
        # "addresses"} (LcapClusterService.cluster_info).  When set,
        # the routing epoch is piggybacked on subscribe/fetch/commit
        # replies and the ``topology`` verb serves the full snapshot,
        # so a consumer connected to any one shard can detect epoch
        # bumps and re-resolve the whole fan-in.
        self.cluster_info = cluster_info
        self._stop = threading.Event()
        self.server = RpcServer(self._handle, self._disconnected, host, port)
        self.address = self.server.address
        self._poller = threading.Thread(target=self._poll_loop, daemon=True)

    def _stamp(self, reply: Dict) -> Dict:
        """Piggyback the routing epoch on a data-path reply."""
        if self.cluster_info is not None:
            reply["epoch"] = self.cluster_info()["epoch"]
        return reply

    # ------------------------------------------------------------- service
    def _handle(self, msg: Dict, session: Dict) -> Dict:
        op = msg.get("op")
        try:
            if msg.get("v", 0) > PROTOCOL_VERSION:
                raise SessionError(f"protocol version {msg['v']} not "
                                   f"supported (server speaks "
                                   f"{PROTOCOL_VERSION})")
            if op in ("subscribe", "resume"):
                info = self.proxy.attach(
                    msg.get("group"), flags=msg.get("flags"),
                    mode=msg.get("mode", "persistent"),
                    types=msg.get("types"), name=msg.get("name"),
                    resume=True if op == "resume" else msg.get("resume"),
                    replay=msg.get("replay"),
                    tenant=TenantPrincipal.from_wire(msg.get("tenant")))
                session.setdefault("cids", set()).add(info["cid"])
                # record-frame negotiation: fetch frames are emitted at
                # the highest generation both sides speak (an old client
                # never sends "wire" and keeps getting v1 frames)
                wire = min(int(msg.get("wire", WIRE_V1)), WIRE_V2)
                session["wire"] = wire
                if self.shard_index is not None:   # cluster-aware reply
                    info = {**info, "shard": self.shard_index,
                            "shards": self.shard_count}
                return self._stamp({"v": PROTOCOL_VERSION, "wire": wire,
                                    **info})
            if op == "caps":
                # feature discovery for cluster peers: record-frame
                # generation, deep-batched offer support, and (when the
                # shard is topology-aware) the routing epoch.  An old
                # daemon answers with an unknown-op error reply, which
                # callers treat as "v1, shallow".
                return self._stamp({"v": PROTOCOL_VERSION, "wire": WIRE_V2,
                                    "deep": True})
            if op == "topology":
                # the full routing snapshot: epoch, shard count, and
                # every shard's address — served by any one shard
                if self.cluster_info is None:
                    raise SessionError("not a topology-aware shard")
                return {"v": PROTOCOL_VERSION, **self.cluster_info()}
            if op == "add_source":
                self.proxy.add_source(msg["pid"], msg.get("first", 1))
                return {"ok": True}
            if op == "offer":
                admitted = self.proxy.offer(
                    msg["pid"], RecordBatch.from_wire(msg["blob"]),
                    msg.get("hi"))
                return {"admitted": admitted,
                        "watermarks": dict(self.proxy.upstream_acked)}
            if op == "offer_many":
                # deep-batched ingest: a whole routing round in one
                # call, admitted under one proxy lock; the reply
                # piggybacks the shard watermarks so the coordinator
                # skips its separate watermark round-trip
                admitted = self.proxy.offer_many(
                    [(pid, RecordBatch.from_wire(blob), hi)
                     for pid, blob, hi in msg["offers"]])
                return {"admitted": admitted,
                        "watermarks": dict(self.proxy.upstream_acked)}
            if op == "watermarks":
                self.proxy.flush_upstream()
                return {"watermarks": dict(self.proxy.upstream_acked)}
            if op == "register":      # legacy readers; same flag default
                cid = self.proxy.subscribe(msg.get("group"),
                                           msg.get("flags"),
                                           msg.get("mode", "persistent"))
                session.setdefault("cids", set()).add(cid)
                return {"cid": cid}
            if op == "fetch":
                # whole batches on the wire: one (producer, frame) pair
                # per consecutive same-producer run, framed at the
                # generation negotiated on subscribe (v2 ships the
                # header columns alongside the payload)
                wire = session.get("wire", WIRE_V1)
                batches = self.proxy.fetch_batches(msg["cid"],
                                                   msg.get("max", 256))
                return self._stamp(
                    {"batches": [(pid, batch.to_wire(wire))
                                 for pid, batch in batches]})
            if op == "fetch_replay":
                wire = session.get("wire", WIRE_V1)
                batches, done = self.proxy.fetch_replay(msg["cid"],
                                                        msg.get("max", 256))
                return self._stamp(
                    {"batches": [(pid, batch.to_wire(wire))
                                 for pid, batch in batches],
                     "done": done})
            if op == "commit":
                self.proxy.commit(msg["cid"], msg["acks"])
                return self._stamp({"ok": True})
            if op == "ack":
                self.proxy.ack(msg["cid"], msg["pid"], msg["index"])
                return {"ok": True}
            if op == "ack_batch":
                self.proxy.ack_batch(msg["cid"], msg["pid"], msg["indices"])
                return {"ok": True}
            if op == "detach":
                session.get("cids", set()).discard(msg["cid"])
                self.proxy.disconnect(msg["cid"])
                return {"ok": True}
            if op == "close":
                session.get("cids", set()).discard(msg["cid"])
                self.proxy.unsubscribe(msg["cid"])
                return {"ok": True}
            if op == "stats":
                return {"stats": dict(self.proxy.stats)}
            if op == "metrics":
                return {"metrics": self.proxy.metrics_snapshot()}
            if op == "lag":
                return {"lag": self.proxy.lag()}
            raise SessionError(f"unknown op {op!r}")
        except Exception as exc:  # noqa: BLE001 — reported to the peer
            return {"err": f"{type(exc).__name__}: {exc}",
                    "err_type": type(exc).__name__}

    def _disconnected(self, session: Dict) -> None:
        for cid in session.get("cids", ()):  # durable -> park, else fail
            self.proxy.disconnect(cid)

    # -------------------------------------------------------------- poller
    def _poll_loop(self) -> None:
        while not self._stop.is_set():
            moved = self.proxy.pump()
            self.proxy.flush_upstream()
            if not moved:
                time.sleep(self.poll_interval)

    def start(self) -> "LcapService":
        self.server.start()
        self._poller.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._poller.join(timeout=5)
        self.server.stop()
