"""Client/server transport (paper: ZeroMQ; here: in-proc + framed TCP).

Message framing: u32 length prefix + msgpack payload.  The proxy exposes
a request/response service (register / fetch / ack / close); consumers
poll, exactly like Lustre changelog readers do.  Record payloads ride
inside the msgpack body as whole ``RecordBatch`` wire frames (see
``records.RecordBatch.to_wire``) — one message moves a batch, not a
record, so the per-message overhead (syscalls, framing, Nagle
interactions) amortizes across the batch.

Record frames come in two generations (the message envelope is the same
either way, so ``PROTOCOL_VERSION`` stays 1): v1 carries lengths +
packed payload; v2 additionally ships the batch's decoded header table
so the receiver attaches the columns without re-gathering.  The frame a
peer *emits* is negotiated — clients offer ``"wire": 2`` on subscribe
and servers echo what they will speak; cluster coordinators probe shard
daemons once with the ``caps`` verb.  Receivers sniff the frame magic
and accept both generations regardless, so negotiation only protects
old peers from frames they cannot parse.
"""

from __future__ import annotations

import socket
import socketserver
import struct
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import msgpack

#: wire protocol generation, stamped as "v" on every client message and
#: checked by the server — one definition for both halves
PROTOCOL_VERSION = 1

#: record-frame generations (re-exported from records for the transport
#: surface: the "wire" negotiation key takes these values)
from .records import WIRE_V1, WIRE_V2  # noqa: E402,F401

_LEN = struct.Struct("<I")

#: (sent_msgs, sent_bytes, recvd_msgs, recvd_bytes) counter instruments,
#: installed by :func:`instrument`; None keeps the framing hot path at a
#: single identity check per message (per-frame, never per-record)
_METRICS = None


def instrument(registry) -> None:
    """Publish transport frame/byte counters into a metrics registry."""
    global _METRICS
    msgs = registry.counter("lcap_transport_messages_total",
                            "wire frames by direction",
                            labels=("direction",))
    byts = registry.counter("lcap_transport_bytes_total",
                            "wire payload bytes (incl. length prefix)",
                            labels=("direction",))
    _METRICS = (msgs.labels(direction="sent"),
                byts.labels(direction="sent"),
                msgs.labels(direction="received"),
                byts.labels(direction="received"))


def send_msg(sock: socket.socket, msg: Dict[str, Any]) -> None:
    blob = msgpack.packb(msg, use_bin_type=True)
    sock.sendall(_LEN.pack(len(blob)) + blob)
    m = _METRICS
    if m is not None:
        m[0].inc()
        m[1].inc(4 + len(blob))


def recv_msg(sock: socket.socket) -> Optional[Dict[str, Any]]:
    hdr = _recv_exact(sock, 4)
    if hdr is None:
        return None
    (ln,) = _LEN.unpack(hdr)
    blob = _recv_exact(sock, ln)
    if blob is None:
        return None
    m = _METRICS
    if m is not None:
        m[2].inc()
        m[3].inc(4 + ln)
    return msgpack.unpackb(blob, raw=False)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    chunks = []
    while n:
        try:
            chunk = sock.recv(n)
        except OSError:
            return None
        if not chunk:
            return None
        if len(chunk) == n and not chunks:
            return chunk                 # whole frame in one recv
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


class RpcServer:
    """Threaded TCP server dispatching msgpack requests to a handler.

    handler(msg, session) -> reply dict.  ``session`` is a per-connection
    dict; ``on_disconnect(session)`` fires when the peer goes away (used
    by the proxy to trigger at-least-once redelivery).
    """

    def __init__(self, handler: Callable[[Dict, Dict], Dict],
                 on_disconnect: Optional[Callable[[Dict], None]] = None,
                 host: str = "127.0.0.1", port: int = 0):
        outer = self

        class _Handler(socketserver.BaseRequestHandler):
            def setup(self):
                self.request.setsockopt(socket.IPPROTO_TCP,
                                        socket.TCP_NODELAY, 1)

            def handle(self):
                session: Dict[str, Any] = {}
                try:
                    while True:
                        msg = recv_msg(self.request)
                        if msg is None:
                            break
                        reply = outer.handler(msg, session)
                        send_msg(self.request, reply)
                finally:
                    if outer.on_disconnect:
                        outer.on_disconnect(session)

        class _Server(socketserver.ThreadingTCPServer):
            daemon_threads = True
            allow_reuse_address = True

        self.handler = handler
        self.on_disconnect = on_disconnect
        self._server = _Server((host, port), _Handler)
        self.address: Tuple[str, int] = self._server.server_address
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        daemon=True)

    def start(self) -> "RpcServer":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()


class RpcClient:
    def __init__(self, address: Tuple[str, int], timeout: float = 10.0):
        self._sock = socket.create_connection(address, timeout=timeout)
        # request/response over small frames: latency beats coalescing
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)

    def call(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        send_msg(self._sock, msg)
        reply = recv_msg(self._sock)
        if reply is None:
            raise ConnectionError("proxy closed the connection")
        return reply

    def send_request(self, msg: Dict[str, Any]) -> None:
        """Fire a request without waiting; pair with ``recv_reply``.
        The server handles one connection sequentially, so replies come
        back in request order."""
        send_msg(self._sock, msg)

    def recv_reply(self) -> Dict[str, Any]:
        reply = recv_msg(self._sock)
        if reply is None:
            raise ConnectionError("proxy closed the connection")
        return reply

    def call_pipelined(self, msgs) -> list:
        """Send a burst of requests before reading any reply.  A
        cluster coordinator routes one batch per (shard, journal) per
        round — pipelining turns N round-trips into one flush and one
        drain (and lets every *shard* process its burst concurrently
        when the caller interleaves send/recv across connections)."""
        msgs = list(msgs)
        for msg in msgs:
            self.send_request(msg)
        return [self.recv_reply() for _ in msgs]

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
