"""Step builders: the functions that get pjit-ed onto the mesh.

- ``build_train_step``  — microbatched (grad-accumulation scan) training
  step with remat, fp32 master params, AdamW, loss in the carry.
- ``build_prefill_step`` / ``build_decode_step`` — serving: prompt
  ingestion returning a KV cache; single-token decode updating it.

All of them are pure (params/opt/cache in -> out) so they lower with
ShapeDtypeStruct inputs — this is what the multi-pod dry-run compiles.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..models import transformer as T
from ..models.config import ModelConfig
from ..optim import adamw
from .sharding import lshard


class TrainHParams(NamedTuple):
    n_micro: int = 1
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    attn_impl: str = "naive"
    remat: bool = True
    remat_policy: str = "dots"       # dots | none | everything


REMAT_POLICIES = {
    "dots": lambda: jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    "none": lambda: jax.checkpoint_policies.nothing_saveable,
    "everything": lambda: jax.checkpoint_policies.everything_saveable,
}


def build_train_step(cfg: ModelConfig, hp: TrainHParams):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).  ``batch`` is a dict with tokens/labels
    (+ frames / image_embeds when the arch needs them), global batch
    leading."""

    policy = REMAT_POLICIES[hp.remat_policy]()

    def micro_loss(params, micro):
        kw = {k: v for k, v in micro.items() if k not in ("tokens", "labels")}
        total, (loss, aux) = T.loss_fn(params, cfg, micro["tokens"],
                                       micro["labels"], impl=hp.attn_impl,
                                       remat=hp.remat, remat_policy=policy,
                                       **kw)
        return total, (loss, aux)

    grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        n_micro = min(hp.n_micro, B)
        assert B % n_micro == 0, (B, n_micro)

        def reshape_micro(x):
            return x.reshape(n_micro, B // n_micro, *x.shape[1:])

        micros = jax.tree.map(reshape_micro, batch)

        def accum(carry, micro):
            gacc, lacc = carry
            (_, (loss, aux)), grads = grad_fn(params, micro)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                gacc, grads)
            return (gacc, lacc + loss), None

        gacc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        # probe mode unrolls the accumulation so cost_analysis counts
        # every microbatch (see launch/dryrun.py cost model)
        (gsum, lsum), _ = lax.scan(accum, (gacc0, jnp.zeros((), jnp.float32)),
                                   micros,
                                   unroll=n_micro if T.UNROLL_LAYERS else 1)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        lr = adamw.cosine_lr(opt_state.step, peak=hp.peak_lr,
                             warmup=hp.warmup, total=hp.total_steps)
        params, opt_state, gnorm = adamw.update(
            grads, opt_state, params, lr=lr,
            weight_decay=hp.weight_decay, max_norm=hp.max_grad_norm)
        metrics = {"loss": lsum / n_micro, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, max_seq: Optional[int] = None,
                       attn_impl: str = "blockwise"):
    def prefill_step(params, batch):
        kw = {k: v for k, v in batch.items() if k != "tokens"}
        logits, cache = T.prefill(params, cfg, batch["tokens"],
                                  max_seq=max_seq, impl=attn_impl, **kw)
        return logits, cache

    return prefill_step


def build_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, token, pos):
        logits, cache = T.decode_step(params, cfg, token, cache, pos)
        return logits[:, 0, :], cache

    return decode_step
