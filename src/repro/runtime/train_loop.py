"""End-to-end training orchestration with LCAP activity tracking.

Wires together every substrate: sharded data pipeline, pjit train step,
per-host ActivityTracker producers, the LCAP proxy, and the consumer
groups (metrics DB, checkpoint committer, straggler detector, elastic
controller).  This is the host-side program each node runs; on CPU it
drives reduced configs end-to-end (examples/, tests/).
"""

from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from .. import configs as C
from ..checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from ..core.proxy import LcapProxy
from ..data import ShardedTokenPipeline
from ..models import transformer as T
from ..optim import adamw
from ..track import (ActivityTracker, CheckpointCommitter, MetricsDB,
                     StragglerDetector)
from .elastic import make_elastic_mesh, reshard_state
from .sharding import LogicalRules, use_rules
from .specs import shardings_of
from .steps import TrainHParams, build_train_step


class Trainer:
    def __init__(self, cfg, *, workdir: str, mesh=None, hp: TrainHParams = None,
                 global_batch: int = 8, seq_len: int = 32, n_hosts: int = 2,
                 ckpt_every: int = 10, n_metrics_workers: int = 2,
                 seed: int = 0):
        self.cfg = cfg
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        self.mesh = mesh or make_elastic_mesh()
        self.hp = hp or TrainHParams(n_micro=1, attn_impl="naive",
                                     remat=False)
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.ckpt_every = ckpt_every

        # --- LCAP backbone: one producer per (simulated) host ------------
        self.trackers = [
            ActivityTracker(run_id=1, host_id=h, jobid=f"{cfg.arch_id}",
                            shard=(0, h, 0, 0),
                            path=os.path.join(workdir, f"host{h}.llog"))
            for h in range(n_hosts)]
        self.proxy = LcapProxy({t.llog.producer_id: t.llog
                                for t in self.trackers})
        self.metrics = [MetricsDB(self.proxy,
                                  os.path.join(workdir, "metrics.sqlite"))
                        for _ in range(n_metrics_workers)]
        self.committer = CheckpointCommitter(
            self.proxy, os.path.join(workdir, "manifests"))
        self.straggler = StragglerDetector(self.proxy)
        self.ckpt = AsyncCheckpointer(os.path.join(workdir, "ckpt"),
                                      n_shards=n_hosts,
                                      tracker=self.trackers[0])

        # --- data ----------------------------------------------------------
        self.pipes = [ShardedTokenPipeline(
            cfg.vocab_size, seq_len, global_batch, n_hosts, h, seed=seed,
            tracker=t) for h, t in enumerate(self.trackers)]

        # --- model/optimizer state ------------------------------------------
        self.rules = LogicalRules(self.mesh)
        with use_rules(self.rules):
            params = T.init_params(cfg, seed=seed)
            opt = adamw.init(params)
        p_sh = shardings_of(self.rules, T.param_axes(cfg))
        self.params = jax.tree.map(jax.device_put, params, p_sh)
        self.opt_state = opt
        self.step = 0
        self._maybe_restore()

        self.train_step = jax.jit(build_train_step(cfg, self.hp),
                                  donate_argnums=(0, 1))
        self.history: List[Dict[str, float]] = []

    # ------------------------------------------------------------------ io
    def _maybe_restore(self) -> None:
        ck_dir = os.path.join(self.workdir, "ckpt")
        last = latest_step(ck_dir)
        if last is None:
            return
        tree = {"params": self.params, "opt": self.opt_state}
        restored = restore_checkpoint(tree, last, ck_dir)
        self.params, self.opt_state, _ = reshard_state(
            self.cfg, restored["params"], restored["opt"], self.mesh)
        self.step = last
        for p in self.pipes:
            p.seek(last)

    # ---------------------------------------------------------------- loop
    def pump_consumers(self) -> None:
        self.proxy.pump()
        for w in self.metrics:
            w.poll()
        self.committer.poll()
        self.straggler.poll()
        self.proxy.flush_upstream()

    def run(self, n_steps: int) -> List[Dict[str, float]]:
        with use_rules(self.rules), self.mesh:
            for _ in range(n_steps):
                t0 = time.time()
                shards = [next(p) for p in self.pipes]
                batch = {k: np.concatenate([s[k] for s in shards])
                         for k in shards[0]}
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch)
                dt = time.time() - t0
                loss = float(metrics["loss"])
                self.step += 1
                for t in self.trackers:
                    t.step_commit(self.step, loss, dt,
                                  self.global_batch * self.seq_len)
                    t.heartbeat(self.step, dt)
                if self.step % self.ckpt_every == 0:
                    self.ckpt.submit({"params": self.params,
                                      "opt": self.opt_state}, self.step)
                self.pump_consumers()
                self.history.append({"step": self.step, "loss": loss,
                                     "time": dt})
        return self.history

    def close(self) -> None:
        self.ckpt.close()
        for w in self.metrics:
            w.close()
