"""Elastic scaling: mesh (re)planning + checkpoint resharding.

Membership comes from ELASTIC_JOIN/LEAVE changelog records (the
ElasticController consumer).  On a generation change the runtime:
  1. drains in-flight steps, async-checkpoints,
  2. rebuilds the mesh from the surviving hosts (largest usable 2^k),
  3. restores the (mesh-agnostic) checkpoint with the new shardings,
  4. resumes from the DATA_CONSUME watermark.

Checkpoints are mesh-agnostic (unsharded numpy per leaf), so resharding
is just device_put against the new mesh — no format conversion.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax

from ..models import transformer as T
from ..optim import adamw
from .sharding import LogicalRules
from .specs import shardings_of


def plan_mesh_shape(n_devices: int) -> Tuple[int, int]:
    """Largest usable power-of-two (data, model) grid <= n_devices."""
    usable = 1 << int(math.log2(max(n_devices, 1)))
    data = 1 << (int(math.log2(usable)) // 2)
    return data, usable // data


def make_elastic_mesh(n_devices: Optional[int] = None):
    devs = jax.devices()
    n = n_devices or len(devs)
    data, model = plan_mesh_shape(n)
    import numpy as np
    grid = np.array(devs[:data * model]).reshape(data, model)
    return jax.sharding.Mesh(grid, ("data", "model"))


def reshard_state(cfg, params, opt_state, mesh,
                  overrides: Optional[Dict] = None):
    """Land host (numpy) param/opt trees on ``mesh`` with the logical
    rules — the elastic restore path."""
    rules = LogicalRules(mesh, overrides)
    p_sh = shardings_of(rules, T.param_axes(cfg))
    params = jax.tree.map(jax.device_put, params, p_sh)
    if opt_state is not None:
        o_sh = adamw.AdamWState(
            step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            m=p_sh, v=p_sh)
        opt_state = jax.tree.map(jax.device_put, opt_state, o_sh)
    return params, opt_state, rules
