"""Straggler mitigation policy.

Detection lives in track.consumers.StragglerDetector (EWMA of per-host
step durations from HEARTBEAT/STEP records vs fleet median).  This
module is the *response*: rebalance data-shard ownership away from
flagged hosts proportionally to their measured slowdown, so the
synchronous step time tracks the median host, not the slowest.

Decisions are emitted as CL_STRAGGLER records so every consumer group
(metrics, elastic controller) observes them — the same changelog
backbone the paper provides.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from ..core import records as R
from ..track.consumers import StragglerDetector
from ..track.tracker import ActivityTracker


def rebalance_shards(n_shards: int, hosts: Sequence[int],
                     ewma: Dict[int, float]) -> Dict[int, List[int]]:
    """Assign data shards inversely proportional to per-host EWMA step
    time (missing hosts get median weight).  Every shard is assigned
    exactly once; every host keeps >= 1 shard unless fully flagged out."""
    if not hosts:
        return {}
    times = [ewma.get(h) for h in hosts]
    known = sorted(t for t in times if t)
    median = known[len(known) // 2] if known else 1.0
    speed = {h: median / (ewma.get(h) or median) for h in hosts}
    total = sum(speed.values())
    # largest-remainder apportionment
    quota = {h: n_shards * speed[h] / total for h in hosts}
    alloc = {h: int(quota[h]) for h in hosts}
    rem = n_shards - sum(alloc.values())
    for h in sorted(hosts, key=lambda h: quota[h] - alloc[h], reverse=True):
        if rem <= 0:
            break
        alloc[h] += 1
        rem -= 1
    out: Dict[int, List[int]] = {h: [] for h in hosts}
    shard = 0
    for h in hosts:
        for _ in range(alloc[h]):
            out[h].append(shard)
            shard += 1
    return out


class StragglerMitigator:
    def __init__(self, detector: StragglerDetector, n_shards: int,
                 tracker: Optional[ActivityTracker] = None):
        self.detector = detector
        self.n_shards = n_shards
        self.tracker = tracker
        self.assignment: Dict[int, List[int]] = {}

    def maybe_rebalance(self, hosts: Sequence[int],
                        step: int = 0) -> Optional[Dict[int, List[int]]]:
        """Returns a new shard assignment when stragglers are flagged
        (and logs the decision), else None."""
        if not self.detector.flagged:
            return None
        new = rebalance_shards(self.n_shards, hosts, self.detector.ewma)
        if new == self.assignment:
            return None
        self.assignment = new
        if self.tracker is not None:
            for h in sorted(self.detector.flagged):
                self.tracker._log(  # noqa: SLF001 — same-package protocol
                    R.CL_STRAGGLER, oid=h, ver=step,
                    xattr={"shards": {str(k): v for k, v in new.items()}})
        return new
