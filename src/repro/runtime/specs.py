"""Input specs + shardings for every (arch x shape x mesh) cell.

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins for
every model input (no device allocation) — what the multi-pod dry-run
lowers against.  ``cell_rules`` adapts the logical->mesh mapping to the
cell (e.g. batch unsharded when the batch does not divide the DP axes).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import transformer as T
from ..models.config import ModelConfig, ShapeConfig
from ..optim import adamw
from .sharding import DEFAULT_RULES, LogicalRules


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, str):
        return mesh.shape[ax]
    return int(np.prod([mesh.shape[a] for a in ax]))


def cell_rules(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               overrides: Optional[Dict[str, Any]] = None) -> LogicalRules:
    rules = LogicalRules(mesh, overrides)
    dp = _axis_size(mesh, rules.rules["batch"])
    if shape.global_batch % dp != 0:
        # e.g. long_500k batch=1: replicate the batch dimension
        rules.rules["batch"] = None
    return rules


def batch_struct(cfg: ModelConfig, shape: ShapeConfig,
                 kind: Optional[str] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract training/prefill batch: tokens/labels (+ stub modality
    frontends)."""
    kind = kind or shape.kind
    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.is_encoder_decoder:
        out["frames"] = jax.ShapeDtypeStruct((B, cfg.n_frames, cfg.d_model),
                                             jnp.bfloat16)
    if cfg.n_image_patches:
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_patches, cfg.d_model), jnp.bfloat16)
    return out


def batch_axes(cfg: ModelConfig, shape: ShapeConfig,
               kind: Optional[str] = None) -> Dict[str, Tuple]:
    kind = kind or shape.kind
    out = {"tokens": ("batch", None)}
    if kind == "train":
        out["labels"] = ("batch", None)
    if cfg.is_encoder_decoder:
        out["frames"] = ("batch", "frames", None)
    if cfg.n_image_patches:
        out["image_embeds"] = ("batch", None, None)
    return out


def shardings_of(rules: LogicalRules, axes_tree):
    return jax.tree.map(
        lambda axes: rules.sharding(axes), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def train_cell(cfg: ModelConfig, shape: ShapeConfig, rules: LogicalRules,
               param_dtype=None):
    """(abstract_args, in_shardings, out_shardings) for train_step."""
    params = T.abstract_params(cfg, param_dtype or jnp.float32)
    opt = adamw.abstract_state(params)
    batch = batch_struct(cfg, shape)
    p_shard = shardings_of(rules, T.param_axes(cfg))
    opt_shard = adamw.AdamWState(
        step=NamedSharding(rules.mesh, P()), m=p_shard,
        v=jax.tree.map(lambda s: s, p_shard))
    b_shard = shardings_of(rules, batch_axes(cfg, shape))
    metrics_shard = {k: NamedSharding(rules.mesh, P())
                     for k in ("loss", "grad_norm", "lr")}
    return ((params, opt, batch),
            (p_shard, opt_shard, b_shard),
            (p_shard, opt_shard, metrics_shard))


def prefill_cell(cfg: ModelConfig, shape: ShapeConfig, rules: LogicalRules,
                 param_dtype=None):
    params = T.abstract_params(cfg, param_dtype or jnp.float32)
    batch = batch_struct(cfg, shape, kind="prefill")
    p_shard = shardings_of(rules, T.param_axes(cfg))
    b_shard = shardings_of(rules, batch_axes(cfg, shape, kind="prefill"))
    cache_shard = shardings_of(rules, T.cache_axes(cfg))
    logits_shard = rules.sharding(("batch", "vocab"))
    return ((params, batch), (p_shard, b_shard),
            (logits_shard, cache_shard))


def decode_cell(cfg: ModelConfig, shape: ShapeConfig, rules: LogicalRules,
                param_dtype=None):
    B, S = shape.global_batch, shape.seq_len
    params = T.abstract_params(cfg, param_dtype or jnp.float32)
    cache = T.init_cache(cfg, B, S, abstract=True)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    p_shard = shardings_of(rules, T.param_axes(cfg))
    cache_shard = shardings_of(rules, T.cache_axes(cfg))
    tok_shard = rules.sharding(("batch", None))
    pos_shard = rules.sharding(("batch",))
    logits_shard = rules.sharding(("batch", "vocab"))
    return ((params, cache, token, pos),
            (p_shard, cache_shard, tok_shard, pos_shard),
            (logits_shard, cache_shard))
