from . import elastic, sharding, specs, steps, straggler

__all__ = ["elastic", "sharding", "specs", "steps", "straggler"]
