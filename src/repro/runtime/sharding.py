"""Logical-axis sharding (MaxText-style rules).

Model code annotates params/activations with *logical* axis names;
a ``LogicalRules`` context maps them to mesh axes.  Outside a rules
context every annotation is a no-op, so the same model code runs in CPU
unit tests, the single-pod mesh and the multi-pod mesh.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

# default rules for the single-pod (data, model) mesh
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": "data",          # global batch
    "seq": None,              # sequence (replicated by default)
    "seq_kv": "model",        # cached KV sequence in decode
    "embed": "data",          # d_model rows of weights (FSDP shards here)
    "mlp": "model",           # d_ff / ffn hidden (tensor parallel)
    "heads": "model",         # attention heads (tensor parallel)
    "kv_heads": None,         # kv heads (replicated; small for GQA)
    "head_dim": None,
    "qkv": "model",           # fused q/k/v output dim
    "vocab": "model",         # embedding/logit vocab dim
    "experts": "model",       # expert parallelism
    "expert_mlp": None,       # per-expert ffn hidden
    "layers": None,           # stacked scan bodies
    "conv": None,
    "ssm_inner": "model",     # SSD inner width
    "ssm_heads": "model",
    "state": None,
    "frames": None,
}

# multi-pod: DP spans ("pod", "data")
MULTIPOD_OVERRIDES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
}


class LogicalRules:
    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, Axis]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if "pod" in mesh.axis_names:
            self.rules.update(MULTIPOD_OVERRIDES)
        if rules:
            self.rules.update(rules)

    def spec(self, logical_axes: Sequence[Optional[str]]) -> P:
        out = []
        used = set()
        for name in logical_axes:
            ax = self.rules.get(name) if name else None
            # a mesh axis may be used at most once per spec
            if ax is not None:
                flat = (ax,) if isinstance(ax, str) else tuple(ax)
                if any(a in used for a in flat):
                    ax = None
                else:
                    used.update(flat)
            out.append(ax)
        return P(*out)

    def sharding(self, logical_axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes))


_tls = threading.local()


def current_rules() -> Optional[LogicalRules]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[LogicalRules]):
    prev = getattr(_tls, "rules", None)
    _tls.rules = rules
    try:
        yield rules
    finally:
        _tls.rules = prev


def axis_size(logical_name: str) -> int:
    """Mesh extent the given logical axis maps to (1 without rules)."""
    rules = current_rules()
    if rules is None:
        return 1
    ax = rules.rules.get(logical_name)
    if ax is None:
        return 1
    if isinstance(ax, str):
        return rules.mesh.shape[ax]
    import numpy as _np
    return int(_np.prod([rules.mesh.shape[a] for a in ax]))


def lshard(x, *logical_axes):
    """Constrain ``x`` to the mapping of ``logical_axes`` (no-op without
    an active rules context)."""
    rules = current_rules()
    if rules is None:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(f"rank {x.ndim} vs axes {logical_axes}")
    return jax.lax.with_sharding_constraint(x, rules.sharding(logical_axes))
