"""Sharded checkpoint save/restore riding the LCAP stream.

Save: the param/opt pytree is flattened; leaves are round-robined into
``n_shards`` .npz files (one per writer host in a real deployment).
Each completed shard emits a CL_CKPT_WRITE record; the load-balanced
CheckpointCommitter group publishes the manifest once all shards have
been seen (tests/test_track.py), making the commit protocol exactly the
paper's collective-acknowledgement pattern.

Restore: read the manifest (or directly the shard files), reassemble,
then ``jax.device_put`` against the CURRENT mesh's shardings — which is
also how elastic resharding works (the checkpoint is mesh-agnostic).

``AsyncCheckpointer`` overlaps serialization/IO with training (the host
thread writes while the next step runs on device).
"""

from __future__ import annotations

import json
import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save_checkpoint(tree, step: int, out_dir: str, *, n_shards: int = 4,
                    tracker=None) -> List[str]:
    """Write ``n_shards`` npz files + a local index; emits CKPT_WRITE
    records when a tracker is given.  Returns the shard paths."""
    os.makedirs(out_dir, exist_ok=True)
    flat = _flatten(tree)
    paths = []
    for shard in range(n_shards):
        arrs = {str(i): np.asarray(leaf)
                for i, (name, leaf) in enumerate(flat)
                if i % n_shards == shard}
        path = os.path.join(out_dir, f"step-{step:08d}-shard{shard}.npz")
        tmp = path + ".tmp.npz"
        np.savez(tmp, **arrs)
        os.replace(tmp, path)
        paths.append(path)
        if tracker is not None:
            tracker.ckpt_write(step, shard_id=shard,
                               nbytes=os.path.getsize(path), path=path,
                               total_shards=n_shards)
    index = {"step": step, "n_shards": n_shards,
             "leaves": [name for name, _ in flat]}
    with open(os.path.join(out_dir, f"step-{step:08d}.index.json"),
              "w") as fh:
        json.dump(index, fh)
    return paths


def latest_step(out_dir: str) -> Optional[int]:
    if not os.path.isdir(out_dir):
        return None
    steps = [int(f.split("-")[1].split(".")[0])
             for f in os.listdir(out_dir) if f.endswith(".index.json")]
    return max(steps) if steps else None


def restore_checkpoint(tree_like, step: int, out_dir: str,
                       shardings=None):
    """Rebuild the pytree of ``tree_like`` (structure donor) from the
    shard files.  ``shardings``: optional matching pytree of
    NamedSharding — THIS is where elastic resharding happens: the
    checkpoint is mesh-agnostic and lands on whatever mesh is current."""
    with open(os.path.join(out_dir, f"step-{step:08d}.index.json")) as fh:
        index = json.load(fh)
    n_shards = index["n_shards"]
    arrays: Dict[int, np.ndarray] = {}
    for shard in range(n_shards):
        path = os.path.join(out_dir, f"step-{step:08d}-shard{shard}.npz")
        with np.load(path) as z:
            for k in z.files:
                arrays[int(k)] = z[k]
    leaves_order = [arrays[i] for i in range(len(arrays))]
    treedef = jax.tree_util.tree_structure(tree_like)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves_order)
    if shardings is not None:
        rebuilt = jax.tree.map(
            lambda a, s: jax.device_put(a, s), rebuilt, shardings)
    return rebuilt


class AsyncCheckpointer:
    """Background checkpoint writer: snapshot on the caller thread
    (cheap host copies), serialize+write off-thread."""

    def __init__(self, out_dir: str, n_shards: int = 4, tracker=None):
        self.out_dir = out_dir
        self.n_shards = n_shards
        self.tracker = tracker
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._last: Optional[Future] = None

    def submit(self, tree, step: int) -> Future:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        self._last = self._pool.submit(
            save_checkpoint, host_tree, step, self.out_dir,
            n_shards=self.n_shards, tracker=self.tracker)
        return self._last

    def wait(self) -> None:
        if self._last is not None:
            self._last.result()
            self._last = None

    def close(self) -> None:
        self.wait()
        self._pool.shutdown()
