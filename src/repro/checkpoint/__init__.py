from .ckpt import (AsyncCheckpointer, restore_checkpoint, save_checkpoint,
                   latest_step)

__all__ = ["save_checkpoint", "restore_checkpoint", "AsyncCheckpointer",
           "latest_step"]
