"""Deterministic, shardable synthetic token pipeline.

Batches are a pure function of (seed, shard, step), so any host can
regenerate any range — restart never needs data movement, only the
DATA_CONSUME changelog records to know where to resume.  The pipeline
emits one record per consumed range through the host's ActivityTracker
(the journal IS the replay log)."""

from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from ..track.tracker import ActivityTracker


class ShardedTokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 n_shards: int, shard_id: int, seed: int = 0,
                 tracker: Optional[ActivityTracker] = None):
        assert global_batch % n_shards == 0
        self.vocab = vocab_size
        self.seq_len = seq_len
        self.local_batch = global_batch // n_shards
        self.n_shards = n_shards
        self.shard_id = shard_id
        self.seed = seed
        self.tracker = tracker
        self.step = 0

    # -- deterministic generation -------------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for (shard, step) — stateless; used for replay too."""
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[0, 0, self.shard_id, step]))
        tokens = rng.integers(0, self.vocab,
                              (self.local_batch, self.seq_len + 1),
                              dtype=np.int64).astype(np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self.batch_at(self.step)
        if self.tracker is not None:
            lo = self.step * self.local_batch
            self.tracker.data_consume(self.step, self.shard_id, lo,
                                      lo + self.local_batch)
        self.step += 1
        return batch

    # -- restart -------------------------------------------------------------
    def seek(self, step: int) -> None:
        self.step = step

    @staticmethod
    def resume_step_from_records(records) -> int:
        """Highest consumed step + 1, from replayed DATA_CONSUME records."""
        hi = -1
        for rec in records:
            hi = max(hi, rec.tfid.ver)
        return hi + 1
