from .pipeline import ShardedTokenPipeline

__all__ = ["ShardedTokenPipeline"]
