"""Windowed aggregation over the live changelog stream.

``ActivityAggregator`` is an ordinary consumer (``_GroupWorker`` on the
Session API — it runs against a single proxy, a TCP service, or a whole
cluster) that folds every batch into **tumbling windows** keyed by
stream time (``cr_time // window_ns``) of per-(op-type, jobid,
producer, shard-host) record counts and value sums (the first
CLF_METRICS gauge: loss, bytes written, step seconds — whatever the op
carries).

The fold is columnar end to end: window ids, op types, jobids, shard
hosts and metric values are gathered as whole columns from the
``RecordBatch`` header table and payload extensions, grouped with one
``lexsort`` + change-point scan, and reduced with ``np.add.reduceat`` —
per *unique group* Python, never per record.

Windows live in a bounded ring (``retention`` newest window ids);
records older than the evicted horizon count as ``late_dropped``.
**Sliding views** are sums over the last *k* panes; **trend deltas**
(rate, diff vs the previous window) come from comparing adjacent panes.
Built with ``replay=True`` the aggregator warm-starts from the
compacted history tier before tailing live — the stanford-rc HSM
viewer's bootstrap-then-follow shape.

Delivery is at-least-once: in a clean run (no failover) counters match
an exact offline SQL aggregation record for record (equivalence-tested
against ``MetricsDB``); across a shard kill, redelivered records can
count twice — trends, not ledgers.
"""

from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core import records as R
from repro.track.consumers import _GroupWorker

__all__ = ["ActivityAggregator", "WindowKey"]

#: aggregation key: (op type, jobid, producer, shard host)
WindowKey = Tuple[int, str, str, int]

#: dimension name -> position in WindowKey
DIMS = {"op": 0, "jobid": 1, "producer": 2, "shard": 3}


class ActivityAggregator(_GroupWorker):
    def __init__(self, target, group: str = "obs",
                 window_ns: int = 1_000_000_000, retention: int = 120,
                 flags: Optional[int] = None,
                 types: Optional[Iterable[int]] = None,
                 name: Optional[str] = None, mode: str = "persistent",
                 replay=None):
        if window_ns <= 0:
            raise ValueError("window_ns must be positive")
        # jobid + shard are the aggregation dimensions; ask the proxy to
        # keep (only) them unless the caller projects differently
        if flags is None:
            flags = R.CLF_JOBID | R.CLF_SHARD | R.CLF_METRICS
        # zero_fill off: the column gathers read absent extensions as
        # zeros already, so delivery stays strip-only (usually identity)
        super().__init__(target, group, flags=flags, types=types,
                         name=name, mode=mode, replay=replay,
                         zero_fill=False)
        self.window_ns = int(window_ns)
        self.retention = int(retention)
        self._lock = threading.Lock()
        #: window id -> {WindowKey: [count, value_sum]}
        self._windows: Dict[int, Dict[WindowKey, list]] = {}
        self._evict_hi = -(1 << 62)          # newest evicted window id
        self._jobid_ids: Dict[bytes, int] = {}
        self._jobid_names: List[str] = []
        self.stats = {"records": 0, "batches": 0, "late_dropped": 0,
                      "windows_evicted": 0}

    # ------------------------------------------------------------- the fold
    def _intern_jobids(self, batch: R.RecordBatch) -> np.ndarray:
        """Map each record's 32-byte jobid to a small int id (stable for
        the aggregator's lifetime); one ``np.unique`` per batch, one
        dict probe per *distinct* jobid."""
        mat = batch.jobid_col()
        void = np.ascontiguousarray(mat).view(
            np.dtype((np.void, mat.shape[1]))).ravel()
        uniq, inverse = np.unique(void, return_inverse=True)
        ids = np.empty(len(uniq), dtype=np.int64)
        for j, raw in enumerate(uniq):
            key = raw.tobytes()
            known = self._jobid_ids.get(key)
            if known is None:
                known = self._jobid_ids[key] = len(self._jobid_names)
                self._jobid_names.append(
                    key.rstrip(b"\0").decode("utf-8", errors="replace"))
            ids[j] = known
        return ids[inverse]

    def handle_batch(self, pid: str, batch: R.RecordBatch) -> None:
        n = len(batch)
        if not n:
            return
        h = batch.header()
        wins = (h["time"].astype(np.int64) // self.window_ns)
        ops = h["type"].astype(np.int64)
        jids = self._intern_jobids(batch)
        _pod, hosts = batch.shard_cols()
        vals = batch.metric0_col()

        order = np.lexsort((hosts, jids, ops, wins))
        w = wins[order]
        o = ops[order]
        j = jids[order]
        s = hosts[order]
        v = vals[order]
        change = np.empty(n, dtype=bool)
        change[0] = True
        change[1:] = ((w[1:] != w[:-1]) | (o[1:] != o[:-1])
                      | (j[1:] != j[:-1]) | (s[1:] != s[:-1]))
        starts = np.flatnonzero(change)
        counts = np.diff(np.append(starts, n))
        vsums = np.add.reduceat(v, starts)

        with self._lock:
            names = self._jobid_names
            for st, c, vs in zip(starts.tolist(), counts.tolist(),
                                 vsums.tolist()):
                win = int(w[st])
                if win <= self._evict_hi:
                    self.stats["late_dropped"] += c
                    continue
                wd = self._windows.get(win)
                if wd is None:
                    wd = self._windows[win] = {}
                key = (int(o[st]), names[int(j[st])], pid, int(s[st]))
                cell = wd.get(key)
                if cell is None:
                    wd[key] = [c, vs]
                else:
                    cell[0] += c
                    cell[1] += vs
            self.stats["records"] += n
            self.stats["batches"] += 1
            while len(self._windows) > self.retention:
                oldest = min(self._windows)
                del self._windows[oldest]
                if oldest > self._evict_hi:
                    self._evict_hi = oldest
                self.stats["windows_evicted"] += 1

    # ------------------------------------------------------------- queries
    def window_ids(self) -> List[int]:
        with self._lock:
            return sorted(self._windows)

    @property
    def current_window(self) -> Optional[int]:
        with self._lock:
            return max(self._windows) if self._windows else None

    def counters(self, window: Optional[int] = None,
                 ) -> Dict[WindowKey, Tuple[int, float]]:
        """The full key table of one window (default: newest)."""
        with self._lock:
            if window is None:
                if not self._windows:
                    return {}
                window = max(self._windows)
            wd = self._windows.get(window, {})
            return {k: (c, vs) for k, (c, vs) in wd.items()}

    def sliding(self, k: int, end: Optional[int] = None,
                ) -> Dict[WindowKey, Tuple[int, float]]:
        """Counters summed over the last ``k`` panes ending at ``end``
        (default: newest) — the sliding-window view of the same fold."""
        with self._lock:
            if end is None:
                if not self._windows:
                    return {}
                end = max(self._windows)
            out: Dict[WindowKey, list] = {}
            for win in range(end - k + 1, end + 1):
                for key, (c, vs) in self._windows.get(win, {}).items():
                    cell = out.get(key)
                    if cell is None:
                        out[key] = [c, vs]
                    else:
                        cell[0] += c
                        cell[1] += vs
            return {k_: (c, vs) for k_, (c, vs) in out.items()}

    def totals(self) -> List[Tuple[int, int, float]]:
        """Per retained window: (window id, records, value sum)."""
        with self._lock:
            return [(win,
                     sum(c for c, _ in wd.values()),
                     sum(vs for _, vs in wd.values()))
                    for win, wd in sorted(self._windows.items())]

    def top(self, dim: str = "jobid", k: int = 10,
            window: Optional[int] = None,
            sliding: Optional[int] = None) -> List[dict]:
        """The busiest labels of one dimension, with trend deltas.

        Each row: ``label``, ``count``, ``value_sum``, ``rate`` (records
        per second across the measured span) and ``delta`` (count minus
        the previous same-width span — positive = heating up)."""
        pos = DIMS[dim]
        span = max(1, int(sliding or 1))
        with self._lock:
            if window is None:
                if not self._windows:
                    return []
                window = max(self._windows)
        cur = self._fold_dim(self.sliding(span, end=window), pos)
        prev = self._fold_dim(self.sliding(span, end=window - span), pos)
        secs = span * self.window_ns / 1e9
        rows = []
        for label, (c, vs) in cur.items():
            if dim == "op":
                label = R.TYPE_NAMES.get(label, f"?{label}")
            rows.append({"label": label, "count": c, "value_sum": vs,
                         "rate": c / secs,
                         "delta": c - prev.get(label, (0, 0.0))[0]})
        rows.sort(key=lambda r: (-r["count"], str(r["label"])))
        return rows[:k]

    @staticmethod
    def _fold_dim(table: Dict[WindowKey, Tuple[int, float]],
                  pos: int) -> Dict[object, Tuple[int, float]]:
        out: Dict[object, list] = {}
        for key, (c, vs) in table.items():
            cell = out.get(key[pos])
            if cell is None:
                out[key[pos]] = [c, vs]
            else:
                cell[0] += c
                cell[1] += vs
        return {k: (c, vs) for k, (c, vs) in out.items()}

    def rate(self, window: Optional[int] = None) -> float:
        """Aggregate records/second of one window (default: newest)."""
        table = self.counters(window)
        secs = self.window_ns / 1e9
        return sum(c for c, _ in table.values()) / secs

    # ------------------------------------------------------------ plumbing
    def run_once(self, max_records: int = 4096) -> int:
        """Drain whatever the stream has buffered right now (replay
        bootstrap included); returns records folded."""
        moved = 0
        while True:
            got = self.poll(max_records)
            if not got:
                return moved
            moved += got

    def collector(self, labels: Optional[Dict[str, str]] = None):
        """A registry collector exporting the newest *closed* pane (the
        one before the still-filling newest window) as labeled gauges —
        hook with ``registry.register_collector(agg.collector())``."""
        base = dict(labels or {})

        def _collect():
            with self._lock:
                wins = sorted(self._windows)
                stats = dict(self.stats)
            out = [(f"lcap_agg_{key}_total", "counter",
                    f"aggregator stats[{key}]", base, val)
                   for key, val in stats.items()]
            out.append(("lcap_agg_windows_retained", "gauge",
                        "window panes currently held", base, len(wins)))
            target = wins[-2] if len(wins) > 1 else None
            if target is not None:
                for (op, jobid, pid, host), (c, vs) in \
                        self.counters(target).items():
                    lb = dict(base, op=R.TYPE_NAMES.get(op, str(op)),
                              jobid=jobid, producer=pid, shard=str(host),
                              window=str(target))
                    out.append(("lcap_window_records", "gauge",
                                "records in the newest closed window",
                                lb, c))
                    out.append(("lcap_window_value_sum", "gauge",
                                "metric-0 sum in the newest closed window",
                                lb, vs))
            return out

        return _collect
