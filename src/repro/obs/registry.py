"""Typed internal metrics: counters, gauges, histograms, atomic snapshots.

The registry unifies the ad-hoc ``proxy.stats`` / ``cluster.stats``
dicts into labeled instruments with one wire-friendly snapshot format.
Two publishing styles are supported:

- **push**: code holds an instrument child and calls ``inc()`` /
  ``set()`` / ``observe()`` on the hot path (cheap: one lock, one add).
- **pull**: a *collector* callable is registered and invoked at
  ``snapshot()`` time, yielding ``(name, kind, help, labels, value)``
  tuples read from live state (the proxy exports its ``stats`` dict and
  per-group ack-tracker depths this way, so the hot path pays nothing).

``snapshot()`` returns a plain msgpack-able dict — the payload of the
``metrics`` RPC verb — and :func:`merge_snapshots` folds per-shard
snapshots into one cluster view (summing counters/histograms, relabeling
by shard so gauges never collide).
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "merge_snapshots",
    "DEFAULT_BUCKETS",
]

#: default histogram buckets (seconds) — spans sub-ms pump latencies up
#: to multi-second stalls.
DEFAULT_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
    0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


class Counter:
    """Monotonically increasing value."""

    __slots__ = ("_lock", "_value")
    kind = "counter"

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _sample(self) -> float:
        return self.value


class Gauge:
    """Point-in-time value; may also be bound to a callable."""

    __slots__ = ("_lock", "_value", "_fn")
    kind = "gauge"

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value -= amount

    def set_function(self, fn: Callable[[], float]) -> None:
        """Read ``fn()`` at snapshot time instead of a stored value."""
        self._fn = fn

    @property
    def value(self) -> float:
        if self._fn is not None:
            return float(self._fn())
        with self._lock:
            return self._value

    def _sample(self) -> float:
        return self.value


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics)."""

    __slots__ = ("_lock", "buckets", "_counts", "_sum", "_count")
    kind = "histogram"

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS) -> None:
        b = sorted(float(x) for x in buckets)
        if not b:
            raise ValueError("histogram needs at least one bucket")
        self._lock = threading.Lock()
        self.buckets = tuple(b)
        self._counts = [0] * len(b)
        self._sum = 0.0
        self._count = 0

    def observe(self, value: float) -> None:
        with self._lock:
            self._sum += value
            self._count += 1
            # linear probe: pump latencies cluster in the low buckets
            for i, le in enumerate(self.buckets):
                if value <= le:
                    self._counts[i] += 1
                    break

    def _sample(self) -> dict:
        with self._lock:
            cum, out = 0, []
            for le, c in zip(self.buckets, self._counts):
                cum += c
                out.append([le, cum])
            return {"buckets": out, "sum": self._sum, "count": self._count}


class _Family:
    """A named metric with a fixed label schema; children per label set."""

    __slots__ = ("name", "help", "kind", "labelnames", "_make", "_lock",
                 "_children")

    def __init__(self, name: str, help: str, kind: str,
                 labelnames: Tuple[str, ...], make: Callable[[], object]):
        self.name = name
        self.help = help
        self.kind = kind
        self.labelnames = labelnames
        self._make = make
        self._lock = threading.Lock()
        self._children: Dict[Tuple[str, ...], object] = {}
        if not labelnames:                    # usable directly when unlabeled
            self._children[()] = make()

    def labels(self, **kv: object):
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(sorted(kv))}")
        key = tuple(str(kv[n]) for n in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._make()
            return child

    # unlabeled convenience: family proxies to its single child
    def inc(self, amount: float = 1.0) -> None:
        self._children[()].inc(amount)          # type: ignore[attr-defined]

    def set(self, value: float) -> None:
        self._children[()].set(value)           # type: ignore[attr-defined]

    def dec(self, amount: float = 1.0) -> None:
        self._children[()].dec(amount)          # type: ignore[attr-defined]

    def observe(self, value: float) -> None:
        self._children[()].observe(value)       # type: ignore[attr-defined]

    def set_function(self, fn: Callable[[], float]) -> None:
        self._children[()].set_function(fn)     # type: ignore[attr-defined]

    @property
    def value(self):
        return self._children[()].value         # type: ignore[attr-defined]

    def _samples(self) -> List[list]:
        with self._lock:
            items = list(self._children.items())
        out = []
        for key, child in items:
            labels = dict(zip(self.labelnames, key))
            out.append([labels, child._sample()])  # type: ignore[attr-defined]
        return out


#: collector yield type: (name, kind, help, labels, value)
CollectorSample = Tuple[str, str, str, Dict[str, str], float]


class MetricsRegistry:
    """Instrument factory + atomic snapshot over instruments and collectors."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}
        self._collectors: List[Callable[[], Iterable[CollectorSample]]] = []

    # ------------------------------------------------------------ factories
    def _family(self, name: str, help: str, kind: str,
                labels: Sequence[str], make: Callable[[], object]) -> _Family:
        labelnames = tuple(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != labelnames:
                    raise ValueError(
                        f"metric {name!r} re-registered as {kind}"
                        f"{labelnames}, was {fam.kind}{fam.labelnames}")
                return fam
            fam = self._families[name] = _Family(
                name, help, kind, labelnames, make)
            return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> _Family:
        return self._family(name, help, "counter", labels, Counter)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> _Family:
        return self._family(name, help, "gauge", labels, Gauge)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> _Family:
        return self._family(name, help, "histogram", labels,
                            lambda: Histogram(buckets))

    def register_collector(
            self, fn: Callable[[], Iterable[CollectorSample]]) -> None:
        with self._lock:
            self._collectors.append(fn)

    # ------------------------------------------------------------- snapshot
    def snapshot(self) -> Dict[str, dict]:
        """One msgpack-able view: ``{name: {type, help, samples}}`` where
        each sample is ``[labels_dict, value]`` (histogram values are
        ``{buckets: [[le, cumulative], ...], sum, count}``)."""
        with self._lock:
            families = list(self._families.values())
            collectors = list(self._collectors)
        out: Dict[str, dict] = {}
        for fam in families:
            out[fam.name] = {"type": fam.kind, "help": fam.help,
                             "samples": fam._samples()}
        for fn in collectors:
            for name, kind, help, labels, value in fn():
                ent = out.setdefault(
                    name, {"type": kind, "help": help, "samples": []})
                ent["samples"].append([dict(labels), value])
        return out


def _merge_value(kind: str, a, b):
    if kind == "histogram":
        # bucket schemas match across shards (same code built them)
        buckets = [[le, ca + cb] for (le, ca), (_, cb)
                   in zip(a["buckets"], b["buckets"])]
        return {"buckets": buckets, "sum": a["sum"] + b["sum"],
                "count": a["count"] + b["count"]}
    return a + b


def merge_snapshots(per_shard: Dict[str, Dict[str, dict]],
                    shard_label: str = "shard") -> Dict[str, dict]:
    """Fold per-shard snapshots into one cluster snapshot.

    Counters and histograms with identical label sets are summed;
    gauges keep a ``shard`` label so per-shard depths stay visible
    (summing outbox depth across shards hides a hot shard).
    """
    out: Dict[str, dict] = {}
    for sid, snap in sorted(per_shard.items()):
        for name, ent in snap.items():
            tgt = out.setdefault(
                name, {"type": ent["type"], "help": ent.get("help", ""),
                       "samples": []})
            for labels, value in ent["samples"]:
                labels = dict(labels)
                if ent["type"] == "gauge":
                    labels[shard_label] = str(sid)
                for row in tgt["samples"]:
                    if row[0] == labels:
                        row[1] = _merge_value(ent["type"], row[1], value)
                        break
                else:
                    tgt["samples"].append([labels, value])
    return out
