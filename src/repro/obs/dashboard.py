"""``top`` for the activity stream — a curses-free live terminal view.

Renders, from an :class:`ActivityAggregator` plus optional session /
cluster handles:

- headline window rates and totals with a per-window sparkline,
- the busiest jobids / op types / shards of the newest pane(s) with
  trend arrows (diff vs the previous same-width span),
- consumer lag per (group, producer) — dispatch watermark minus the
  group's ack cursor (``Session.lag`` / ``ClusterSession.lag``),
- shard health (alive/dead, slots owned, routing counters) when a
  ``LcapCluster`` handle is given.

``render()`` returns the frame as a string (what the tests drive);
``run()`` repaints in place with ANSI clear — no curses dependency, so
it works over any dumb pipe and in CI logs.
"""

from __future__ import annotations

import sys
import time
from typing import Dict, List, Optional

__all__ = ["ActivityTop"]

_SPARK = "▁▂▃▄▅▆▇█"


def _spark(values: List[float], width: int = 24) -> str:
    if not values:
        return ""
    tail = values[-width:]
    hi = max(tail) or 1.0
    return "".join(_SPARK[min(len(_SPARK) - 1,
                              int(v / hi * (len(_SPARK) - 1)))]
                   for v in tail)


def _arrow(delta: float) -> str:
    if delta > 0:
        return f"↑{delta:+,.0f}"
    if delta < 0:
        return f"↓{delta:+,.0f}"
    return "·"


def _fmt_count(v: float) -> str:
    return f"{v:,.0f}"


class ActivityTop:
    def __init__(self, aggregator, session=None, cluster=None,
                 k: int = 8, sliding: int = 1, width: int = 78):
        self.agg = aggregator
        self.session = session        # Session or ClusterSession (lag())
        self.cluster = cluster        # LcapCluster (shard health)
        self.k = k
        self.sliding = sliding
        self.width = width

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> dict:
        """The structured data one frame renders (stable test surface)."""
        agg = self.agg
        snap = {
            "window_ns": agg.window_ns,
            "windows": agg.totals(),
            "stats": dict(agg.stats),
            "top": {dim: agg.top(dim, k=self.k, sliding=self.sliding)
                    for dim in ("jobid", "op", "producer", "shard")},
            "lag": {},
            "shards": [],
        }
        if self.session is not None:
            try:
                lag = self.session.lag()
            except (ConnectionError, OSError):
                lag = {}
            snap["lag"] = {g: v for g, v in lag.items()
                           if g != "per_shard"}
        if self.cluster is not None:
            owned = [0] * len(self.cluster.shards)
            for o in self.cluster.slot_owner:
                owned[o] += 1
            snap["shards"] = [
                {"index": i, "alive": bool(self.cluster.alive[i]),
                 "slots": owned[i]}
                for i in range(len(self.cluster.shards))]
            snap["cluster_stats"] = dict(self.cluster.stats)
        return snap

    # -------------------------------------------------------------- render
    def render(self) -> str:
        s = self.snapshot()
        w = self.width
        lines: List[str] = []
        secs = s["window_ns"] / 1e9
        windows = s["windows"]
        total = sum(c for _, c, _ in windows)
        cur_rate = (windows[-1][1] / secs) if windows else 0.0
        lines.append(f"lcap top — pane {secs:g}s · {len(windows)} retained "
                     f"· {_fmt_count(total)} records "
                     f"· {_fmt_count(cur_rate)} rec/s")
        lines.append(_spark([c for _, c, _ in windows]) or "(no traffic yet)")
        st = s["stats"]
        lines.append(f"folded {_fmt_count(st['records'])} in "
                     f"{_fmt_count(st['batches'])} batches · late "
                     f"{_fmt_count(st['late_dropped'])} · evicted "
                     f"{_fmt_count(st['windows_evicted'])} panes")
        lines.append("─" * w)

        for dim, title in (("jobid", "BUSIEST JOBS"),
                           ("op", "BUSIEST OPS"),
                           ("shard", "BUSIEST SHARDS"),
                           ("producer", "BUSIEST PRODUCERS")):
            rows = s["top"][dim]
            if not rows:
                continue
            lines.append(f"{title:<24}{'COUNT':>12}{'RATE/S':>12}"
                         f"{'VALUE':>14}{'TREND':>12}")
            for r in rows:
                label = str(r["label"]) or "(none)"
                lines.append(f"  {label[:22]:<22}"
                             f"{_fmt_count(r['count']):>12}"
                             f"{r['rate']:>12,.1f}"
                             f"{r['value_sum']:>14,.2f}"
                             f"{_arrow(r['delta']):>12}")
            lines.append("")

        if s["lag"]:
            lines.append(f"{'CONSUMER LAG':<18}{'PRODUCER':>12}"
                         f"{'DISPATCH':>12}{'ACK':>12}{'LAG':>9}"
                         f"{'IN-FLIGHT':>11}")
            for group in sorted(s["lag"]):
                for pid in sorted(s["lag"][group]):
                    ent = s["lag"][group][pid]
                    lines.append(f"  {group[:16]:<16}{pid:>12}"
                                 f"{ent['dispatch_hw']:>12,}"
                                 f"{ent['ack']:>12,}{ent['lag']:>9,}"
                                 f"{ent['in_flight']:>11,}")
            lines.append("")

        if s["shards"]:
            health = "  ".join(
                f"shard{e['index']}[{'UP' if e['alive'] else 'DOWN'}"
                f" {e['slots']}sl]" for e in s["shards"])
            lines.append(f"SHARDS  {health}")
            cs = s.get("cluster_stats", {})
            if cs:
                lines.append(f"  routed {_fmt_count(cs.get('routed', 0))} "
                             f"· rounds {_fmt_count(cs.get('routing_rounds', 0))} "
                             f"· failed {cs.get('shards_failed', 0)} "
                             f"· failover redelivered "
                             f"{_fmt_count(cs.get('failover_redelivered', 0))}")
        return "\n".join(lines)

    # ----------------------------------------------------------- live loop
    def run(self, interval: float = 1.0, iterations: Optional[int] = None,
            out=None, clear: bool = True, poll: bool = True) -> None:
        """Repaint every ``interval`` seconds (``iterations=None`` runs
        until interrupted).  With ``poll`` the aggregator's stream is
        drained before each frame — one-process demos need no separate
        consumer thread."""
        out = out or sys.stdout
        n = 0
        try:
            while iterations is None or n < iterations:
                if poll:
                    self.agg.run_once()
                frame = self.render()
                if clear:
                    out.write("\x1b[2J\x1b[H")
                out.write(frame + "\n")
                out.flush()
                n += 1
                if iterations is not None and n >= iterations:
                    break
                time.sleep(interval)
        except KeyboardInterrupt:
            pass
