"""Observability plane over the LCAP stream.

Three layers, each owning a different kind of signal:

- :mod:`repro.obs.registry` — typed internal metrics (counter / gauge /
  histogram) that the proxy, cluster, ack tracker, and transport publish
  into.  These describe the *fabric*: dispatch latency, outbox depth,
  backpressure parks, redeliveries.
- :mod:`repro.obs.aggregator` — a windowed aggregation consumer that
  folds the *stream itself* into per-(op, jobid, producer, shard)
  tumbling windows with sliding views and trend deltas.
- :mod:`repro.obs.exporter` / :mod:`repro.obs.dashboard` — the edges:
  a Prometheus-text HTTP endpoint, a Ganglia-shaped pusher, and a
  ``top``-style terminal view.
"""

from repro.obs.registry import (          # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry, merge_snapshots,
)
from repro.obs.aggregator import ActivityAggregator   # noqa: F401
from repro.obs.exporter import (          # noqa: F401
    PrometheusExporter, GangliaPusher, render_prometheus,
)
from repro.obs.dashboard import ActivityTop           # noqa: F401
