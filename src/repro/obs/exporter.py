"""Metric export edges: Prometheus text scrape + Ganglia-shaped push.

Both edges render the same source — a ``MetricsRegistry`` snapshot
(which already folds in any registered aggregator collectors) — so
everything visible on the dashboard is also visible to the fleet
monitoring stack.

- :class:`PrometheusExporter`: a stdlib-only threaded HTTP server whose
  ``GET /metrics`` serves text exposition format 0.0.4 (``# HELP`` /
  ``# TYPE`` heads, escaped labels, ``_bucket``/``_sum``/``_count``
  histogram expansion).
- :class:`GangliaPusher`: flattens the same snapshot into gmond-module
  shaped metric dicts — dotted names built from a ``name_map`` plus the
  label values, with units, like the lustre gmond module's per-target
  stats — handed to a pluggable ``send`` callable (gmetric spawn, UDP
  socket, or the default in-memory list for tests).
"""

from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, List, Optional

__all__ = ["render_prometheus", "PrometheusExporter", "GangliaPusher"]


# ------------------------------------------------------------- text format
def _sanitize_name(name: str) -> str:
    out = []
    for i, ch in enumerate(name):
        ok = ch.isascii() and (ch.isalpha() or ch == "_"
                               or (ch.isdigit() and i > 0) or ch == ":")
        out.append(ch if ok else "_")
    return "".join(out)


def _escape_label(value: str) -> str:
    return (str(value).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_sanitize_name(k)}="{_escape_label(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def render_prometheus(snapshot: Dict[str, dict]) -> str:
    """Render a registry snapshot as Prometheus exposition text."""
    lines: List[str] = []
    for name in sorted(snapshot):
        ent = snapshot[name]
        mname = _sanitize_name(name)
        kind = ent.get("type", "untyped")
        help_ = ent.get("help", "")
        if help_:
            lines.append(f"# HELP {mname} {_escape_label(help_)}")
        lines.append(f"# TYPE {mname} {kind}")
        for labels, value in ent.get("samples", []):
            if kind == "histogram":
                for le, cum in value["buckets"]:
                    lb = dict(labels, le=_fmt_value(le))
                    lines.append(f"{mname}_bucket{_fmt_labels(lb)} {cum}")
                inf = dict(labels, le="+Inf")
                lines.append(
                    f"{mname}_bucket{_fmt_labels(inf)} {value['count']}")
                lines.append(f"{mname}_sum{_fmt_labels(labels)} "
                             f"{_fmt_value(value['sum'])}")
                lines.append(f"{mname}_count{_fmt_labels(labels)} "
                             f"{value['count']}")
            else:
                lines.append(
                    f"{mname}{_fmt_labels(labels)} {_fmt_value(value)}")
    return "\n".join(lines) + "\n"


# ------------------------------------------------------------ HTTP scrape
class PrometheusExporter:
    """Serve ``GET /metrics`` for a registry (or any ``snapshot()``-
    shaped source, e.g. ``LcapCluster.metrics``)."""

    content_type = "text/plain; version=0.0.4; charset=utf-8"

    def __init__(self, registry=None, snapshot_fn: Optional[
            Callable[[], Dict[str, dict]]] = None,
            host: str = "127.0.0.1", port: int = 0):
        if (registry is None) == (snapshot_fn is None):
            raise ValueError("pass exactly one of registry / snapshot_fn")
        self._snapshot = snapshot_fn or registry.snapshot
        exporter = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                          # noqa: N802
                if self.path.split("?", 1)[0] != "/metrics":
                    self.send_error(404)
                    return
                body = exporter.render().encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", exporter.content_type)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):              # quiet
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self.address = self._httpd.server_address
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True)

    @property
    def url(self) -> str:
        return f"http://{self.address[0]}:{self.address[1]}/metrics"

    def render(self) -> str:
        return render_prometheus(self._snapshot())

    def start(self) -> "PrometheusExporter":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()


# ------------------------------------------------------------ Ganglia push
class GangliaPusher:
    """Push-mode export, shaped like a gmond python module.

    Each ``push()`` flattens the current snapshot into
    ``{"name", "value", "type", "units", "group"}`` dicts — the keyword
    surface of ``gmetric``/``gmond`` metric descriptors — and hands each
    to ``send``.  Names are dotted: ``prefix.short_name.label_values``,
    with ``name_map`` renaming the wire-format metric names to the short
    operator-facing ones (the lustre gmond module idiom)."""

    #: registry name -> (short name, units); everything else passes
    #: through with its units guessed from the name suffix
    name_map = {
        "lcap_proxy_ingested_total": ("ingested", "records"),
        "lcap_proxy_dispatched_total": ("dispatched", "records"),
        "lcap_proxy_filtered_out_total": ("filtered", "records"),
        "lcap_proxy_redelivered_total": ("redelivered", "records"),
        "lcap_proxy_ephemeral_drops_total": ("eph_drops", "records"),
        "lcap_buffered_records": ("buffered", "records"),
        "lcap_consumer_outbox_depth": ("outbox", "records"),
        "lcap_consumer_in_flight": ("in_flight", "records"),
        "lcap_ack_watermark": ("ack_wm", "index"),
        "lcap_ack_in_flight": ("unacked", "records"),
        "lcap_ack_delivered_records_total": ("delivered", "records"),
        "lcap_ack_acked_records_total": ("acked", "records"),
        "lcap_ingest_watermark": ("ingest_wm", "index"),
        "lcap_cluster_routed_total": ("routed", "records"),
        "lcap_cluster_failover_redelivered_total": ("refed", "records"),
        "lcap_shard_alive": ("alive", "boolean"),
        "lcap_shard_slots_owned": ("slots", "slots"),
        "lcap_agg_records_total": ("agg_records", "records"),
        "lcap_agg_late_dropped_total": ("agg_late", "records"),
        "lcap_pump_latency_seconds": ("pump_latency", "seconds"),
        "lcap_window_records": ("win_records", "records"),
        "lcap_window_value_sum": ("win_value", "units"),
        "lcap_transport_bytes_total": ("net_bytes", "bytes"),
        "lcap_transport_messages_total": ("net_msgs", "frames"),
    }

    def __init__(self, registry=None, snapshot_fn: Optional[
            Callable[[], Dict[str, dict]]] = None,
            send: Optional[Callable[[dict], None]] = None,
            prefix: str = "lcap", group: str = "lustre_activity"):
        if (registry is None) == (snapshot_fn is None):
            raise ValueError("pass exactly one of registry / snapshot_fn")
        self._snapshot = snapshot_fn or registry.snapshot
        self.prefix = prefix
        self.group = group
        self.sent: List[dict] = []
        self._send = send or self.sent.append

    def _name(self, name: str, labels: Dict[str, str]) -> str:
        short = self.name_map.get(name, (name, None))[0]
        parts = [self.prefix, short]
        parts.extend(str(labels[k]) for k in sorted(labels) if labels[k])
        return ".".join(p.replace(".", "_").replace(" ", "_")
                        for p in parts if p)

    def _units(self, name: str, kind: str) -> str:
        mapped = self.name_map.get(name)
        if mapped and mapped[1]:
            return mapped[1]
        if name.endswith("_seconds"):
            return "seconds"
        if name.endswith("_bytes_total") or name.endswith("_bytes"):
            return "bytes"
        return "count" if kind == "counter" else "value"

    def push(self) -> int:
        """Flatten and send one snapshot; returns metrics pushed.
        Histograms ship their ``_count`` and ``_sum`` (gmond has no
        histogram type)."""
        n = 0
        for name, ent in sorted(self._snapshot().items()):
            kind = ent.get("type", "gauge")
            for labels, value in ent.get("samples", []):
                base = self._name(name, labels)
                if kind == "histogram":
                    emit = [(base + ".count", value["count"], "count"),
                            (base + ".sum", value["sum"],
                             self._units(name, kind))]
                else:
                    emit = [(base, value, self._units(name, kind))]
                for mname, mval, units in emit:
                    self._send({"name": mname, "value": mval,
                                "type": "counter" if kind == "counter"
                                else "gauge",
                                "units": units, "group": self.group})
                    n += 1
        return n
