"""Policy engine on the changelog fabric (Robinhood + HSM action
stream analogue): namespace mirror (ground truth), declarative rules
emitting an action lifecycle stream, and the reconciler that audits
the invariant between them."""

from .engine import (FAILED, STARTED, SUCCEED, WAITING, Action,
                     PolicyEngine, PolicyRule)
from .mirror import MIRROR_TYPES, MirrorEntry, NamespaceMirror
from .reconciler import (ActionState, ReconcileReport, reconcile,
                         replay_action_state)

__all__ = ["NamespaceMirror", "MirrorEntry", "MIRROR_TYPES",
           "PolicyRule", "PolicyEngine", "Action",
           "WAITING", "STARTED", "SUCCEED", "FAILED",
           "reconcile", "replay_action_state", "ReconcileReport",
           "ActionState"]
