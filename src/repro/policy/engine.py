"""Declarative policy rules + the action lifecycle stream.

The Robinhood half: ``PolicyRule``s are evaluated *incrementally*
against the ``NamespaceMirror`` (only targets the stream dirtied since
the last evaluation), and a match emits an **action record** — a
first-class changelog record (``CL_ACTION_*``, records.py) with the
lifecycle the ``lustre-hsm-action-stream`` toolkit ships for HSM
coordinators:

    NEW -> UPDATE(started) -> COMPLETED(succeeded|failed) -> PURGED

Action records are written to the engine's own journal (an ``Llog``
under producer id ``actions``) and that journal is registered with the
proxy — or with the cluster coordinator, which push-feeds each shard's
``PushSource`` and routes by target FID, so one action's whole chain
lands on one shard and never splits.  Because the journal is the
durable source (reader watermarks persist on the journal, not in the
proxy), a proxy restart re-attaches at its own acked watermark:
acknowledged actions are never re-ingested, unacknowledged ones are —
the same exactly-once-through-restart contract the changelog itself
has.  With a raw (uncompacted) history store attached, the full action
stream stays replayable forever — which is what the reconciler audits.

The **janitor** (``janitor_sweep``) is the stream's garbage collector:
it PURGEs completed action chains (dropping them from every stream-
derived state) and reaps zombies — live actions whose target has
disappeared from the mirror.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from ..core import records as R
from ..core.history import HistoryStore
from ..core.llog import Llog
from .mirror import Key, MirrorEntry, NamespaceMirror

#: action statuses (the HSM coordinator vocabulary)
WAITING = "WAITING"
STARTED = "STARTED"
SUCCEED = "SUCCEED"
FAILED = "FAILED"

_TERMINAL = frozenset({SUCCEED, FAILED})


@dataclass(frozen=True)
class PolicyRule:
    """Declarative match against mirror entries.

    name          rule identity (stamped into every action record)
    action        what to do with a match ("archive", "purge", ...)
    types         op-type mask: the *last* operation that touched the
                  entry must be in this set (None = any)
    flags_all     CLF_* bits the last writer's record must have carried
                  (attr_shard => CLF_SHARD, attr_jobid => CLF_JOBID,
                  attr_metrics => CLF_METRICS)
    min_age_s     entry age (stream clock - creation time) threshold
    min_idle_s    idle time (stream clock - last touch) threshold
    metrics_min   last writer's metrics[0] lower bound
    metrics_max   last writer's metrics[0] upper bound
    predicate     arbitrary extra check fn(key, entry, clock_ns) -> bool
    """

    name: str
    action: str = "archive"
    types: Optional[frozenset] = None
    flags_all: int = 0
    min_age_s: Optional[float] = None
    min_idle_s: Optional[float] = None
    metrics_min: Optional[float] = None
    metrics_max: Optional[float] = None
    predicate: Optional[Callable[[Key, MirrorEntry, int], bool]] = \
        field(default=None, compare=False)

    def __post_init__(self):
        if self.types is not None and not isinstance(self.types, frozenset):
            object.__setattr__(self, "types", frozenset(self.types))

    def static_ok(self, key: Key, entry: MirrorEntry,
                  clock_ns: int) -> bool:
        """Every condition except the time gates."""
        if self.types is not None and entry.last_type not in self.types:
            return False
        if self.flags_all:
            have = 0
            if entry.attr_shard is not None:
                have |= R.CLF_SHARD
            if entry.attr_jobid:
                have |= R.CLF_JOBID
            if entry.attr_metrics is not None:
                have |= R.CLF_METRICS
            if (have & self.flags_all) != self.flags_all:
                return False
        if self.metrics_min is not None or self.metrics_max is not None:
            m = entry.attr_metrics
            v = m[0] if m else None
            if v is None:
                return False
            if self.metrics_min is not None and v < self.metrics_min:
                return False
            if self.metrics_max is not None and v > self.metrics_max:
                return False
        if self.predicate is not None and \
                not self.predicate(key, entry, clock_ns):
            return False
        return True

    def ready_at(self, entry: MirrorEntry) -> int:
        """Stream time (ns) at which the time gates open for ``entry``
        — 0 when the rule carries none.  Lets the engine re-examine a
        quiescent entry once it ages in, without new activity on it."""
        at = 0
        if self.min_age_s is not None:
            at = max(at, entry.ctime + int(self.min_age_s * 1e9))
        if self.min_idle_s is not None:
            at = max(at, entry.mtime + int(self.min_idle_s * 1e9))
        return at

    def matches(self, key: Key, entry: MirrorEntry, clock_ns: int) -> bool:
        return (self.static_ok(key, entry, clock_ns)
                and self.ready_at(entry) <= clock_ns)


class Action:
    """One live action: the engine-side ground truth of its lifecycle."""

    __slots__ = ("cookie", "key", "rule", "kind", "status")

    def __init__(self, cookie: int, key: Key, rule: str, kind: str):
        self.cookie = cookie
        self.key = key
        self.rule = rule
        self.kind = kind
        self.status = WAITING


class PolicyEngine:
    """Evaluates rules against a mirror; owns the action stream.

    ``target`` is the proxy or cluster the action journal registers
    with (both expose ``add_producer``); pass ``target=None`` to defer
    and call ``attach(proxy_or_cluster)`` later — and call ``attach``
    again after a proxy restart to re-register the journal with the
    new incarnation (it resumes at its own acked watermark).
    """

    def __init__(self, mirror: NamespaceMirror, rules: Iterable[PolicyRule],
                 target=None, producer: str = "actions",
                 path: Optional[str] = None, run_id: int = 1):
        self.mirror = mirror
        self.rules = list(rules)
        names = [r.name for r in self.rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate rule names: {names}")
        self.producer = producer
        self.run_id = run_id
        # raw retained history: the action stream must stay fully
        # replayable after trim — the reconciler's audit depends on it
        self.log = Llog(producer, path=path,
                        history=HistoryStore(path + ".hist" if path else None,
                                             compactor=None))
        # arm logging before any target attaches (an unarmed Llog
        # silently drops records); this reader never acks, so records
        # emitted while detached are retained until a real target's
        # reader takes over the trim gate in attach()
        self._arm_rid = self.log.register_reader("engine-arm",
                                                 resume=True)
        self._cookie_seq = itertools.count(1)
        self.actions: Dict[int, Action] = {}          # live, by cookie
        self._live_by_target: Dict[Tuple[Key, str], int] = {}
        #: (target, rule name) -> stream time at which its time gates
        #: open — quiescent entries are re-examined when they age in
        self._waiting: Dict[Tuple[Key, str], int] = {}
        self.stats = {"evaluated": 0, "emitted": 0, "completed": 0,
                      "purged": 0, "zombies_reaped": 0, "recovered": 0}
        self._recover()
        if target is not None:
            self.attach(target)

    def _recover(self) -> None:
        """Rebuild the live-action table (and the cookie sequence) from
        the journal + its raw history: a restarted engine over a
        persistent ``path`` continues the previous incarnation's
        lifecycle instead of reusing its cookies or forgetting its
        live chains."""
        from ..core.history import JournalReplayReader
        reader = JournalReplayReader(self.log)
        pos, last = reader.available_lo(), self.log.last_index
        hi_cookie = 0
        while pos <= last:
            batch, pos = reader.read(pos, 1024)
            # columnar replay: types/keys off the header columns, and
            # only the xattr blobs themselves decoded — never a full
            # per-record unpack
            types = batch.types_np().tolist()
            keys = batch.keys()
            for i, x in enumerate(batch.xattrs_col()):
                cookie = (x or {}).get("cookie")
                if cookie is None:
                    continue
                hi_cookie = max(hi_cookie, cookie)
                if types[i] == R.CL_ACTION_PURGED:
                    act = self.actions.pop(cookie, None)
                    if act is not None:
                        self._live_by_target.pop((act.key, act.rule), None)
                else:
                    act = self.actions.get(cookie)
                    if act is None:
                        act = Action(cookie, keys[i], x.get("rule", ""),
                                     x.get("action", ""))
                        self.actions[cookie] = act
                        self._live_by_target[(act.key, act.rule)] = cookie
                    act.status = x.get("status", act.status)
        if hi_cookie:
            self._cookie_seq = itertools.count(hi_cookie + 1)
            self.stats["recovered"] = len(self.actions)

    # -- wiring ----------------------------------------------------------------
    def attach(self, target) -> None:
        """Register the action journal with a proxy or cluster
        coordinator (idempotent across restarts: the journal's reader
        watermark survives, so a restarted target resumes exactly at
        its own acked position).  Records emitted before the first
        attach are part of the new reader's backlog — nothing emitted
        while detached is lost."""
        target.add_producer(self.producer, self.log)
        if self._arm_rid is not None:
            # the target's reader now gates the trim; the arming
            # reader must stop holding retention back
            self.log.deregister_reader(self._arm_rid)
            self._arm_rid = None

    # -- lifecycle emission ----------------------------------------------------
    def _emit(self, rtype: int, act: Action, status: str) -> Optional[int]:
        act.status = status
        return self.log.log(R.ChangelogRecord(
            type=rtype, tfid=R.Fid(*act.key),
            pfid=R.Fid(self.run_id, 0, 0), name=act.kind.encode(),
            time=self.mirror.clock,      # stream time (0 -> journal stamps)
            xattr={"cookie": act.cookie, "rule": act.rule,
                   "action": act.kind, "status": status}))

    def evaluate(self) -> List[Action]:
        """One incremental pass: match the rules against every target
        the stream dirtied since the last pass — plus every queued
        (target, rule) whose time gate has opened since (an age-out
        rule must fire on a file nobody touches again) — emit NEW
        actions, and reap zombies (live actions whose target
        disappeared).  Returns the newly emitted actions."""
        dirty = self.mirror.drain_dirty()
        clock = self.mirror.clock
        entries = self.mirror.entries
        by_name = {r.name: r for r in self.rules}
        # (key, rule) pairs to examine: dirtied targets against every
        # rule; aged-in waiters against theirs.  Dirty recomputation
        # supersedes a stale waiting slot.
        pairs: List[Tuple[Key, PolicyRule]] = []
        for key in dirty:
            if entries.get(key) is None:
                self._reap_target(key)
                continue
            for rule in self.rules:
                self._waiting.pop((key, rule.name), None)
                pairs.append((key, rule))
        for (key, rname), at in list(self._waiting.items()):
            if at <= clock:
                del self._waiting[(key, rname)]
                rule = by_name.get(rname)
                if rule is not None:
                    pairs.append((key, rule))
        out: List[Action] = []
        for key, rule in pairs:
            entry = entries.get(key)
            if entry is None:
                continue                # vanished since queueing
            self.stats["evaluated"] += 1
            if (key, rule.name) in self._live_by_target:
                continue                # one live action per (target, rule)
            if not rule.static_ok(key, entry, clock):
                continue
            at = rule.ready_at(entry)
            if at > clock:
                self._waiting[(key, rule.name)] = at   # age in later
                continue
            act = Action(next(self._cookie_seq), key, rule.name,
                         rule.action)
            self.actions[act.cookie] = act
            self._live_by_target[(key, rule.name)] = act.cookie
            self._emit(R.CL_ACTION_NEW, act, WAITING)
            self.stats["emitted"] += 1
            out.append(act)
        return out

    def _reap_target(self, key: Key) -> None:
        """Target gone: purge its live actions (the related repo's
        janitor calls these zombies) and forget its age-in waiters."""
        for (k, rule), cookie in list(self._live_by_target.items()):
            if k == key:
                self.purge(cookie)
                self.stats["zombies_reaped"] += 1
        for k_rule in [kr for kr in self._waiting if kr[0] == key]:
            del self._waiting[k_rule]

    def start(self, cookie: int) -> None:
        act = self.actions[cookie]
        self._emit(R.CL_ACTION_UPDATE, act, STARTED)

    def complete(self, cookie: int, ok: bool = True) -> None:
        act = self.actions[cookie]
        self._emit(R.CL_ACTION_COMPLETED, act, SUCCEED if ok else FAILED)
        self.stats["completed"] += 1

    def purge(self, cookie: int) -> None:
        act = self.actions.pop(cookie, None)
        if act is None:
            return
        self._live_by_target.pop((act.key, act.rule), None)
        self._emit(R.CL_ACTION_PURGED, act, act.status)
        self.stats["purged"] += 1

    def janitor_sweep(self) -> int:
        """Purge every action in a terminal state, closing its chain
        (the stream-side state drops it; the journal's collective ack
        can then trim it).  Returns chains purged."""
        done = [c for c, a in self.actions.items() if a.status in _TERMINAL]
        for cookie in done:
            self.purge(cookie)
        return len(done)

    # -- ground truth ----------------------------------------------------------
    def live_state(self) -> Dict[int, Tuple[Key, str, str]]:
        """cookie -> (target, rule, status) for every unpurged action —
        the 'hsm/actions file' the reconciler diffs the stream
        against."""
        return {c: (a.key, a.rule, a.status)
                for c, a in self.actions.items()}

    def run_pending(self, executor: Optional[Callable[[Action], bool]] = None,
                    ) -> int:
        """Drive WAITING actions through start -> complete, using
        ``executor`` (returns success) or succeeding by default — the
        in-process stand-in for an HSM copytool fleet."""
        n = 0
        for act in list(self.actions.values()):
            if act.status != WAITING:
                continue
            self.start(act.cookie)
            ok = True if executor is None else bool(executor(act))
            self.complete(act.cookie, ok=ok)
            n += 1
        return n
