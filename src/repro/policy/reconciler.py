"""Action-stream reconciler — the integrity check between derived and
ground-truth state (the related repo's ``hsm-stream-reconciler``).

Two maps are built and diffed:

1. **Stream-derived state**: a full replay of the action stream (an
   *ephemeral* ``Subscription(replay=True)`` with the ``CL_ACTION_*``
   op-type mask pushed down, so no other record is ever copied), folded
   with the lifecycle reducer: NEW/UPDATE/COMPLETED set the cookie's
   status, PURGED drops it.  The ephemeral mode matters: an audit scan
   must never block the journal trim or join a delivery group.
2. **Ground truth**: the engine's live action table
   (``PolicyEngine.live_state()``) — the analogue of scanning the MDTs'
   ``hsm/actions`` files.

The report lists cookies **missing** from the stream (ground truth has
them, the stream does not — lost records), **extra** in the stream
(stream says live, truth says gone — a lost PURGED), and
**mismatched** status.  A healthy deployment reconciles to zero of
each, through proxy restarts and single-shard failovers — that is the
acceptance invariant of the whole policy subsystem.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from ..core import records as R
from ..core.session import Subscription, connect
from .engine import PolicyEngine

#: cookie -> (target key, rule, status)
ActionState = Dict[int, Tuple[Tuple[int, int, int], str, str]]


def replay_action_state(target, producer: str = "actions",
                        rounds: int = 10000) -> ActionState:
    """Rebuild the live-action map from a full replay of the action
    stream against ``target`` (a proxy, service, cluster, or address)."""
    session = connect(target)
    stream = session.subscribe(Subscription(
        mode="ephemeral", replay=True, types=R.CL_ACTION_TYPES,
        max_records=4096))
    state: ActionState = {}
    try:
        for _ in range(rounds):
            pairs = stream.fetch(8192)
            for pid, batch in pairs:
                if pid != producer:
                    continue
                for i in range(len(batch)):
                    rec = batch.record(i)
                    x = rec.xattr or {}
                    cookie = x.get("cookie")
                    if cookie is None:
                        continue
                    if rec.type == R.CL_ACTION_PURGED:
                        state.pop(cookie, None)
                    else:
                        state[cookie] = (rec.key(), x.get("rule", ""),
                                         x.get("status", ""))
            if not pairs and not stream.replaying:
                return state
        raise RuntimeError("action replay did not drain")
    finally:
        session.close()


@dataclass
class ReconcileReport:
    missing: List[int] = field(default_factory=list)     # truth only
    extra: List[int] = field(default_factory=list)       # stream only
    mismatched: List[Tuple[int, str, str]] = field(default_factory=list)
    truth_live: int = 0
    stream_live: int = 0

    @property
    def ok(self) -> bool:
        return not (self.missing or self.extra or self.mismatched)

    def __str__(self) -> str:
        if self.ok:
            return (f"reconciled: {self.truth_live} live actions, "
                    f"zero discrepancies")
        return (f"DISCREPANCIES: {len(self.missing)} missing from stream, "
                f"{len(self.extra)} extra in stream, "
                f"{len(self.mismatched)} status mismatches "
                f"({self.truth_live} truth / {self.stream_live} stream)")


def reconcile(engine: PolicyEngine, target=None,
              derived: ActionState = None) -> ReconcileReport:
    """Diff the engine's ground truth against the stream-derived state
    (replayed from ``target``, or passed pre-built via ``derived``)."""
    if derived is None:
        derived = replay_action_state(target, engine.producer)
    truth = engine.live_state()
    report = ReconcileReport(truth_live=len(truth),
                             stream_live=len(derived))
    for cookie in sorted(truth.keys() - derived.keys()):
        report.missing.append(cookie)
    for cookie in sorted(derived.keys() - truth.keys()):
        report.extra.append(cookie)
    for cookie in sorted(truth.keys() & derived.keys()):
        t_status, d_status = truth[cookie][2], derived[cookie][2]
        if t_status != d_status:
            report.mismatched.append((cookie, t_status, d_status))
    return report
