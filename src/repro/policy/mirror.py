"""Stateful namespace mirror — the policy engine's ground truth.

A Robinhood policy engine replays namespace activity into a database
and decides archive/purge actions against that state (PAPERS.md).  The
``NamespaceMirror`` is that database, kept directly on the changelog
fabric:

- it **bootstraps** from the compacted history tier
  (``Subscription(replay=True)``) and then applies the live stream —
  a fresh mirror reconstructs the same per-FID state as a mirror that
  consumed the stream from the beginning, because its reducer commutes
  with the ``Compactor``'s folding rules (history.py):

  * CREATE/MKDIR/MKNOD/SOFTLINK insert an entry (annihilation only
    drops lifetimes whose UNLINK the mirror would apply anyway);
  * HARDLINK adds a name (``nlink`` += 1) — hardlinked lifetimes are
    never annihilated, so the mirror sees every link/unlink;
  * UNLINK/RMDIR remove one name, and the entry once the last name is
    gone;
  * RENAME rewrites name/parent (rename-chain folding keeps exactly
    the final name the mirror would have ended at);
  * SETATTR records the last writer (last-writer-wins thinning keeps
    exactly that record).

- it is **redelivery-safe**: per-target delivery order is guaranteed
  (single proxy, and FID-hash routing in a cluster), so a per-(producer,
  target) index high-watermark makes applying at-least-once redelivery
  (proxy restart, shard failover) exactly-once on the state.

Entries carry what policy rules match on: name, parent, link count,
creation/modification stream time, and the last writer's
shard/jobid/metrics.  ``clock`` is the newest record timestamp seen —
rules measure ages against stream time, never wall time, so a mirror
replaying history does not see every file as ancient.
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional, Set, Tuple

from ..core import records as R
from ..core.history import CREATES, DESTROYS
from ..track.consumers import _GroupWorker

Key = Tuple[int, int, int]

#: the op types a namespace mirror consumes (pushed down to dispatch)
MIRROR_TYPES = frozenset(CREATES | DESTROYS
                         | {R.CL_HARDLINK, R.CL_RENAME, R.CL_SETATTR})


class MirrorEntry:
    """Per-FID ground truth: one live namespace object."""

    __slots__ = ("name", "parent", "nlink", "ctime", "mtime", "last_type",
                 "attr_time", "attr_shard", "attr_jobid", "attr_metrics")

    def __init__(self, name: bytes, parent: Key, ctime: int):
        self.name = name
        self.parent = parent
        self.nlink = 1
        self.ctime = ctime          # stream time (cr_time ns) of creation
        self.mtime = ctime          # stream time of the last touch
        self.last_type = R.CL_CREATE
        self.attr_time: int = 0     # last SETATTR stream time
        self.attr_shard = None      # last writer's (pod, host, row, col)
        self.attr_jobid: bytes = b""
        self.attr_metrics = None

    def age_ns(self, clock: int) -> int:
        return max(0, clock - self.ctime)

    def idle_ns(self, clock: int) -> int:
        return max(0, clock - self.mtime)

    def snapshot(self) -> dict:
        """Comparable view (tests: live mirror == bootstrapped mirror)."""
        return {"name": self.name, "parent": self.parent,
                "nlink": self.nlink, "attr_time": self.attr_time,
                "attr_shard": self.attr_shard,
                "attr_jobid": self.attr_jobid,
                "attr_metrics": self.attr_metrics}


class NamespaceMirror(_GroupWorker):
    """A consumer group member holding the namespace state.

    ``replay=True`` (default) bootstraps from history; pass
    ``replay=None`` for a mirror that only tracks from now on.  Drive
    it with ``poll()`` (or ``bootstrap()`` to drain the whole history
    phase); ``entries`` maps target FID -> ``MirrorEntry``.
    """

    def __init__(self, proxy, group: str = "mirror",
                 name: Optional[str] = None, replay=True,
                 types: Optional[Iterable[int]] = None):
        super().__init__(proxy, group, types=types or MIRROR_TYPES,
                         name=name, replay=replay)
        self.entries: Dict[Key, MirrorEntry] = {}
        self.clock = 0                      # newest cr_time seen (ns)
        #: (producer, target) -> highest applied journal index; per-target
        #: order is guaranteed end to end, so this makes at-least-once
        #: redelivery exactly-once on the state
        self._applied: Dict[Tuple[str, Key], int] = {}
        #: targets touched since the policy engine last drained them
        self.dirty: Set[Key] = set()
        self.stats = {"applied": 0, "deduped": 0}

    # -- state ----------------------------------------------------------------
    def snapshot(self) -> Dict[Key, dict]:
        return {k: e.snapshot() for k, e in self.entries.items()}

    def drain_dirty(self) -> Set[Key]:
        """Targets changed since the last drain (incremental rule
        evaluation); includes targets that were removed."""
        dirty, self.dirty = self.dirty, set()
        return dirty

    # -- reduction -------------------------------------------------------------
    def handle_batch(self, pid: str, batch: R.RecordBatch) -> None:
        applied = self._applied
        for i in range(len(batch)):
            rec = batch.record(i)
            key = rec.key()
            mark = (pid, key)
            if rec.index <= applied.get(mark, 0):
                self.stats["deduped"] += 1   # failover/restart redelivery
                continue
            applied[mark] = rec.index
            self._apply(rec, key)
            self.stats["applied"] += 1

    def _apply(self, rec: R.ChangelogRecord, key: Key) -> None:
        if rec.time > self.clock:
            self.clock = rec.time
        t = rec.type
        e = self.entries.get(key)
        if t in CREATES:
            e = MirrorEntry(rec.name,
                            (rec.pfid.seq, rec.pfid.oid, rec.pfid.ver),
                            rec.time)
            e.last_type = t
            self.entries[key] = e
        elif t == R.CL_HARDLINK:
            if e is None:
                # link to an object that predates the stream: the
                # lifetime is still hardlinked, so materialize it
                e = MirrorEntry(rec.name,
                                (rec.pfid.seq, rec.pfid.oid, rec.pfid.ver),
                                rec.time)
                self.entries[key] = e
            e.nlink += 1
            e.mtime = rec.time
            e.last_type = t
        elif t in DESTROYS:
            if e is not None:
                if e.nlink > 1:
                    e.nlink -= 1
                    e.mtime = rec.time
                    e.last_type = t
                else:
                    del self.entries[key]
        elif t == R.CL_RENAME:
            if e is not None:
                e.name = rec.name
                e.parent = (rec.pfid.seq, rec.pfid.oid, rec.pfid.ver)
                e.mtime = rec.time
                e.last_type = t
        elif t == R.CL_SETATTR:
            if e is not None:
                e.attr_time = rec.time
                # local remap zero-fills extensions the producer did not
                # send (§IV-A), so an all-zero value means "absent" —
                # the only presence signal that survives the remap
                e.attr_shard = rec.shard if (rec.shard and
                                             any(rec.shard)) else None
                e.attr_jobid = rec.jobid or b""
                e.attr_metrics = rec.metrics or None
                e.mtime = rec.time
                e.last_type = t
        else:
            return
        self.dirty.add(key)

    def compact_applied(self, trim_points: Dict[str, int]) -> int:
        """Bound the dedup map: drop per-target watermarks below a
        journal's trim point (``{pid: Llog.first_index}``).  Safe
        because every redelivery path — proxy restart, cluster shard
        failover — re-reads from the journal, which no longer holds
        records below its trim point, so those indices can never
        arrive again.  Refused mid-bootstrap: a failover-rewound
        history replay may still revisit old indices.  Returns the
        number of watermarks dropped."""
        if self.bootstrapping:
            return 0
        before = len(self._applied)
        self._applied = {mark: idx for mark, idx in self._applied.items()
                         if idx >= trim_points.get(mark[0], 0)}
        return before - len(self._applied)

    # -- driving ---------------------------------------------------------------
    def bootstrap(self, rounds: int = 10000,
                  max_records: int = 4096) -> int:
        """Drain the whole history phase (and whatever live records are
        already queued); returns records applied."""
        n = 0
        for _ in range(rounds):
            moved = self.poll(max_records)
            n += moved
            if not moved and not self.bootstrapping:
                return n
        raise RuntimeError("mirror bootstrap did not drain")
