"""qwen2.5-14b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=256)
