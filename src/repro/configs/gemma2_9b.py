"""gemma2-9b [dense] — local+global alternating attention, logit softcap
[arXiv:2408.00118]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    d_ff=14336, vocab_size=256000,
    local_global_period=2, sliding_window=4096,
    attn_softcap=50.0, logit_softcap=30.0,
    tie_embeddings=True, embed_scale=True, act="gelu",
)

SMOKE = CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=256, sliding_window=8)
