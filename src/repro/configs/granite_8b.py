"""granite-8b [dense] — llama-arch, code [arXiv:2405.04324]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=49152,
    rope_theta=1e4,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=256)
