"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab_size=151936,
    n_experts=128, top_k=8, moe_d_ff=768,
    rope_theta=1e6,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=32, moe_d_ff=32, vocab_size=256,
                       n_experts=8, top_k=2, capacity_factor=8.0)
