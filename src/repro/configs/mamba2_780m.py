"""mamba2-780m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    ssm_head_dim=64, ssm_groups=1,
    tie_embeddings=True, use_rope=False,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, vocab_size=256,
                       ssm_state=16, ssm_head_dim=32, ssm_chunk=8)
