"""starcoder2-3b [dense] — GQA, RoPE [arXiv:2402.19173]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab_size=49152,
    rope_theta=1e5,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=256)
