"""Architecture registry: one module per assigned architecture.

``get_config(arch)`` returns the full (paper-exact) config;
``get_smoke(arch)`` a reduced same-family config for CPU tests.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import SHAPES, ModelConfig, ShapeConfig, shape_applicable

_MODULES: Dict[str, str] = {
    "starcoder2-3b": "starcoder2_3b",
    "gemma2-9b": "gemma2_9b",
    "granite-8b": "granite_8b",
    "qwen2.5-14b": "qwen25_14b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "jamba-v0.1-52b": "jamba_52b",
    "pixtral-12b": "pixtral_12b",
    "whisper-small": "whisper_small",
    "mamba2-780m": "mamba2_780m",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch]}", __package__)


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


__all__ = ["get_config", "get_smoke", "list_archs", "SHAPES",
           "ModelConfig", "ShapeConfig", "shape_applicable"]
