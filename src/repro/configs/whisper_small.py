"""whisper-small [audio] — enc-dec, conv frontend (STUB)
[arXiv:2212.04356].

The conv/mel frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, n_frames, d_model) consumed
by the encoder; shapes' seq_len applies to the decoder.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    is_encoder_decoder=True, n_encoder_layers=12, n_frames=1500,
    use_rope=False, sinusoidal_pos=True, act="gelu",
)

SMOKE = CONFIG.replace(n_layers=2, n_encoder_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                       n_frames=16)
