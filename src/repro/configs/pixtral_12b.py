"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409].

The modality frontend is a STUB per the assignment: ``input_specs``
provides precomputed patch embeddings (B, n_image_patches, d_model)
which replace the first n_image_patches token positions.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=131072,
    n_image_patches=256, rope_theta=1e9,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=256, n_image_patches=4)
