"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 every other layer [arXiv:2403.19887].

Layer pattern period 8: attention at index 4, SSD (Mamba) elsewhere;
MoE replaces the MLP on odd layer indices.  Jamba's Mamba-1 recurrence
is instantiated with the SSD block (d_state=16) — DESIGN.md
§Hardware-adaptation.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    n_experts=16, top_k=2, moe_d_ff=14336, moe_period=2,
    hybrid_period=8, hybrid_attn_index=4,
    ssm_state=16, ssm_expand=2, ssm_conv=4, ssm_chunk=256, ssm_head_dim=64,
    use_rope=False,               # jamba uses no positional encoding
)

SMOKE = CONFIG.replace(n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, moe_d_ff=128, vocab_size=256,
                       n_experts=4, top_k=2, capacity_factor=8.0, ssm_state=8, ssm_head_dim=32,
                       ssm_chunk=8)
