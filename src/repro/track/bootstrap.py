"""Fast object-index traversal (paper §IV-C-2).

Instead of a POSIX-style scan to populate a fresh policy/metrics
database, synthesize "a special changelog stream, filled with entries
from the MDT object index, and consumed by instances of the policy
engine".  Here the object index is the framework's checkpoint/object
manifest; the synthetic stream is consumed through ordinary Session
subscriptions by load-balanced MetricsDB instances exactly like live
records — no separate scan path:

    proxy = LcapProxy({"index0": synthesize_index_stream(index)})
    workers = [MetricsDB(proxy, db_path) for _ in range(4)]
"""

from __future__ import annotations

from typing import Dict, Iterable, Tuple

from ..core import records as R
from ..core.llog import Llog


def synthesize_index_stream(index: Iterable[Tuple[int, int, str, int]],
                            run_id: int = 0,
                            producer_id: str = "index0") -> Llog:
    """Build an Llog pre-filled with one CL_MARK record per index entry.

    ``index`` yields (oid, version, name, nbytes).  The returned journal
    is handed to an LcapProxy as an extra producer; a consumer group
    drains it collaboratively (this is what makes the traversal fast —
    it parallelizes like any other changelog stream).
    """
    log = Llog(producer_id)
    log.register_reader("bootstrap-hold")  # arms logging; holds trim
    log.log_batch(R.ChangelogRecord(
        type=R.CL_MARK, tfid=R.Fid(run_id, oid, ver),
        name=name.encode(), metrics=(float(nbytes),),
        xattr={"bootstrap": True}) for oid, ver, name, nbytes in index)
    return log
