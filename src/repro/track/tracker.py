"""ActivityTracker — the per-host changelog producer (the MDT analogue).

One tracker per runtime shard/host.  Every state-modifying operation of
the training run is logged as a changelog record with the LU-1996
extensions: ``jobid`` = run name, ``shard`` = (pod, host, mesh_row,
mesh_col), ``metrics``/``xattr`` as each event type needs.

fid convention (see records.Fid): seq = run id, oid = object id within
the event type's namespace, ver = step / version.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from ..core import records as R
from ..core.llog import Llog


class ActivityTracker:
    def __init__(self, run_id: int, host_id: int, jobid: str = "run",
                 shard: Tuple[int, int, int, int] = (0, 0, 0, 0),
                 path: Optional[str] = None,
                 mask: Optional[Sequence[int]] = None):
        self.run_id = run_id
        self.host_id = host_id
        self.jobid = jobid.encode()[:32]
        self.shard = shard
        self.llog = Llog(f"host{host_id}", path=path, mask=mask)

    def _log(self, rtype: int, oid: int, ver: int = 0, name: bytes = b"",
             pfid: R.Fid = R.NULL_FID, **ext) -> Optional[int]:
        rec = R.ChangelogRecord(
            type=rtype, tfid=R.Fid(self.run_id, oid, ver), pfid=pfid,
            name=name, jobid=self.jobid, shard=self.shard, **ext)
        return self.llog.log(rec)

    # -- training events ----------------------------------------------------
    def step_commit(self, step: int, loss: float, step_time_s: float,
                    tokens: int) -> Optional[int]:
        return self._log(R.CL_STEP_COMMIT, oid=self.host_id, ver=step,
                         name=b"step", metrics=(loss, step_time_s,
                                                float(tokens)))

    def ckpt_write(self, step: int, shard_id: int, nbytes: int,
                   path: str, total_shards: int) -> Optional[int]:
        return self._log(R.CL_CKPT_WRITE, oid=shard_id, ver=step,
                         name=path.encode(),
                         metrics=(float(nbytes),),
                         xattr={"total_shards": total_shards})

    def data_consume(self, step: int, shard_id: int, lo: int, hi: int) -> Optional[int]:
        """Record that sample range [lo, hi) of data shard ``shard_id``
        was consumed — the replay log for exact restart."""
        return self._log(R.CL_DATA_CONSUME, oid=shard_id, ver=step,
                         name=b"range", xattr={"lo": lo, "hi": hi})

    def heartbeat(self, step: int, step_time_s: float) -> Optional[int]:
        return self._log(R.CL_HEARTBEAT, oid=self.host_id, ver=step,
                         metrics=(step_time_s,))

    def elastic(self, joined: bool, n_hosts: int, step: int) -> Optional[int]:
        return self._log(R.CL_ELASTIC_JOIN if joined else R.CL_ELASTIC_LEAVE,
                         oid=self.host_id, ver=step,
                         xattr={"n_hosts": n_hosts})

    def evict(self, object_id: int, version: int, reason: str = "stale") -> Optional[int]:
        """Cache-invalidation notice (Ganesha analogue, paper §IV-C-1)."""
        return self._log(R.CL_EVICT, oid=object_id, ver=version,
                         name=reason.encode())

    # -- filesystem-flavoured events (kept for fidelity/benchmarks) ---------
    def fs_op(self, rtype: int, oid: int, name: bytes,
              parent_oid: int = 0) -> Optional[int]:
        return self._log(rtype, oid=oid, name=name,
                         pfid=R.Fid(self.run_id, parent_oid, 0))
