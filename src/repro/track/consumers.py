"""LCAP consumer groups used by the framework, on the Session API.

Every worker subscribes declaratively (``session.subscribe``) and names
the op types it consumes, so the proxy's server-side pushdown never
copies irrelevant records into its outbox:

- ``MetricsDB`` — the Robinhood analogue: N load-balanced instances of
  one group replicate the record stream into one shared SQLite database
  (paper §III: "multiple instances of robinhood operating on a shared
  database").  Subscribes to everything (it is the audit log).
- ``CheckpointCommitter`` — CKPT_WRITE only; once every shard of a step
  has been seen (across all producers), publishes the checkpoint-commit
  manifest.  Runs as a load-balanced group; members coordinate through
  the shared manifest store.
- ``StragglerDetector`` — HEARTBEAT + STEP_COMMIT; EWMA per host
  against the fleet median flags stragglers.
- ``ElasticController`` — ELASTIC_JOIN/LEAVE; recomputes the device
  plan for the next restart window.
- ``CacheInvalidator`` — the Ganesha analogue (§IV-C-1): ephemeral
  consumer of EVICT records that invalidates a local cache.

Workers may pass ``name=`` to become durable consumers: a crashed
worker that reconnects under the same name resumes at its acknowledged
cursor instead of triggering a group-wide redelivery storm.
"""

from __future__ import annotations

import json
import math
import os
import sqlite3
import threading
from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from ..core import records as R
from ..core.session import Subscription, connect


class _GroupWorker:
    """Base: subscribe a Stream, process batches, commit after each poll
    round (acks "may be delayed and batched", paper §II).

    ``replay`` passes straight through to the ``Subscription``: a worker
    built with ``replay=True`` bootstraps from the compacted history
    tier before its live stream starts (``bootstrapping`` reports the
    phase) — the policy engine's namespace mirror rides on this."""

    def __init__(self, proxy, group: str, flags: Optional[int] = None,
                 types: Optional[Iterable[int]] = None,
                 name: Optional[str] = None, mode: str = "persistent",
                 replay=None, zero_fill: bool = True):
        self.session = connect(proxy)
        self.stream = self.session.subscribe(Subscription(
            group=None if mode == "ephemeral" else group, mode=mode,
            flags=flags, types=types, name=name, auto_commit=False,
            replay=replay, zero_fill=zero_fill))

    @property
    def bootstrapping(self) -> bool:
        """True while the history replay is still streaming."""
        return self.stream.replaying

    def poll(self, max_records: int = 256) -> int:
        n = 0
        batches = self.stream.fetch(max_records)
        done = 0
        try:
            for pid, batch in batches:
                self.handle_batch(pid, batch)
                done += 1
                n += len(batch)
        except Exception:
            # a failed handler must not let a later commit() ack the
            # unprocessed records: requeue them so the next poll
            # retries exactly where this one failed
            self.stream.requeue(batches[done:])
            raise
        self.stream.commit()
        return n

    def handle_batch(self, pid: str, batch: R.RecordBatch) -> None:
        """Default: decode lazily, process record by record.  Workers
        with a batch-shaped sink (e.g. one DB transaction per batch)
        override this — the batch arrives with its header columns
        attached (v2 wire frames ship them), so columnar handlers read
        ``batch.header()`` / the payload gathers with zero per-record
        decode."""
        for i in range(len(batch)):
            self.handle(pid, batch.record(i))

    def handle(self, pid: str, rec: R.ChangelogRecord) -> None:
        raise NotImplementedError

    def close(self, failed: bool = False) -> None:
        self.stream.close(failed=failed)
        self.session.close()


class MetricsDB(_GroupWorker):
    """Replicates the activity stream into a shared SQLite DB."""

    SCHEMA = """
    CREATE TABLE IF NOT EXISTS events (
        producer TEXT, idx INTEGER, type INTEGER, time INTEGER,
        run INTEGER, oid INTEGER, ver INTEGER, name TEXT, jobid TEXT,
        pod INTEGER, host INTEGER, m0 REAL, m1 REAL, m2 REAL,
        PRIMARY KEY (producer, idx) ON CONFLICT REPLACE
    );
    """

    def __init__(self, proxy, db_path: str, group: str = "metrics",
                 name: Optional[str] = None):
        super().__init__(proxy, group, name=name)
        self.db_path = db_path
        self.conn = sqlite3.connect(db_path, timeout=30.0,
                                    check_same_thread=False)
        self.conn.execute("PRAGMA journal_mode=WAL")
        self.conn.execute(self.SCHEMA)
        self.conn.commit()

    @staticmethod
    def _row(pid: str, rec: R.ChangelogRecord) -> tuple:
        m = (list(rec.metrics or []) + [None] * 3)[:3]
        shard = rec.shard or (0, 0, 0, 0)
        return (pid, rec.index, rec.type, rec.time, rec.tfid.seq,
                rec.tfid.oid, rec.tfid.ver, rec.name.decode(errors="replace"),
                (rec.jobid or b"").decode(errors="replace"),
                shard[0], shard[1], m[0], m[1], m[2])

    @staticmethod
    def _rows(pid: str, batch: R.RecordBatch) -> List[tuple]:
        """Column-built rows, value-identical to mapping ``_row`` over
        the decoded records: header columns + the vectorized payload
        gathers, no per-record ``unpack``."""
        h = batch.header()
        names = [nm.decode(errors="replace") for nm in batch.name_col()]
        jraw = batch.jobid_col().tobytes()
        jobs = [jraw[o:o + 32].rstrip(b"\0").decode(errors="replace")
                for o in range(0, len(jraw), 32)]
        pod, host = batch.shard_cols()
        mat, cnt = batch.metrics_cols(3)
        rows = []
        for i, (ix, tp, tm, sq, od, vr, po, ho, c, mv) in enumerate(zip(
                h["index"].tolist(), h["type"].tolist(), h["time"].tolist(),
                h["tseq"].tolist(), h["toid"].tolist(), h["tver"].tolist(),
                pod.tolist(), host.tolist(), cnt.tolist(), mat.tolist())):
            rows.append((pid, ix, tp, tm, sq, od, vr, names[i], jobs[i],
                         po, ho,
                         mv[0] if c > 0 else None,
                         mv[1] if c > 1 else None,
                         mv[2] if c > 2 else None))
        return rows

    def handle_batch(self, pid: str, batch: R.RecordBatch) -> None:
        # one transaction per batch — the whole point of batch flow for
        # a DB-shaped consumer; rows come straight off the columns
        self.conn.executemany(
            "INSERT INTO events VALUES (?,?,?,?,?,?,?,?,?,?,?,?,?,?)",
            self._rows(pid, batch))
        self.conn.commit()

    def handle(self, pid: str, rec: R.ChangelogRecord) -> None:
        self.conn.execute(
            "INSERT INTO events VALUES (?,?,?,?,?,?,?,?,?,?,?,?,?,?)",
            self._row(pid, rec))
        self.conn.commit()

    def query(self, sql: str, args=()) -> List[tuple]:
        return list(self.conn.execute(sql, args))

    def close(self, failed: bool = False) -> None:
        # keep the base signature: a crashed worker is closed with
        # failed=True so its durable cursor parks instead of
        # deregistering (resume picks up exactly at the ack cursor)
        super().close(failed=failed)
        self.conn.close()


class CheckpointCommitter(_GroupWorker):
    """Watches CKPT_WRITE records; commits when all shards of a step are
    present.  The shared manifest dir is the coordination point, so the
    group can be load-balanced (any member may complete a step).

    Coordination is lock-free across processes: each CKPT_WRITE record
    becomes its *own* ``step-S.shard-N.json`` file (atomic tmp+rename,
    idempotent — the content is a pure function of the record), and a
    step commits when the directory holds ``total_shards`` shard files.
    A shared read-modify-write state file would lose updates between
    group members in different processes (a per-instance lock cannot
    order their write-backs); per-shard files cannot collide, and two
    members racing to commit write byte-identical manifests."""

    def __init__(self, proxy, manifest_dir: str, group: str = "ckpt",
                 name: Optional[str] = None):
        super().__init__(proxy, group, types={R.CL_CKPT_WRITE}, name=name)
        self.dir = manifest_dir
        os.makedirs(manifest_dir, exist_ok=True)
        self.committed: Set[int] = set()

    def _shard_path(self, step: int, shard_id: int) -> str:
        return os.path.join(self.dir,
                            f"step-{step:08d}.shard-{shard_id:08d}.json")

    def manifest_path(self, step: int) -> str:
        return os.path.join(self.dir, f"step-{step:08d}.manifest.json")

    def _shard_files(self, step: int) -> List[str]:
        prefix = f"step-{step:08d}.shard-"
        return [os.path.join(self.dir, f) for f in os.listdir(self.dir)
                if f.startswith(prefix) and f.endswith(".json")]

    def handle(self, pid: str, rec: R.ChangelogRecord) -> None:
        if rec.type != R.CL_CKPT_WRITE:
            return
        step = rec.tfid.ver
        shard_id = rec.tfid.oid
        total = (rec.xattr or {}).get("total_shards", 0)
        if step in self.committed or os.path.exists(self.manifest_path(step)):
            return    # redelivered record of a committed step: no litter
        path = self._shard_path(step, shard_id)
        # unique tmp per writer: two processes landing the same shard
        # (redelivery) must not corrupt each other's rename source
        tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "w") as fh:
            json.dump({"shard": shard_id, "total": total,
                       "path": rec.name.decode(), "producer": pid,
                       "bytes": (rec.metrics or (0.0,))[0]}, fh)
        os.replace(tmp, path)
        self._try_commit(step, total)

    def _try_commit(self, step: int, total_hint: int = 0) -> None:
        paths = self._shard_files(step)
        if total_hint and len(paths) < total_hint:
            return      # cannot be complete yet: skip the JSON read pass
        shards: Dict[str, dict] = {}
        total = total_hint
        for path in paths:
            try:
                with open(path) as fh:
                    entry = json.load(fh)
            except (OSError, ValueError):
                continue        # racing writer; the next record retries
            total = max(total, entry.get("total", 0))
            shards[str(entry["shard"])] = {
                "path": entry["path"], "producer": entry["producer"],
                "bytes": entry["bytes"]}
        if total and len(shards) >= total:
            tmp = (self.manifest_path(step)
                   + f".tmp.{os.getpid()}.{threading.get_ident()}")
            with open(tmp, "w") as fh:
                json.dump({"step": step, "complete": True,
                           "shards": shards}, fh)
            os.replace(tmp, self.manifest_path(step))
            self.committed.add(step)
            # the manifest is the durable record; dropping the shard
            # files keeps the directory (and the per-record listdir in
            # _shard_files) bounded by *in-flight* steps only
            for path in paths:
                try:
                    os.remove(path)
                except OSError:
                    pass        # a racing member already cleaned it

    def latest_committed(self) -> Optional[int]:
        steps = [int(f.split("-")[1].split(".")[0])
                 for f in os.listdir(self.dir) if f.endswith(".manifest.json")]
        return max(steps) if steps else None


class StragglerDetector(_GroupWorker):
    """EWMA of per-host step durations; a host whose EWMA exceeds
    ``threshold`` x the fleet median is flagged.

    Hosts that leave the fleet are evicted from the EWMA map: an
    ELASTIC_LEAVE record drops the host immediately, and a host whose
    last sample is more than ``stale_after_s`` (record time) behind the
    newest sample in the stream is aged out.  Without eviction a
    departed straggler's entry skews the fleet median forever and keeps
    ``flagged`` pinned on a host that no longer exists."""

    def __init__(self, proxy, group: str = "health", alpha: float = 0.3,
                 threshold: float = 1.5, stale_after_s: float = 60.0,
                 name: Optional[str] = None):
        super().__init__(proxy, group,
                         types={R.CL_HEARTBEAT, R.CL_STEP_COMMIT,
                                R.CL_ELASTIC_LEAVE}, name=name)
        self.alpha = alpha
        self.threshold = threshold
        self.stale_after_ns = int(stale_after_s * 1e9)
        self.ewma: Dict[int, float] = {}
        self.last_seen: Dict[int, int] = {}    # host -> cr_time (ns)
        self.flagged: Set[int] = set()
        self._clock = 0                        # newest cr_time seen

    def handle(self, pid: str, rec: R.ChangelogRecord) -> None:
        self._clock = max(self._clock, rec.time)
        host = rec.tfid.oid
        if rec.type == R.CL_ELASTIC_LEAVE:
            self._evict(host)
            return
        if rec.type not in (R.CL_HEARTBEAT, R.CL_STEP_COMMIT):
            return
        m = rec.metrics or ()
        if rec.type == R.CL_STEP_COMMIT:
            # step_commit metrics are (loss, step_time_s, tokens); be
            # robust to truncated records instead of crashing the poll
            dt = m[-2] if len(m) >= 2 else (m[0] if m else 0.0)
        else:
            dt = m[0] if m else 0.0
        prev = self.ewma.get(host)
        self.ewma[host] = dt if prev is None else \
            self.alpha * dt + (1 - self.alpha) * prev
        self.last_seen[host] = max(self.last_seen.get(host, 0), rec.time)
        self._evict_stale()
        self._reflag()

    def _evict(self, host: int) -> None:
        self.ewma.pop(host, None)
        self.last_seen.pop(host, None)
        self.flagged.discard(host)
        self._reflag()

    def _evict_stale(self) -> None:
        horizon = self._clock - self.stale_after_ns
        for host in [h for h, t in self.last_seen.items() if t < horizon]:
            self.ewma.pop(host, None)
            self.last_seen.pop(host, None)
            self.flagged.discard(host)

    def _reflag(self) -> None:
        # flagged can only shrink below 2 known hosts: a lone survivor
        # has no fleet to straggle behind
        self.flagged &= set(self.ewma)
        if len(self.ewma) < 2:
            return
        vals = sorted(self.ewma.values())
        median = vals[len(vals) // 2]
        if median <= 0:
            return
        self.flagged = {h for h, v in self.ewma.items()
                        if v > self.threshold * median}


class ElasticController(_GroupWorker):
    """Tracks fleet membership from ELASTIC_JOIN/LEAVE records and
    proposes the largest usable mesh for the next restart window."""

    def __init__(self, proxy, group: str = "elastic",
                 chips_per_host: int = 4, name: Optional[str] = None):
        super().__init__(proxy, group,
                         types={R.CL_ELASTIC_JOIN, R.CL_ELASTIC_LEAVE},
                         name=name)
        self.chips_per_host = chips_per_host
        self.members: Set[int] = set()
        self.generation = 0

    def handle(self, pid: str, rec: R.ChangelogRecord) -> None:
        if rec.type == R.CL_ELASTIC_JOIN:
            self.members.add(rec.tfid.oid)
            self.generation += 1
        elif rec.type == R.CL_ELASTIC_LEAVE:
            self.members.discard(rec.tfid.oid)
            self.generation += 1

    def plan(self) -> Dict[str, int]:
        """Largest power-of-two device count usable as (data x model)."""
        chips = len(self.members) * self.chips_per_host
        usable = 1 << max(0, int(math.log2(chips))) if chips else 0
        data = 1 << (int(math.log2(usable)) // 2) if usable else 0
        return {"chips": chips, "usable": usable,
                "data": data, "model": usable // data if data else 0,
                "generation": self.generation}


class CacheInvalidator(_GroupWorker):
    """Ephemeral consumer invalidating a local cache on EVICT records —
    the Ganesha/pNFS metadata-cache analogue (§IV-C-1).  In the serving
    runtime this is the per-replica KV/page cache."""

    def __init__(self, proxy, cache: Dict[Tuple[int, int], object],
                 mode: str = "ephemeral"):
        # pushdown: only EVICT records ever reach this consumer's outbox
        super().__init__(proxy, "evict", types={R.CL_EVICT}, mode=mode)
        self.cache = cache
        self.invalidated = 0

    def handle_batch(self, pid: str, batch: R.RecordBatch) -> None:
        # type + tfid straight from the decoded header columns — an
        # invalidator never needs the record body.  Delivery goes
        # through the base poll(), whose requeue-on-failure guard keeps
        # a persistent-mode invalidator at-least-once when a handler
        # round dies mid-way.
        rows = np.flatnonzero(batch.types_np() == R.CL_EVICT)
        if not rows.size:
            return
        _, oid, ver = batch.tfid_cols()
        pop = self.cache.pop
        for key in zip(oid[rows].tolist(), ver[rows].tolist()):
            if pop(key, None) is not None:
                self.invalidated += 1
