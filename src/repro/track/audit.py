"""Audit trails over the (tenant-scoped, possibly federated) stream.

The audit use case from the source paper's lineage: changelog records
carry a ``jobid`` naming who caused each operation, so a consumer can
reconstruct *who did what, where, and when* without scanning the
filesystem.  ``AuditTrail`` is that consumer: it subscribes to an
activity plane — a single proxy, a sharded cluster, or a whole
``Federation`` of filesystems — and folds the stream into per-jobid /
per-user trails (operation counts by type, first/last activity, and a
per-origin breakdown when the stream is federated).

Tenancy composes by construction: pass ``tenant=`` and the proxies
enforce the scope server-side (pushdown), so a tenant-scoped audit
trail can only ever contain that tenant's activity — the trail is
trustworthy *because the consumer never saw anything else*, not
because it filtered politely.

Jobids follow the Lustre ``procname_uid`` convention (``"dd.1000"``):
the default user extractor takes the suffix after the last ``"."``.
Pass ``user_of=`` to override.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from ..core import records as R
from ..core.federation import FederatedStream, Federation
from ..core.session import Subscription, connect
from ..core.tenancy import TenantPrincipal


def default_user(jobid: bytes) -> str:
    """Lustre ``procname_uid`` convention: ``b"dd.1000"`` -> ``"1000"``
    (the whole jobid when there is no dot)."""
    _head, sep, tail = jobid.rpartition(b".")
    return (tail if sep else jobid).decode(errors="replace")


@dataclass
class JobTrail:
    """The audit trail of one jobid: who, what, when, where."""

    jobid: str
    user: str
    records: int = 0
    first_ns: Optional[int] = None      # earliest record time seen
    last_ns: Optional[int] = None       # latest record time seen
    by_type: Dict[int, int] = field(default_factory=dict)
    by_origin: Dict[str, int] = field(default_factory=dict)

    def note(self, rtype: int, time_ns: int, origin: Optional[str]) -> None:
        self.records += 1
        self.by_type[rtype] = self.by_type.get(rtype, 0) + 1
        if origin is not None:
            self.by_origin[origin] = self.by_origin.get(origin, 0) + 1
        if self.first_ns is None or time_ns < self.first_ns:
            self.first_ns = time_ns
        if self.last_ns is None or time_ns > self.last_ns:
            self.last_ns = time_ns


class AuditTrail:
    """Folds an activity stream into per-jobid and per-user trails.

    ``target`` is anything ``connect()`` accepts *or* a ``Federation``
    — a federated trail records which filesystem (origin) each jobid
    touched.  Records without a jobid are counted in ``unattributed``
    but never become trails: there is no one to attribute them to (and
    a tenant-scoped stream never contains them at all — unattributed
    activity matches no tenant scope).
    """

    def __init__(self, target, group: str = "audit",
                 name: Optional[str] = None,
                 tenant: Optional[TenantPrincipal] = None,
                 types=None, replay=None,
                 user_of: Callable[[bytes], str] = default_user):
        spec = Subscription(group=group, name=name, types=types,
                            tenant=tenant, auto_commit=False,
                            replay=None if isinstance(replay, dict)
                            else replay)
        if isinstance(target, Federation):
            self.session = None
            self.stream = target.subscribe(spec, replay=replay)
        else:
            if isinstance(replay, dict):
                raise ValueError("per-origin replay dicts need a "
                                 "Federation target")
            self.session = connect(target)
            self.stream = self.session.subscribe(spec)
        self.tenant = tenant
        self.user_of = user_of
        self.trails: Dict[str, JobTrail] = {}
        self.unattributed = 0

    # ---------------------------------------------------------------- intake
    @property
    def bootstrapping(self) -> bool:
        return self.stream.replaying

    def poll(self, max_records: int = 1024) -> int:
        """One fetch/fold/commit round; returns records folded."""
        n = 0
        if isinstance(self.stream, FederatedStream):
            for origin, _pid, batch in self.stream.fetch(max_records):
                n += self._fold(batch, origin)
        else:
            for _pid, batch in self.stream.fetch(max_records):
                n += self._fold(batch, batch.origin)
        self.stream.commit()
        return n

    def _fold(self, batch: R.RecordBatch, origin: Optional[str]) -> int:
        # columnar fold: jobid matrix + header columns, no per-record
        # decode — the audit consumer reads no record bodies at all
        h = batch.header()
        types = h["type"].tolist()
        times = h["time"].tolist()
        jraw = batch.jobid_col().tobytes()
        for i, (tp, tm) in enumerate(zip(types, times)):
            jobid = jraw[i * 32:(i + 1) * 32].rstrip(b"\0")
            if not jobid:
                self.unattributed += 1
                continue
            key = jobid.decode(errors="replace")
            trail = self.trails.get(key)
            if trail is None:
                trail = self.trails[key] = JobTrail(
                    jobid=key, user=self.user_of(jobid))
            trail.note(tp, tm, origin)
        return len(batch)

    # --------------------------------------------------------------- queries
    def trail(self, jobid) -> Optional[JobTrail]:
        if isinstance(jobid, bytes):
            jobid = jobid.decode(errors="replace")
        return self.trails.get(jobid)

    def users(self) -> Dict[str, int]:
        """Per-user record totals across their jobids."""
        out: Dict[str, int] = {}
        for t in self.trails.values():
            out[t.user] = out.get(t.user, 0) + t.records
        return out

    def top(self, n: int = 10) -> List[JobTrail]:
        """The ``n`` most active jobids."""
        return sorted(self.trails.values(),
                      key=lambda t: (-t.records, t.jobid))[:n]

    def report(self) -> Dict:
        """A serializable audit report: per-jobid trails plus user and
        origin rollups."""
        origins: Dict[str, int] = {}
        for t in self.trails.values():
            for o, c in t.by_origin.items():
                origins[o] = origins.get(o, 0) + c
        return {
            "tenant": self.tenant.name if self.tenant else None,
            "jobs": {
                t.jobid: {
                    "user": t.user, "records": t.records,
                    "first_ns": t.first_ns, "last_ns": t.last_ns,
                    "by_type": dict(t.by_type),
                    "by_origin": dict(t.by_origin),
                } for t in self.trails.values()},
            "users": self.users(),
            "origins": origins,
            "unattributed": self.unattributed,
        }

    # -------------------------------------------------------------- lifecycle
    def close(self, failed: bool = False) -> None:
        self.stream.close(failed=failed)
        if self.session is not None:
            self.session.close()


__all__ = ["AuditTrail", "JobTrail", "default_user"]
