"""repro.track — LCAP integrated as the framework's activity backbone.

Producers: every runtime shard owns an ``ActivityTracker`` (an ``Llog``
producer) and emits a changelog record for each state-modifying training
operation.  Consumers are LCAP groups: a load-balanced metrics database
(the Robinhood analogue), the checkpoint committer, the straggler
detector, the elastic controller, and serving-side cache invalidation
(the Ganesha analogue).
"""

from .tracker import ActivityTracker
from .audit import AuditTrail, JobTrail
from .consumers import (CacheInvalidator, CheckpointCommitter, ElasticController,
                        MetricsDB, StragglerDetector)
from .bootstrap import synthesize_index_stream

__all__ = ["ActivityTracker", "MetricsDB", "CheckpointCommitter",
           "StragglerDetector", "ElasticController", "CacheInvalidator",
           "AuditTrail", "JobTrail", "synthesize_index_stream"]
