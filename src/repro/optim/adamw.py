"""AdamW with decoupled weight decay + cosine schedule + global-norm
clipping.  Pure pytree implementation; optimizer state shards exactly
like the parameters (ZeRO — the sharding rules apply to m/v because
they are tree-mapped from the same layout)."""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def abstract_state(abstract_params) -> AdamWState:
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                     abstract_params)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=z, v=z)


def cosine_lr(step, *, peak: float, warmup: int, total: int,
              floor_frac: float = 0.1):
    warm = peak * (step + 1) / max(warmup, 1)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 *
                  (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, max_norm: float = 1.0):
    grads, gnorm = clip_by_global_norm(grads, max_norm)
    step = state.step + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                     state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ +
                     (1 - b2) * jnp.square(g.astype(jnp.float32)),
                     state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) + weight_decay * p
        return (p - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v), gnorm
