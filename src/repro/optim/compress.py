"""Error-feedback int8 gradient compression for DP all-reduce.

The data-parallel gradient all-reduce is the largest recurring
collective at scale.  ``compressed_psum`` quantizes each leaf to int8
with a per-leaf scale, all-reduces the int8 payload (8x less ICI
traffic; the scale is psum'd separately), dequantizes, and keeps the
quantization residual in an error-feedback buffer that is added to the
next step's gradient — the standard EF-SGD construction that preserves
convergence.

Used inside ``shard_map`` over the DP axis (see tests/test_optim.py and
runtime/train_loop.py's ``grad_transport='int8'`` mode).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(g, scale=None):
    if scale is None:
        scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(grads, err, axis_name: str) -> Tuple[Any, Any]:
    """Returns (mean-reduced grads, new error buffers).  ``err`` matches
    ``grads``; pass zeros initially.

    Scheme: pmax-share one scale scalar per leaf (negligible traffic),
    quantize, psum the int8 payload, dequantize; the local quantization
    residual goes into the error-feedback buffer."""
    n = jax.lax.psum(1, axis_name)

    def leaf(g, e):
        g = g.astype(jnp.float32) + e
        gmax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
        scale = gmax / 127.0 + 1e-12
        q, _ = _quantize(g, scale)
        deq_local = q.astype(jnp.float32) * scale
        new_err = g - deq_local                    # residual stays local
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = q_sum.astype(jnp.float32) * scale / n
        return mean, new_err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    means = jax.tree.unflatten(tdef, [o[0] for o in out])
    errs = jax.tree.unflatten(tdef, [o[1] for o in out])
    return means, errs


def plain_psum_mean(grads, axis_name: str):
    n = jax.lax.psum(1, axis_name)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, grads)
