from . import adamw
from .adamw import AdamWState, cosine_lr

__all__ = ["adamw", "AdamWState", "cosine_lr"]
