"""Analytic HBM-traffic model (TPU-fusion semantics).

``cost_analysis()['bytes accessed']`` on the CPU dry-run backend counts
every operand of every op post-CPU-fusion — far more HBM round trips
than a TPU executable performs (XLA:TPU fuses elementwise chains into
single HBM reads/writes, flash attention keeps S^2 tiles in VMEM).  The
roofline table therefore reports BOTH: the XLA number (upper bound) and
this closed-form fused-traffic estimate, per device:

train  = optimizer(28 B/param/dev) + grad-accum(8 B x M)
         + weights-read (3 passes x bf16 x gathered shard) x M
         + activations (~16 tensors x tokens_loc x d_model x 2 B / layer)
         + logits (3 x tokens_loc x V/tp x 4 B)
prefill= weights-read + activations + KV-cache write
decode = weights-read (gathered shard) + full KV-cache shard read + write
"""

from __future__ import annotations

from typing import Dict

from ..models import transformer as T
from ..models.config import ModelConfig, ShapeConfig


def estimate_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, *, n_dev: int,
                       dp: int, tp: int, n_micro: int = 1) -> float:
    P = T.count_params(cfg)
    P_active = T.count_params(cfg, active_only=True)
    B, S = shape.global_batch, shape.seq_len
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.n_layers
    tok_loc = max(1, B // dp) * (S if shape.kind != "decode" else 1)
    tok_micro = tok_loc / max(n_micro, 1)

    # per-device weight bytes touched per full pass (bf16 compute copies,
    # gathered over the FSDP axis -> 1/tp of the total remains sharded)
    w_pass = 2.0 * P_active / tp

    total = 0.0
    if shape.kind == "train":
        p_loc = P / n_dev
        total += 28.0 * p_loc                        # AdamW update r/w f32
        total += 8.0 * p_loc * n_micro               # grad accumulation
        total += 3.0 * w_pass * n_micro              # fwd + remat + bwd
        act = 16.0 * tok_micro * D * 2.0 * L
        total += act * n_micro
        total += 3.0 * tok_micro * (V / tp) * 4.0 * n_micro   # logits f32
    elif shape.kind == "prefill":
        total += w_pass
        total += 8.0 * tok_loc * D * 2.0 * L
        total += _cache_bytes(cfg, shape) / n_dev    # cache write
        total += tok_loc * (V / tp) * 4.0 / max(S, 1)  # last-pos logits
    else:  # decode
        total += w_pass                              # every weight, once
        total += 2.0 * _cache_bytes(cfg, shape) / n_dev / 2  # read + 1-row
        total += max(1, B // dp) * (V / tp) * 4.0
    return total


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global KV/state cache size in bytes for this cell."""
    import numpy as np

    cache = T.init_cache(cfg, shape.global_batch, shape.seq_len,
                         abstract=True)
    return float(sum(np.prod(l.shape) * l.dtype.itemsize
                     for l in __import__("jax").tree.leaves(cache)))
