"""Serving launcher: batched prefill + decode with KV cache, plus the
Ganesha-style cache-invalidation loop over LCAP (paper §IV-C-1).

Replicas prefill prompts into a KV/page cache keyed by (prompt-id,
version).  When a prompt's backing object changes (simulated admin
write), the owning replica emits CL_EVICT; every other replica is an
EPHEMERAL changelog reader and drops its stale entry — exactly the
paper's loose metadata-cache invalidation.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=2)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from .. import configs as C
    from ..core.proxy import LcapProxy
    from ..models import transformer as T
    from ..track import ActivityTracker, CacheInvalidator
    from ..runtime.steps import build_decode_step, build_prefill_step

    cfg = C.get_smoke(args.arch) if args.smoke else C.get_config(args.arch)
    params = T.init_params(cfg, seed=0)
    B, P, G = args.batch, args.prompt_len, args.gen_len
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, P)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.n_frames, cfg.d_model), jnp.float32)
    if cfg.n_image_patches:
        batch["image_embeds"] = jnp.asarray(
            rng.randn(B, cfg.n_image_patches, cfg.d_model), jnp.float32)

    prefill = jax.jit(build_prefill_step(cfg, max_seq=P + G,
                                         attn_impl="naive"))
    decode = jax.jit(build_decode_step(cfg), donate_argnums=(1,))

    logits, cache = prefill(params, batch)
    out_tokens = [jnp.argmax(logits, -1)]
    for i in range(G - 1):
        pos = jnp.full((B,), P + i, jnp.int32)
        logits, cache = decode(params, cache, out_tokens[-1][:, None], pos)
        out_tokens.append(jnp.argmax(logits, -1))
    gen = jnp.stack(out_tokens, 1)

    # --- LCAP cache invalidation across replicas (paper §IV-C-1) ---------
    owner = ActivityTracker(run_id=1, host_id=0, jobid="serve-owner")
    proxy = LcapProxy({"host0": owner.llog})
    page_caches = [{(pid, 1): f"kv-page-{pid}" for pid in range(B)}
                   for _ in range(args.replicas)]
    invalidators = [CacheInvalidator(proxy, pc) for pc in page_caches]
    owner.evict(2, 1, reason="prompt-updated")      # object 2 changed
    proxy.pump()
    for inv in invalidators:
        inv.poll()

    print(json.dumps({
        "arch": cfg.arch_id,
        "generated_shape": list(gen.shape),
        "generated_finite": bool(jnp.all(gen >= 0)),
        "evicted_per_replica": [inv.invalidated for inv in invalidators],
        "remaining_pages": [len(pc) for pc in page_caches],
    }, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
