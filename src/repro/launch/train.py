"""Training launcher.

On real hardware each host runs this entrypoint (jax.distributed
handles process groups); on CPU it drives reduced configs end-to-end
with the full LCAP tracking stack.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --smoke --steps 20 --workdir /tmp/run1
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--n-hosts", type=int, default=2)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--host-devices", type=int, default=0,
                    help="simulate N host devices (sets XLA_FLAGS; must "
                         "be first jax use in the process)")
    ap.add_argument("--workdir", default="/tmp/repro_train")
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    from .. import configs as C
    from ..runtime.train_loop import Trainer

    cfg = C.get_smoke(args.arch) if args.smoke else C.get_config(args.arch)
    trainer = Trainer(cfg, workdir=args.workdir,
                      global_batch=args.global_batch, seq_len=args.seq_len,
                      n_hosts=args.n_hosts, ckpt_every=args.ckpt_every)
    hist = trainer.run(args.steps)
    trainer.ckpt.wait()
    rows = trainer.metrics[0].query(
        "SELECT COUNT(*), COUNT(DISTINCT type) FROM events")
    print(json.dumps({
        "arch": cfg.arch_id,
        "steps": [h["step"] for h in hist[-3:]],
        "loss_first": hist[0]["loss"], "loss_last": hist[-1]["loss"],
        "metrics_rows": rows[0][0], "event_types": rows[0][1],
        "stragglers": sorted(trainer.straggler.flagged),
        "last_ckpt": trainer.committer.latest_committed(),
    }, indent=1))
    trainer.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
