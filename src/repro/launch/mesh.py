"""Production mesh definitions (TPU v5e pods: 256 chips/pod).

``make_production_mesh`` is a FUNCTION (never module-level state) so
importing this module touches no jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices are available —
    used by tests and the elastic runtime."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-chip effective)
CHIPS_PER_POD = 256
